"""Shared CLI surface: one flag vocabulary, one validator, one prescan.

``repro.launch.cli`` is the single declaration point for the flags the
stream/transport/fleet/workload drivers share.  The unit half exercises
the prescan and validator in-process (no jax); the subprocess half pins
``--help`` and error-exit parity across all four entry points -- same
flags advertised, same exit code 2, same pinned message for the same bad
value, regardless of which driver you typed it at.
"""
import argparse
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.cli import (
    add_devices_arg, add_metrics_args, add_slot_table_args, add_symed_args,
    prescan_host_devices, validate_shared_args,
)

REPO = Path(__file__).resolve().parents[1]
SUBENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}

CLIS = ("repro.launch.stream", "repro.launch.transport",
        "repro.launch.fleet", "repro.workload")


# ----------------------------------------------------------- prescan unit


class TestPrescan:
    def test_sets_xla_flags_for_multi_device(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        prescan_host_devices(["prog", "--devices", "4"])
        assert "--xla_force_host_platform_device_count=4" in \
            os.environ["XLA_FLAGS"]

    def test_equals_form_and_last_wins(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        prescan_host_devices(["prog", "--devices", "2", "--devices=8"])
        assert "device_count=8" in os.environ["XLA_FLAGS"]

    def test_single_device_leaves_env_alone(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        prescan_host_devices(["prog", "--devices", "1"])
        assert "XLA_FLAGS" not in os.environ

    def test_malformed_value_left_for_argparse(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        prescan_host_devices(["prog", "--devices", "many"])
        assert "XLA_FLAGS" not in os.environ

    def test_preserves_existing_flags(self, monkeypatch):
        monkeypatch.setenv("XLA_FLAGS", "--xla_foo=1")
        prescan_host_devices(["prog", "--devices=2"])
        assert "device_count=2" in os.environ["XLA_FLAGS"]
        assert "--xla_foo=1" in os.environ["XLA_FLAGS"]


# --------------------------------------------------------- validator unit


def _full_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=6)
    ap.add_argument("--length", type=int, default=384)
    ap.add_argument("--window", type=int, default=48)
    add_slot_table_args(ap, max_slots=4)
    add_devices_arg(ap)
    add_symed_args(ap)
    add_metrics_args(ap)
    return ap


BAD_ARGS = [
    (["--sessions", "0"], "--sessions must be >= 1, got 0"),
    (["--length", "1"], "--length must be >= 2, got 1"),
    (["--window", "0"], "--window must be >= 1, got 0"),
    (["--window", "500"], "--window 500 exceeds --length 384"),
    (["--digitize-every", "-1"], "--digitize-every must be >= 0, got -1"),
    (["--tol", "-1"], "--tol must be > 0, got -1.0"),
    (["--alpha", "2"], "--alpha must be in (0, 1], got 2.0"),
    (["--devices", "0"], "--devices must be >= 1, got 0"),
    (["--max-slots", "0"], "--max-slots must be >= 1, got 0"),
    (["--max-slots", "6", "--devices", "4"],
     "--max-slots 6 must divide over --devices 4"),
    (["--min-slots", "9"], "--min-slots 9 must be in [1, --max-slots 4]"),
    (["--max-slots", "8", "--min-slots", "3", "--devices", "2"],
     "--min-slots 3 must divide over --devices 2"),
    (["--shrink-patience", "0"], "--shrink-patience must be >= 1, got 0"),
    (["--metrics-port", "70000"],
     "--metrics-port must be in [0, 65535], got 70000"),
    (["--metrics-linger", "-1"], "--metrics-linger must be >= 0, got -1.0"),
]


class TestSharedValidator:
    def test_good_args_pass(self):
        ap = _full_parser()
        validate_shared_args(ap, ap.parse_args([]))  # defaults are valid
        validate_shared_args(ap, ap.parse_args(
            ["--devices", "4", "--max-slots", "8", "--min-slots", "4",
             "--metrics-port", "0"]))

    @pytest.mark.parametrize("argv,message", BAD_ARGS,
                             ids=[" ".join(a) for a, _ in BAD_ARGS])
    def test_bad_args_exit_2_with_pinned_message(self, argv, message,
                                                 capsys):
        ap = _full_parser()
        with pytest.raises(SystemExit) as exc:
            validate_shared_args(ap, ap.parse_args(argv))
        assert exc.value.code == 2
        assert message in capsys.readouterr().err

    def test_partial_namespace_skips_absent_flags(self):
        # fleet has no --max-slots; a namespace without it must validate
        ap = argparse.ArgumentParser()
        ap.add_argument("--streams", type=int, default=8)
        add_devices_arg(ap, default=8)
        add_symed_args(ap)
        validate_shared_args(ap, ap.parse_args([]))
        with pytest.raises(SystemExit):
            validate_shared_args(ap, ap.parse_args(["--streams", "0"]))


# ------------------------------------------------------ subprocess parity


def _run_cli(module, argv):
    return subprocess.run(
        [sys.executable, "-m", module, *argv], capture_output=True,
        text=True, env=SUBENV, cwd=REPO, timeout=300)


@pytest.mark.slow
class TestCLIParity:
    @pytest.mark.parametrize("module", CLIS)
    def test_help_exits_zero_and_advertises_shared_flags(self, module):
        proc = _run_cli(module, ["--help"])
        assert proc.returncode == 0, proc.stderr[-2000:]
        for flag in ("--devices", "--tol", "--alpha", "--seed"):
            assert flag in proc.stdout, (module, flag)
        if module != "repro.workload":
            for flag in ("--metrics-port", "--metrics-linger", "--trace-out"):
                assert flag in proc.stdout, (module, flag)
        if module in ("repro.launch.stream", "repro.launch.transport"):
            for flag in ("--max-slots", "--min-slots", "--autoscale",
                         "--shrink-patience", "--pretrace"):
                assert flag in proc.stdout, (module, flag)

    @pytest.mark.parametrize("module,argv,message", [
        ("repro.launch.stream", ["--tol", "-1"],
         "--tol must be > 0, got -1.0"),
        ("repro.launch.transport", ["--metrics-port", "70000"],
         "--metrics-port must be in [0, 65535], got 70000"),
        ("repro.launch.fleet", ["--devices", "0"],
         "--devices must be >= 1, got 0"),
        ("repro.workload", ["--scenario", "flash_crowd", "--sessions", "0"],
         "--sessions must be >= 1, got 0"),
    ], ids=[c.rsplit(".", 1)[-1] for c in CLIS])
    def test_bad_value_rejected_identically(self, module, argv, message):
        proc = _run_cli(module, argv)
        assert proc.returncode == 2, (proc.returncode, proc.stderr[-2000:])
        assert message in proc.stderr

    def test_workload_rejects_unknown_slo(self):
        proc = _run_cli("repro.workload",
                        ["--scenario", "flash_crowd", "--slo", "bogus=1"])
        assert proc.returncode == 2
        assert "unknown SLO" in proc.stderr

    def test_stream_workload_and_pattern_are_exclusive(self):
        proc = _run_cli("repro.launch.stream",
                        ["--workload", "flash_crowd",
                         "--arrival-pattern", "bursty"])
        assert proc.returncode == 2
        assert "mutually exclusive" in proc.stderr
