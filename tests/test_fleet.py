"""Fleet runtime tests: chunked/online ingestion + the sharded shard_map path.

The chunked path must be *bitwise* identical to the whole-stream encoder
(same fp ops in the same order; the carry is exact), and the sharded runtime
must match ``symed_batch`` regardless of mesh layout (per-stream PRNG keys
are split before sharding) -- including the 2-D ``(pod, data)`` grid with
hierarchical telemetry reduction and the streaming-receiver ingestion modes.
Multi-device coverage runs in subprocesses with forced host devices,
mirroring ``tests/test_system.py``; the CLI invariance tests assert that
``pieces`` / ``wire_bytes`` / ``compression_rate`` totals are identical at
--devices 1/4/8 and on a pod x data layout.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_stream

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.core.symed import (
    SymEDConfig, symed_batch, symed_encode, symed_encode_chunk, symed_finish,
)

CFG = SymEDConfig(tol=0.5, alpha=0.01, n_max=256, k_max=32, len_max=128)


def _chunked_encode(ts, cfg, chunk_len, key, reconstruct=True):
    state, parts = None, []
    for c in range(0, ts.shape[-1], chunk_len):
        state, ev = symed_encode_chunk(ts[..., c: c + chunk_len], cfg, state)
        parts.append(ev)
    events = {k: jnp.concatenate([p[k] for p in parts], axis=-1)
              for k in parts[0]}
    return symed_finish(events, state, cfg, key, ts, reconstruct)


class TestChunkedEncode:
    @pytest.mark.parametrize("chunk_len", [96, 128, 512, 1024])
    def test_bitwise_equals_whole_stream(self, rng, chunk_len):
        """Carried CompressorState across chunks == one-shot encode, bitwise.

        chunk_len=96 exercises a ragged tail (512 % 96 != 0); 1024 a single
        oversized window."""
        ts = jnp.asarray(make_stream(rng, 512))
        key = jax.random.key(0)
        whole = symed_encode(ts, CFG, key)
        chunked = _chunked_encode(ts, CFG, chunk_len, key)
        assert set(whole) == set(chunked)
        for k in whole:
            np.testing.assert_array_equal(
                np.asarray(whole[k]), np.asarray(chunked[k]), err_msg=k)

    def test_chunk_events_align_with_stream(self, rng):
        """Per-step event arrays concatenate to exactly T slots; slot 0 (the
        t0 'hello') never emits."""
        ts = jnp.asarray(make_stream(rng, 300))
        state, parts = None, []
        for c in range(0, 300, 100):
            state, ev = symed_encode_chunk(ts[c: c + 100], CFG, state)
            assert ev["emit"].shape[-1] == 100
            parts.append(ev)
        emit = np.concatenate([np.asarray(p["emit"]) for p in parts], -1)
        assert emit.shape == (300,)
        assert not emit[0]

    def test_state_is_resumable_midstream(self, rng):
        """The carry after k chunks equals the whole-stream compressor state
        at the same point (tree-equal, not just behaviorally equal)."""
        from repro.core.compress import compress_stream

        ts = jnp.asarray(make_stream(rng, 256))
        full = compress_stream(ts, tol=CFG.tol, len_max=CFG.len_max,
                               alpha=CFG.alpha)
        state = None
        for c in range(0, 256, 64):
            state, _ = symed_encode_chunk(ts[c: c + 64], CFG, state)
        for a, b in zip(jax.tree.leaves(state),
                        jax.tree.leaves(full["final_state"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batched_chunks(self, rng):
        """Chunked ingestion is vectorized over the stream (slab) axis."""
        slab = jnp.asarray(np.stack([make_stream(rng, 256) for _ in range(3)]))
        keys = jax.random.split(jax.random.key(0), 3)
        state, parts = None, []
        for c in range(0, 256, 64):
            state, ev = symed_encode_chunk(slab[:, c: c + 64], CFG, state)
            parts.append(ev)
        events = {k: jnp.concatenate([p[k] for p in parts], -1) for k in parts[0]}
        out = jax.vmap(
            lambda e, s, k, t: symed_finish(e, s, CFG, k, t, True)
        )(events, state, keys, slab)
        for i in range(3):
            single = symed_encode(slab[i], CFG, keys[i])
            np.testing.assert_array_equal(
                np.asarray(out["symbols"][i]), np.asarray(single["symbols"]))
            np.testing.assert_array_equal(
                np.asarray(out["n_pieces"][i]), np.asarray(single["n_pieces"]))


class TestFleetRuntime:
    def test_single_device_matches_symed_batch(self, rng):
        """run_fleet on a 1-device mesh == plain symed_batch (both modes)."""
        from repro.launch.fleet import fleet_data_mesh, run_fleet

        slab = jnp.asarray(np.stack([make_stream(rng, 384) for _ in range(4)]))
        ref = symed_batch(slab, CFG, jax.random.key(0), reconstruct=False)
        mesh = fleet_data_mesh(1)
        for chunk_len in (None, 128):
            out, tele = run_fleet(slab, CFG, jax.random.key(0), mesh,
                                  chunk_len=chunk_len, reconstruct=False)
            np.testing.assert_array_equal(
                np.asarray(out["symbols"]), np.asarray(ref["symbols"]))
            np.testing.assert_array_equal(
                np.asarray(out["n_pieces"]), np.asarray(ref["n_pieces"]))
            assert float(tele["streams"]) == 4
            assert float(tele["points"]) == 4 * 384
            assert float(tele["pieces"]) == float(
                jnp.sum(ref["n_pieces"].astype(jnp.float32)))
            assert float(tele["wire_bytes"]) == pytest.approx(
                float(jnp.sum(ref["wire_bytes"])))

    def test_uneven_shard_rejected(self):
        """n_streams must divide over the data shards (checked up front)."""
        import types

        from repro.launch.fleet import run_fleet

        fake_mesh = types.SimpleNamespace(
            axis_names=("data",),
            devices=np.empty((2,), dtype=object),
        )
        with pytest.raises(ValueError, match="divide"):
            run_fleet(jnp.zeros((3, 64)), CFG, jax.random.key(0), fake_mesh)

    def test_run_fleet_error_paths(self):
        """Bad arguments fail fast with clear messages, before any tracing."""
        import types

        from repro.launch.fleet import run_fleet

        fake_mesh = types.SimpleNamespace(
            axis_names=("pod", "data"),
            devices=np.empty((2, 2), dtype=object),
        )
        key = jax.random.key(0)
        with pytest.raises(ValueError, match="chunk_len must be >= 1"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh, chunk_len=0,
                      axis=("pod", "data"))
        with pytest.raises(ValueError, match="unknown mesh axis 'model'"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh, axis="model")
        with pytest.raises(ValueError, match="unknown mesh axis"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh,
                      axis=("pod", "replica"))
        with pytest.raises(ValueError, match="at least one mesh axis"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh, axis=())
        with pytest.raises(ValueError, match="divide over 4 podxdata"):
            run_fleet(jnp.zeros((6, 64)), CFG, key, fake_mesh,
                      axis=("pod", "data"))
        with pytest.raises(ValueError, match="digitize_every_k must be >= 0"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh,
                      chunk_len=32, digitize_every_k=-1, axis=("pod", "data"))
        with pytest.raises(ValueError, match="requires chunk_len"):
            run_fleet(jnp.zeros((4, 64)), CFG, key, fake_mesh,
                      digitize_every_k=2, axis=("pod", "data"))

    def test_fleet_report_edge_cases(self):
        """Empty fleets (zero streams / zero points) and zero wall time never
        divide by zero; rates clamp to finite values."""
        from repro.launch.fleet import fleet_report

        zero = {k: 0.0 for k in
                ("streams", "points", "pieces", "wire_bytes", "raw_bytes")}
        rep = fleet_report(zero, 0.0)
        for k, v in rep.items():
            assert np.isfinite(v), (k, v)
        assert rep["compression_rate"] == 0.0
        assert rep["mean_pieces_per_stream"] == 0.0
        assert rep["points_per_s"] == 0.0

        # zero pieces but nonzero points: latency clamps, cr well-defined
        rep = fleet_report({**zero, "streams": 2.0, "points": 128.0,
                            "raw_bytes": 512.0, "wire_bytes": 4.0}, 1.0)
        assert rep["ms_per_symbol"] == 1e3
        assert rep["compression_rate"] == pytest.approx(4.0 / 512.0)

        # normal case: latency is wall / pieces
        rep = fleet_report({"streams": 1.0, "points": 100.0, "pieces": 50.0,
                            "wire_bytes": 204.0, "raw_bytes": 400.0}, 2.1)
        assert rep["ms_per_symbol"] == pytest.approx(2.1e3 / 50.0)

    def test_sharded_matches_batch_on_2x2_mesh(self, tmp_path):
        """shard_map over the data axis of a (2,2) mesh reproduces
        symed_batch exactly (subprocess: forced host devices)."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core.symed import SymEDConfig, symed_batch
from repro.launch.mesh import make_test_mesh
from repro.launch.fleet import run_fleet

cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=16, len_max=64)
rng = np.random.default_rng(3)
slab = jnp.asarray(np.cumsum(rng.normal(0, 0.3, (8, 256)), axis=1),
                   jnp.float32)
ref = symed_batch(slab, cfg, jax.random.key(7), reconstruct=False)

mesh = make_test_mesh((2, 2), ("data", "model"))
for chunk_len in (None, 64):
    out, tele = run_fleet(slab, cfg, jax.random.key(7), mesh,
                          chunk_len=chunk_len, reconstruct=False)
    np.testing.assert_array_equal(np.asarray(out["symbols"]),
                                  np.asarray(ref["symbols"]))
    np.testing.assert_array_equal(np.asarray(out["n_pieces"]),
                                  np.asarray(ref["n_pieces"]))
    np.testing.assert_allclose(np.asarray(out["centers"]),
                               np.asarray(ref["centers"]))
    assert float(tele["pieces"]) == float(jnp.sum(ref["n_pieces"]))
print("FLEET_SHARD_OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, env=env, cwd=REPO, timeout=560)
        assert "FLEET_SHARD_OK" in out.stdout, (out.stdout[-500:],
                                                out.stderr[-2000:])

    def test_pod_data_mesh_matches_batch(self):
        """Acceptance: a 2-D (pod, data) run_fleet reproduces single-device
        results and telemetry totals exactly -- hierarchical psum (data
        within a pod, then across pods) over 2x2 == flat 1-device totals.
        Subprocess: forced host devices."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core.symed import SymEDConfig, symed_batch
from repro.launch.mesh import make_pod_data_mesh
from repro.launch.fleet import fleet_data_mesh, run_fleet

cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=16, len_max=64)
rng = np.random.default_rng(11)
slab = jnp.asarray(np.cumsum(rng.normal(0, 0.3, (8, 256)), axis=1),
                   jnp.float32)
ref = symed_batch(slab, cfg, jax.random.key(7), reconstruct=False)

mesh1 = fleet_data_mesh(1)
_, ref_tele = run_fleet(slab, cfg, jax.random.key(7), mesh1,
                        chunk_len=64, digitize_every_k=1, reconstruct=False)

pods = make_pod_data_mesh(2, 2)
for chunk_len, dk in ((None, None), (64, 1)):
    out, tele = run_fleet(slab, cfg, jax.random.key(7), pods,
                          chunk_len=chunk_len, digitize_every_k=dk,
                          reconstruct=False, axis=("pod", "data"))
    np.testing.assert_array_equal(np.asarray(out["symbols"]),
                                  np.asarray(ref["symbols"]))
    np.testing.assert_array_equal(np.asarray(out["symbols_online"]),
                                  np.asarray(ref["symbols_online"]))
    np.testing.assert_array_equal(np.asarray(out["n_pieces"]),
                                  np.asarray(ref["n_pieces"]))
    np.testing.assert_array_equal(np.asarray(out["centers"]),
                                  np.asarray(ref["centers"]))
    for k in ref_tele:
        if k == "wire_out_bytes" and chunk_len is None:
            # outbound delta traffic is *mode*-dependent by design (streaming
            # emits a frame per digitize pass; whole-stream emits only the
            # closing frame) -- check the closing-frames formula instead
            want = float(np.sum(4.0 + 5.0 * np.asarray(ref["n_pieces"])))
            assert float(tele[k]) == want, (k, tele[k], want)
            continue
        assert float(tele[k]) == float(ref_tele[k]), (k, tele[k], ref_tele[k])
print("FLEET_POD_OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, env=env, cwd=REPO, timeout=560)
        assert "FLEET_POD_OK" in out.stdout, (out.stdout[-500:],
                                              out.stderr[-2000:])

    @pytest.mark.slow
    def test_cli_entrypoint(self):
        """`python -m repro.launch.fleet` dry-runs on forced host devices and
        prints fleet telemetry."""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.fleet", "--streams", "16",
             "--length", "256", "--chunk", "128", "--devices", "2"],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=560,
        )
        assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
        assert "compression rate" in out.stdout
        assert "pieces/s" in out.stdout
        assert "devices / data shards   : 2" in out.stdout
        assert "symbol latency" in out.stdout


def _parse_fleet_stdout(stdout: str) -> dict:
    """Extract the layout-invariant telemetry totals from the CLI report."""
    vals = {}
    for line in stdout.splitlines():
        if ":" not in line:
            continue
        name, _, rest = line.partition(":")
        name, rest = name.strip(), rest.strip()
        if name == "fleet pieces":
            vals["pieces"] = int(rest.split()[0])
        elif name == "fleet wire-in bytes":
            vals["wire_bytes"] = int(rest.split()[0].replace(",", ""))
        elif name == "fleet wire-out bytes":
            vals["wire_out_bytes"] = int(rest.split()[0].replace(",", ""))
        elif name == "fleet raw bytes":
            vals["raw_bytes"] = int(rest.split()[0].replace(",", ""))
        elif name == "compression rate":
            vals["compression_rate"] = float(rest.split()[0])
    return vals


class TestCLI:
    @staticmethod
    def _run(*args, timeout=560):
        env = dict(os.environ, PYTHONPATH="src")
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.fleet", *args],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout,
        )

    @pytest.mark.slow
    def test_device_count_invariance(self):
        """The fleet's pieces / wire_bytes / compression_rate totals are
        invariant to the device layout: 1, 4, and 8 data shards, and a
        2x2 pod x data grid, all report identical numbers (per-stream PRNG
        keys are split before sharding; psums add exact integer-valued
        floats)."""
        base = ["--streams", "8", "--length", "192", "--chunk", "64"]
        runs = {
            "devices1": self._run(*base, "--devices", "1"),
            "devices4": self._run(*base, "--devices", "4"),
            "devices8": self._run(*base, "--devices", "8"),
            "pods2x2": self._run(*base, "--devices", "4", "--pods", "2",
                                 "--digitize-every", "1"),
        }
        parsed = {}
        for name, proc in runs.items():
            assert proc.returncode == 0, (name, proc.stdout[-500:],
                                          proc.stderr[-2000:])
            parsed[name] = _parse_fleet_stdout(proc.stdout)
            assert set(parsed[name]) == {"pieces", "wire_bytes", "raw_bytes",
                                         "wire_out_bytes",
                                         "compression_rate"}, (name,
                                                               proc.stdout)
        ref = parsed["devices1"]
        for name, vals in parsed.items():
            if name == "pods2x2":
                # that run digitizes every window (k=1), so it emits a delta
                # frame per window per stream instead of only the closing
                # frame -- wire-out differs by exactly the extra 4B headers
                vals = dict(vals)
                extra_frames = 8 * (192 // 64)  # streams x mid-stream windows
                assert (vals.pop("wire_out_bytes")
                        == ref["wire_out_bytes"] + 4 * extra_frames), name
                assert vals == {k: v for k, v in ref.items()
                                if k != "wire_out_bytes"}, (name, vals, ref)
                continue
            assert vals == ref, (name, vals, ref)

    def test_rejects_chunk_larger_than_length(self):
        out = self._run("--streams", "4", "--length", "128", "--chunk", "256",
                        "--devices", "1")
        assert out.returncode != 0
        assert "exceeds --length" in out.stderr

    def test_rejects_negative_tol(self):
        out = self._run("--streams", "4", "--length", "128",
                        "--tol", "-0.5", "--devices", "1")
        assert out.returncode != 0
        assert "--tol must be > 0" in out.stderr

    def test_rejects_bad_cadence_and_pods(self):
        out = self._run("--digitize-every", "2", "--devices", "1")
        assert out.returncode != 0
        assert "--digitize-every requires --chunk" in out.stderr

        out = self._run("--devices", "4", "--pods", "3")
        assert out.returncode != 0
        assert "must divide over" in out.stderr
