"""Streaming-receiver equivalence battery.

The online receiver (``ReceiverState`` + ``symed_receive_chunk`` +
``symed_receive_finish``) must be *bitwise* interchangeable with the
whole-stream ``symed_encode`` and the chunked-sender ``symed_finish`` paths:
same fp ops in the same order, for every stream length, window split, and
digitize cadence.  The properties below drive random combinations through
the hypothesis shim; stream lengths and window sizes are drawn from small
palettes so the jit cache stays warm across examples.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import make_stream

from repro.core.symed import (
    SymEDConfig, symed_encode, symed_encode_chunk, symed_finish,
    symed_receive_chunk, symed_receive_finish, symed_step_chunk,
)

# small capacities keep per-shape compiles cheap; both paths share the config
CFG = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                  len_max=32, n_max=64, lloyd_iters=5)

T_LENS = (96, 128, 160)     # palettes bound the number of distinct jit traces
CHUNKS = (17, 32, 48)


def stream_encode(ts, cfg, key, chunk_len, digitize_every_k, reconstruct=False):
    """Reference driver: feed ``ts`` through the streaming receiver in
    ``chunk_len`` windows, digitizing every ``digitize_every_k`` windows."""
    state = None
    for c in range(0, ts.shape[-1], chunk_len):
        window = ts[..., c: c + chunk_len]
        if state is None:
            state, info = symed_receive_chunk(
                window, cfg, None, key, digitize_every_k=digitize_every_k)
        else:
            state, info = symed_receive_chunk(
                window, cfg, state, digitize_every_k=digitize_every_k)
    return symed_receive_finish(
        state, cfg, ts if reconstruct else None, reconstruct)


def assert_outputs_equal(a, b, context=""):
    assert set(a) == set(b), (context, set(a) ^ set(b))
    for name in a:
        np.testing.assert_array_equal(
            np.asarray(a[name]), np.asarray(b[name]),
            err_msg=f"{context}: {name}")


class TestStreamingEquivalence:
    @given(st.sampled_from(T_LENS), st.sampled_from(CHUNKS),
           st.integers(1, 4), st.integers(0, 3))
    @settings(max_examples=30, deadline=None)
    def test_bitwise_equals_whole_stream(self, t_len, chunk_len, cadence, seed):
        """For random lengths, window splits, and digitize cadences, the
        streaming receiver's end-of-stream symbols/centers/telemetry are
        bitwise-equal to one-shot symed_encode."""
        rng = np.random.default_rng(1000 + seed)
        ts = jnp.asarray(make_stream(rng, t_len))
        key = jax.random.key(seed)
        whole = symed_encode(ts, CFG, key, reconstruct=False)
        streamed = stream_encode(ts, CFG, key, chunk_len, cadence)
        assert_outputs_equal(
            whole, streamed,
            f"T={t_len} C={chunk_len} k={cadence} seed={seed}")

    @given(st.sampled_from(CHUNKS), st.integers(0, 2))
    @settings(max_examples=15, deadline=None)
    def test_bitwise_equals_symed_finish(self, chunk_len, seed):
        """Acceptance: streaming end-of-stream == the chunked-sender
        symed_finish path on the same stream (the battery's anchor)."""
        rng = np.random.default_rng(2000 + seed)
        ts = jnp.asarray(make_stream(rng, 128))
        key = jax.random.key(seed)

        state, parts = None, []
        for c in range(0, 128, chunk_len):
            state, ev = symed_encode_chunk(ts[c: c + chunk_len], CFG, state)
            parts.append(ev)
        events = {k: jnp.concatenate([p[k] for p in parts], axis=-1)
                  for k in parts[0]}
        finish = symed_finish(events, state, CFG, key, ts, reconstruct=False)

        streamed = stream_encode(ts, CFG, key, chunk_len, digitize_every_k=1)
        assert_outputs_equal(finish, streamed, f"C={chunk_len} seed={seed}")

    @given(st.sampled_from(T_LENS), st.sampled_from(CHUNKS))
    @settings(max_examples=12, deadline=None)
    def test_cadence_invariance(self, t_len, chunk_len):
        """The digitize cadence only changes *when* symbols emerge, never the
        end-of-stream state: every k (and the defer-to-finish k=0 path via
        symed_step_chunk) agrees bitwise."""
        rng = np.random.default_rng(t_len * 31 + chunk_len)
        ts = jnp.asarray(make_stream(rng, t_len))
        key = jax.random.key(1)
        ref = stream_encode(ts, CFG, key, chunk_len, digitize_every_k=1)
        for cadence in (2, 3):
            assert_outputs_equal(
                ref, stream_encode(ts, CFG, key, chunk_len, cadence),
                f"k={cadence}")
        state = None
        for c in range(0, t_len, chunk_len):
            state, _ = symed_step_chunk(ts[c: c + chunk_len], CFG, state, key)
        assert_outputs_equal(
            ref, symed_receive_finish(state, CFG), "step_chunk+finish")

    def test_reconstruct_bitwise_equal(self, rng):
        """The reconstruction/DTW outputs agree too (needs the raw stream)."""
        ts = jnp.asarray(make_stream(rng, 160))
        key = jax.random.key(5)
        whole = symed_encode(ts, CFG, key, reconstruct=True)
        streamed = stream_encode(ts, CFG, key, 48, 2, reconstruct=True)
        assert_outputs_equal(whole, streamed, "reconstruct")

    def test_online_symbols_stream_out_incrementally(self, rng):
        """With cadence k=1 every window's digitized prefix is final: the
        symbols visible after each window are a prefix of the whole-stream
        ``symbols_online`` (this is what makes the receiver *online*)."""
        ts = jnp.asarray(make_stream(rng, 160))
        key = jax.random.key(9)
        whole = symed_encode(ts, CFG, key, reconstruct=False)
        ref_online = np.asarray(whole["symbols_online"])

        state, seen = None, 0
        for c in range(0, 160, 32):
            if state is None:
                state, info = symed_receive_chunk(
                    ts[c: c + 32], CFG, None, key, digitize_every_k=1)
            else:
                state, info = symed_receive_chunk(
                    ts[c: c + 32], CFG, state, digitize_every_k=1)
            n_dig = int(info["n_digitized"])
            assert n_dig >= seen, "digitized count must be monotone"
            assert n_dig == int(info["n_pieces"]), "k=1 leaves no backlog"
            np.testing.assert_array_equal(
                np.asarray(info["symbols_online"])[:n_dig],
                ref_online[:n_dig],
                err_msg=f"prefix after window ending at {c + 32}")
            seen = n_dig
        out = symed_receive_finish(state, CFG)
        assert int(out["n_pieces"]) >= seen

    def test_open_stream_requires_key(self):
        with pytest.raises(ValueError, match="requires a PRNG key"):
            symed_receive_chunk(jnp.zeros(8), CFG, None, None)

    def test_negative_cadence_rejected(self):
        with pytest.raises(ValueError, match="digitize_every_k"):
            symed_receive_chunk(jnp.zeros(8), CFG, None, jax.random.key(0),
                                digitize_every_k=-1)

    def test_reconstruct_requires_stream(self, rng):
        ts = jnp.asarray(make_stream(rng, 64))
        state, _ = symed_receive_chunk(ts, CFG, None, jax.random.key(0))
        with pytest.raises(ValueError, match="requires the raw stream"):
            symed_receive_finish(state, CFG, None, reconstruct=True)

    def test_vmapped_streaming_matches_single(self, rng):
        """The receiver vmaps over a slab (the fleet's shard body)."""
        slab = jnp.asarray(np.stack([make_stream(rng, 128) for _ in range(3)]))
        keys = jax.random.split(jax.random.key(2), 3)
        state = None
        for c in range(0, 128, 32):
            if state is None:
                state, _ = jax.vmap(
                    lambda w, k: symed_receive_chunk(w, CFG, None, k,
                                                     digitize_every_k=2)
                )(slab[:, c: c + 32], keys)
            else:
                state, _ = jax.vmap(
                    lambda w, s: symed_receive_chunk(w, CFG, s,
                                                     digitize_every_k=2)
                )(slab[:, c: c + 32], state)
        out = jax.vmap(
            lambda s: symed_receive_finish(s, CFG, None, False))(state)
        for i in range(3):
            single = symed_encode(slab[i], CFG, keys[i], reconstruct=False)
            for name in ("symbols", "symbols_online", "centers", "n_pieces",
                         "k", "cr"):
                np.testing.assert_array_equal(
                    np.asarray(out[name][i]), np.asarray(single[name]),
                    err_msg=f"stream {i}: {name}")
