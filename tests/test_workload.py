"""Workload-harness battery: trace schema, scenario zoo, legacy shims,
SLO gates, and replay determinism.

The heart of the contract: a ``workload_trace/v1`` trace plus a seed is a
complete description of a run.  Replaying it twice -- in-process or over
the loopback transport, on 1 or 4 forced host devices -- must produce
bitwise-identical delta streams and identical schedule-determined counter
totals.  The legacy ``--arrival-pattern`` shims must synthesize the exact
tick schedule the retired ``launch.stream._arrival_schedule`` generator
yielded (compared against a frozen copy of it below).
"""
import json
import os
import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.workload import (
    KNOWN_SLOS, SCENARIOS, Trace, TraceBuilder, Workload, check_slos,
    legacy_arrival_schedule, parse_slo, parse_slo_specs, scenario_seed,
    synthesize,
)

REPO = Path(__file__).resolve().parents[1]
SUBENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


# ------------------------------------------------------------ trace schema


class TestTraceSchema:
    def _small(self):
        b = TraceBuilder("t", 0, 2, 64, 32)
        b.open(0, "a", 0)
        b.open(0, "b", 1, mode="pieces")
        b.data(0, "a", 0)
        b.data(10, "a", 1)
        b.data(10, "b", 0)
        b.close(10, "a")
        b.data(20, "b", 1)
        b.close(20, "b")
        return b.build()

    def test_roundtrip_preserves_digest(self, tmp_path):
        tr = self._small()
        path = tmp_path / "t.jsonl"
        tr.save(str(path))
        tr2 = Trace.load(str(path))
        assert tr2.digest() == tr.digest()
        assert tr2.sessions == tr.sessions
        assert tr2.events == tr.events

    def test_counts_and_ticks(self):
        tr = self._small()
        assert tr.counts() == {"events": 8, "windows": 4, "sessions": 2}
        ticks = list(tr.ticks())
        assert [t for t, _ in ticks] == [0, 10, 20]
        assert sum(len(evs) for _, evs in ticks) == 8

    def test_rejects_time_going_backwards(self):
        b = TraceBuilder("t", 0, 1, 64, 32)
        b.open(10, "a", 0)
        b.data(0, "a", 0)
        with pytest.raises(ValueError, match="backwards"):
            b.build()

    def test_rejects_data_before_open(self):
        b = TraceBuilder("t", 0, 1, 64, 32)
        b.data(0, "a", 0)
        b.sessions["a"] = {"stream": 0, "mode": "raw"}
        with pytest.raises(ValueError, match="unopened"):
            b.build()

    def test_rejects_reopen_and_post_close(self):
        b = TraceBuilder("t", 0, 1, 64, 32)
        b.open(0, "a", 0)
        b.open(10, "a", 0)
        with pytest.raises(ValueError, match="reopened"):
            b.build()
        b2 = TraceBuilder("t", 0, 1, 64, 32)
        b2.open(0, "a", 0)
        b2.close(0, "a")
        b2.data(10, "a", 0)
        with pytest.raises(ValueError, match="already closed"):
            b2.build()

    def test_rejects_nonincreasing_window_ref(self):
        b = TraceBuilder("t", 0, 1, 64, 32)
        b.open(0, "a", 0)
        b.data(0, "a", 1)
        b.data(10, "a", 1)
        with pytest.raises(ValueError, match="not increasing"):
            b.build()

    def test_rejects_bad_schema_header(self):
        with pytest.raises(ValueError, match="schema"):
            Trace.from_jsonl('{"schema":"nope/v9"}\n')


# ------------------------------------------------------------ scenario zoo


class TestScenarioZoo:
    def test_every_scenario_synthesizes_valid(self):
        for name in SCENARIOS:
            tr = synthesize(name, seed=scenario_seed(name))
            tr.validate()  # no-throw
            assert tr.counts()["sessions"] >= 1

    def test_same_seed_same_digest(self):
        for name in ("flash_crowd", "dropout_churn", "slot_churn"):
            a = synthesize(name, seed=3).digest()
            b = synthesize(name, seed=3).digest()
            c = synthesize(name, seed=4).digest()
            assert a == b
            assert a != c

    def test_mixed_fleet_carries_both_modes(self):
        tr = synthesize("mixed_fleet", seed=0)
        modes = {m["mode"] for m in tr.sessions.values()}
        assert modes == {"raw", "pieces"}

    def test_dropout_churn_reconnects_share_stream_rows(self):
        tr = synthesize("dropout_churn", seed=scenario_seed("dropout_churn"))
        rows = [m["stream"] for m in tr.sessions.values()]
        assert len(rows) > len(set(rows))  # at least one row resumed

    def test_slot_churn_oversubscribes_its_slot_table(self):
        sc = SCENARIOS["slot_churn"]
        tr = synthesize("slot_churn", seed=scenario_seed("slot_churn"))
        assert tr.counts()["sessions"] > sc.server_kw["max_sessions"]
        assert sc.server_kw["evict_idle"]

    def test_synthesize_requires_explicit_seed(self):
        with pytest.raises(TypeError):
            synthesize("flash_crowd")  # seed is keyword-only on purpose

    def test_row_seeds_are_order_invariant(self):
        # the bench harness seeds every scenario row explicitly via
        # scenario_seed(name, base); synthesizing in any order -- or
        # skipping rows -- must not perturb any row's trace
        names = ["bursty", "flash_crowd", "slot_churn"]
        forward = {n: synthesize(n, seed=scenario_seed(n, 0)).digest()
                   for n in names}
        backward = {n: synthesize(n, seed=scenario_seed(n, 0)).digest()
                    for n in reversed(names)}
        alone = {"flash_crowd": synthesize(
            "flash_crowd", seed=scenario_seed("flash_crowd", 0)).digest()}
        assert forward == backward
        assert forward["flash_crowd"] == alone["flash_crowd"]


# ---------------------------------------------------------- legacy shims


def _reference_arrival_schedule(pattern, n_sessions, n_windows, rng):
    """Frozen copy of ``launch.stream._arrival_schedule`` as of its
    retirement (PR 10) -- the shim-equivalence oracle.  Do not edit."""
    cursors = [0] * n_sessions
    if pattern == "roundrobin":
        while any(c < n_windows for c in cursors):
            tick = [(s, cursors[s]) for s in range(n_sessions)
                    if cursors[s] < n_windows]
            for s, _ in tick:
                cursors[s] += 1
            yield tick
    elif pattern == "random":
        while any(c < n_windows for c in cursors):
            live = [s for s in range(n_sessions) if cursors[s] < n_windows]
            pick = [s for s in live if rng.random() < 0.6] or live[:1]
            tick = [(s, cursors[s]) for s in pick]
            for s, _ in tick:
                cursors[s] += 1
            yield tick
    elif pattern == "bursty":
        s = 0
        while any(c < n_windows for c in cursors):
            live = [i for i in range(n_sessions) if cursors[i] < n_windows]
            s = live[s % len(live)]
            burst = min(int(rng.integers(1, 4)), n_windows - cursors[s])
            for _ in range(burst):
                yield [(s, cursors[s])]
                cursors[s] += 1
            s += 1
    else:
        raise ValueError(pattern)


class TestLegacyShims:
    @pytest.mark.parametrize("pattern", ["roundrobin", "random", "bursty"])
    @pytest.mark.parametrize("seed", [0, 7])
    def test_shim_reproduces_retired_schedule(self, pattern, seed):
        sessions, length, window = 6, 384, 48
        n_windows = -(-length // window)
        rng = np.random.default_rng(seed)
        want = [list(t) for t in _reference_arrival_schedule(
            pattern, sessions, n_windows, rng)]
        wl = Workload.from_pattern(pattern, sessions=sessions, length=length,
                                   window=window, seed=seed, _warn=False)
        got = wl.trace().schedule()
        assert got == want

    def test_generator_port_matches_reference_directly(self):
        for seed in (0, 5):
            want = list(_reference_arrival_schedule(
                "bursty", 4, 6, np.random.default_rng(seed)))
            got = list(legacy_arrival_schedule(
                "bursty", 4, 6, np.random.default_rng(seed)))
            assert got == want

    def test_from_pattern_warns_deprecation(self):
        with pytest.warns(DeprecationWarning, match="arrival-pattern"):
            Workload.from_pattern("bursty", sessions=2, length=64,
                                  window=32, seed=0)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            Workload.from_pattern("bursty", sessions=2, length=64,
                                  window=32, seed=0, _warn=False)


# ------------------------------------------------------------------- SLOs


class TestSLOs:
    def test_parse_good(self):
        assert parse_slo("p99_symbol_ms=50") == ("p99_symbol_ms", 50.0)
        assert parse_slo_specs(["evict_rate=0.5", "evict_rate=0.25"]) == {
            "evict_rate": 0.25}

    def test_parse_rejects_unknown_key_and_bad_shape(self):
        with pytest.raises(ValueError, match="unknown SLO"):
            parse_slo("p42_symbol_ms=1")
        with pytest.raises(ValueError, match="key=limit"):
            parse_slo("p99_symbol_ms")
        with pytest.raises(ValueError):
            parse_slo("p99_symbol_ms=fast")

    def test_check_slos_flags_only_exceeded(self):
        measured = {"p99_symbol_ms": 80.0, "max_queue_depth": 3.0,
                    "evict_rate": 0.0}
        v = check_slos(measured, {"p99_symbol_ms": 50.0,
                                  "max_queue_depth": 64.0})
        assert [x.key for x in v] == ["p99_symbol_ms"]
        assert "p99_symbol_ms" in str(v[0])

    def test_check_slos_missing_measurement_violates(self):
        v = check_slos({}, {"p99_symbol_ms": 50.0})
        assert len(v) == 1 and np.isnan(v[0].measured)

    def test_known_slos_cover_scenario_defaults(self):
        for sc in SCENARIOS.values():
            assert set(sc.slos) <= set(KNOWN_SLOS)


# --------------------------------------------------- replay determinism


def _small_cfg():
    from repro.core.symed import SymEDConfig

    return SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                       len_max=32, n_max=64, lloyd_iters=5)


class TestReplayDeterminism:
    def test_two_runs_bitwise_identical(self):
        from repro.workload.replay import replay_trace

        tr = synthesize("mixed_fleet", seed=scenario_seed("mixed_fleet"),
                        sessions=4, length=64, window=32)
        kw = {"max_sessions": 4, "pretrace": True}
        a = replay_trace(tr, cfg=_small_cfg(), server_kw=kw, verify=True)
        b = replay_trace(tr, cfg=_small_cfg(), server_kw=kw, verify=True)
        assert a.delta_sha256 == b.delta_sha256
        assert a.counters == b.counters  # every obs counter total
        assert a.fingerprint() == b.fingerprint()
        assert a.verified == len(tr.sessions)

    def test_eviction_churn_deterministic(self):
        from repro.workload.replay import replay_trace

        # 5 sessions per wave + the background stream oversubscribe the
        # scenario's 4-slot table, so LRU eviction must fire
        wl = Workload("slot_churn", seed=scenario_seed("slot_churn"),
                      sessions=5, length=64, window=32)
        runs = [replay_trace(wl.trace(), cfg=_small_cfg(),
                             server_kw=wl.server_kw()) for _ in range(2)]
        assert runs[0].counters["evicted"] > 0  # scenario does its job
        assert runs[0].fingerprint() == runs[1].fingerprint()
        assert runs[0].counters == runs[1].counters

    @pytest.mark.slow
    def test_transport_matches_inprocess(self):
        from repro.workload.replay import LOOSE_COUNTER_KEYS, replay_trace

        tr = synthesize("mixed_fleet", seed=scenario_seed("mixed_fleet"),
                        sessions=4, length=64, window=32)
        kw = {"max_sessions": 4, "pretrace": True}
        inproc = replay_trace(tr, cfg=_small_cfg(), server_kw=kw)
        wire = replay_trace(tr, cfg=_small_cfg(), server_kw=kw,
                            transport=True, verify=True)
        assert wire.delta_sha256 == inproc.delta_sha256
        for k in LOOSE_COUNTER_KEYS:
            assert wire.counters[k] == inproc.counters[k], k

    @pytest.mark.slow
    def test_cli_devices_invariance(self, tmp_path):
        """--devices 1 vs 4: identical delta bytes + counter totals."""
        outs = {}
        for dev in (1, 4):
            out = tmp_path / f"bench_d{dev}.json"
            proc = subprocess.run(
                [sys.executable, "-m", "repro.workload",
                 "--scenario", "flash_crowd", "--sessions", "8",
                 "--length", "96", "--window", "32",
                 "--devices", str(dev), "--out", str(out)],
                capture_output=True, text=True, env=SUBENV, cwd=REPO,
                timeout=600)
            assert proc.returncode == 0, proc.stderr[-2000:]
            outs[dev] = json.load(open(out))["rows"][0]
        for key in ("delta_sha256", "trace_digest", "opened", "closed",
                    "evicted", "points_in", "symbols_out",
                    "max_queue_depth", "drains"):
            assert outs[1][key] == outs[4][key], key

    @pytest.mark.slow
    def test_cli_exit_codes(self, tmp_path):
        """Exit 0 when SLOs hold, 1 when violated; artifact records both."""
        base = [sys.executable, "-m", "repro.workload",
                "--scenario", "mixed_fleet", "--sessions", "2",
                "--length", "64", "--window", "32"]
        ok = subprocess.run(base, capture_output=True, text=True,
                            env=SUBENV, cwd=REPO, timeout=600)
        assert ok.returncode == 0, ok.stderr[-2000:]
        assert "violations=0" in ok.stdout
        out = tmp_path / "violated.json"
        bad = subprocess.run(
            base + ["--slo", "p99_symbol_ms=0.0001", "--out", str(out)],
            capture_output=True, text=True, env=SUBENV, cwd=REPO,
            timeout=600)
        assert bad.returncode == 1, (bad.returncode, bad.stderr[-2000:])
        assert "VIOLATION" in bad.stdout
        doc = json.load(open(out))
        assert doc["schema"] == "bench_transport/v1"
        assert doc["rows"][0]["violations"]
