"""Delta-stream equivalence battery for the resident session service.

The ``repro.launch.stream`` contract: a session's concatenated per-chunk
symbol deltas (every ``ingest`` frame plus the closing frame from ``close``)
must be **bitwise** equal to what the one-shot ``symed_encode`` /
``symed_finish`` paths produce on the same points -- for every stream
length, ragged window split, digitize cadence, and session open/close
ordering, with other sessions churning through the same slot table.  Ragged
splits are runtime values (the masked step never retraces), so the
properties vary them freely; table shapes and cadences come from small
palettes to bound compiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import make_stream

from repro.core.compress import compress_stream
from repro.core.symed import SymEDConfig, symed_encode
from repro.launch.stream import StreamServer

CFG = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                  len_max=32, n_max=64, lloyd_iters=5)
T_LENS = (96, 128, 160)   # palettes bound the number of distinct jit traces
WINDOW_CAP = 32


def feed_session(server, sid, ts, key, rng, lo=1, hi=49):
    """Open ``sid``, deliver ``ts`` in ragged arrivals, close; return the
    closing result plus every delta frame in arrival order."""
    server.open(sid, key=key)
    deltas, pos = [], 0
    while pos < len(ts):
        n = int(rng.integers(lo, hi))
        deltas.append(server.ingest(sid, ts[pos: pos + n]))
        pos += n
    return server.close(sid), deltas


def concat_delta(deltas, closing):
    labels = np.concatenate(
        [d["labels"] for d in deltas] + [closing["delta"]["labels"]])
    endpoints = np.concatenate(
        [d["endpoints"] for d in deltas] + [closing["delta"]["endpoints"]])
    return labels, endpoints


def wire_endpoints_ref(ts):
    """Ground-truth transmitted endpoints straight from the sender."""
    ev = compress_stream(jnp.asarray(ts), tol=CFG.tol, len_max=CFG.len_max,
                         alpha=CFG.alpha)
    eps = list(np.asarray(ev["endpoint"])[np.asarray(ev["emit"])])
    if bool(ev["tail"].emit):
        eps.append(float(ev["tail"].endpoint))
    return np.asarray(eps, np.float32)


def assert_session_matches_encode(res, deltas, ts, key, context=""):
    whole = symed_encode(jnp.asarray(ts), CFG, key, reconstruct=False)
    n = int(whole["n_pieces"])
    labels, endpoints = concat_delta(deltas, res)
    np.testing.assert_array_equal(
        labels, np.asarray(whole["symbols_online"])[:n],
        err_msg=f"{context}: delta labels")
    np.testing.assert_array_equal(
        endpoints, wire_endpoints_ref(ts),
        err_msg=f"{context}: delta endpoints")
    for name in whole:
        np.testing.assert_array_equal(
            np.asarray(res["out"][name]), np.asarray(whole[name]),
            err_msg=f"{context}: {name}")


class TestDeltaEquivalence:
    @given(st.sampled_from(T_LENS), st.integers(1, 3), st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_concat_bitwise_equals_encode(self, t_len, cadence, seed):
        """Random lengths x ragged splits x cadences: concatenated deltas and
        the closing output are bitwise-equal to one-shot symed_encode."""
        rng = np.random.default_rng(3000 + 31 * t_len + 7 * cadence + seed)
        ts = make_stream(rng, t_len)
        key = jax.random.key(seed)
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=cadence)
        res, deltas = feed_session(server, "s", ts, key, rng)
        assert_session_matches_encode(
            res, deltas, ts, key, f"T={t_len} k={cadence} seed={seed}")

    @given(st.integers(0, 3))
    @settings(max_examples=8, deadline=None)
    def test_interleaved_sessions_bitwise(self, seed):
        """Concurrent sessions advancing through one slot table in random
        interleavings, closed in random order: each stream's deltas equal
        its own single-stream reference."""
        rng = np.random.default_rng(4000 + seed)
        n_sess = 3
        streams = [make_stream(rng, 128) for _ in range(n_sess)]
        keys = [jax.random.key(100 + seed * 10 + i) for i in range(n_sess)]
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=1)
        deltas = {i: [] for i in range(n_sess)}
        cursors = [0] * n_sess
        for i in range(n_sess):
            server.open(f"s{i}", key=keys[i])
        while any(c < 128 for c in cursors):
            live = [i for i in range(n_sess) if cursors[i] < 128]
            pick = [i for i in live if rng.random() < 0.7] or live[:1]
            batch = {}
            for i in pick:
                n = int(rng.integers(1, 40))
                batch[f"s{i}"] = streams[i][cursors[i]: cursors[i] + n]
                cursors[i] = min(cursors[i] + n, 128)
            for sid, d in server.ingest_many(batch).items():
                deltas[int(sid[1:])].append(d)
        for i in rng.permutation(n_sess):
            res = server.close(f"s{i}")
            assert_session_matches_encode(
                res, deltas[i], streams[i], keys[i],
                f"seed={seed} session={i}")

    def test_slot_reuse_after_close(self, rng):
        """Open/close orderings that recycle slots: a slot freed mid-run and
        reopened by a new stream must not leak state across sessions."""
        server = StreamServer(CFG, max_sessions=2, window_cap=WINDOW_CAP,
                              digitize_every_k=2)
        results = {}
        for round_ in range(3):  # 6 sessions through 2 slots
            for j in range(2):
                sid = f"r{round_}j{j}"
                ts = make_stream(rng, 96)
                key = jax.random.key(7 * round_ + j)
                res, deltas = feed_session(server, sid, ts, key, rng)
                results[sid] = (res, deltas, ts, key)
        assert server.active_sessions == 0
        for sid, (res, deltas, ts, key) in results.items():
            assert_session_matches_encode(res, deltas, ts, key, sid)

    def test_eviction_equals_prefix_encode(self, rng):
        """LRU eviction closes the victim early: its parked output must be
        bitwise-equal to symed_encode over the points it actually got."""
        server = StreamServer(CFG, max_sessions=2, window_cap=WINDOW_CAP,
                              digitize_every_k=1, evict_idle=True)
        streams = {f"s{i}": make_stream(rng, 96) for i in range(3)}
        keys = {f"s{i}": jax.random.key(50 + i) for i in range(3)}
        deltas = {sid: [] for sid in streams}
        server.open("s0", key=keys["s0"])
        server.open("s1", key=keys["s1"])
        deltas["s0"].append(server.ingest("s0", streams["s0"][:40]))
        deltas["s1"].append(server.ingest("s1", streams["s1"][:96]))
        server.open("s2", key=keys["s2"])  # table full -> evicts s0 (LRU)
        assert "s0" in server.evicted and "s0" not in server
        assert server.totals["evicted"] == 1
        res0 = server.evicted["s0"]
        assert res0["t_seen"] == 40
        assert_session_matches_encode(
            res0, deltas["s0"], streams["s0"][:40], keys["s0"], "evicted s0")
        deltas["s2"].append(server.ingest("s2", streams["s2"]))
        for sid in ("s1", "s2"):
            res = server.close(sid)
            assert_session_matches_encode(
                res, deltas[sid], streams[sid], keys[sid], sid)

    def test_defer_cadence_closing_frame_carries_all(self, rng):
        """digitize_every_k=0: no mid-stream frames; the closing frame holds
        the entire symbol stream and still matches the reference."""
        ts = make_stream(rng, 128)
        key = jax.random.key(11)
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=0)
        res, deltas = feed_session(server, "s", ts, key, rng)
        assert all(d["frames"] == 0 and d["n_new"] == 0 for d in deltas)
        assert res["delta"]["frames"] == 1
        assert res["delta"]["n_new"] == res["n_pieces"]
        assert_session_matches_encode(res, deltas, ts, key, "defer")

    def test_wire_accounting_consistent(self, rng):
        """bytes_out decomposes exactly into 4B frame headers + 5B symbols."""
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=1)
        ts = make_stream(rng, 160)
        res, _ = feed_session(server, "s", ts, jax.random.key(0), rng)
        t = server.totals
        assert t["symbols_out"] == res["n_pieces"]
        assert t["bytes_out"] == 4.0 * t["frames_out"] + 5.0 * t["symbols_out"]
        assert t["points_in"] == 160
        assert t["bytes_in"] == 4.0 * 160 + 4.0  # points + the t0 hello
        rep = server.report(1.0)
        assert rep["ms_per_symbol"] > 0 and np.isfinite(rep["wire_out_ratio"])

    def test_dtw_monitor_scores_reconstruction(self, rng):
        """The online monitor reproduces DTW(raw-so-far, piece recon)."""
        from repro.core.receiver import pieces_from_wire
        from repro.core.reconstruct import reconstruct_from_pieces
        from repro.kernels import ops
        from repro.launch.stream import _read_slot

        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=1, dtw_every=2)
        ts = make_stream(rng, 128)
        server.open("s", key=jax.random.key(1))
        for c in range(0, 128, WINDOW_CAP):
            server.ingest("s", ts[c: c + WINDOW_CAP])
        stats = server.session_stats("s")
        assert stats["dtw"] is not None and np.isfinite(stats["dtw"])
        sub = _read_slot(server._table, jnp.asarray(stats["slot"], jnp.int32))
        lens, incs = pieces_from_wire(
            sub.endpoints, sub.steps, sub.n_pieces, sub.t0)
        rec = reconstruct_from_pieces(lens, incs, sub.n_pieces, sub.t0, 128)
        want = float(ops.dtw(ts[None], np.asarray(rec)[None],
                             force_ref=True)[0])
        assert stats["dtw"] == pytest.approx(want, rel=1e-6)
        server.close("s")

    def test_error_paths(self):
        server = StreamServer(CFG, max_sessions=1, window_cap=8)
        server.open("a")
        with pytest.raises(ValueError, match="already open"):
            server.open("a")
        with pytest.raises(RuntimeError, match="table full"):
            server.open("b")
        with pytest.raises(KeyError, match="unknown session"):
            server.ingest("nope", np.zeros(4))
        with pytest.raises(KeyError, match="unknown session"):
            server.close("nope")
        with pytest.raises(ValueError, match="max_sessions"):
            StreamServer(CFG, max_sessions=0)
        with pytest.raises(ValueError, match="digitize_every_k"):
            StreamServer(CFG, digitize_every_k=-1)

    @given(st.integers(0, 3))
    @settings(max_examples=6, deadline=None)
    def test_autoscale_bitwise_across_resizes(self, seed):
        """Sessions churning through an autoscaled table: grows on open
        pressure, shrinks on drain-down, and every session's delta stream
        stays bitwise-equal to its one-shot reference across each resize
        point (resize is a pure concat/gather of slot states)."""
        rng = np.random.default_rng(6000 + seed)
        server = StreamServer(CFG, max_sessions=8, window_cap=WINDOW_CAP,
                              digitize_every_k=1 + seed % 3,
                              autoscale=True, min_slots=1)
        assert server.capacity == 1
        n_sess = 6
        streams = [make_stream(rng, 96) for _ in range(n_sess)]
        keys = [jax.random.key(700 + seed * 10 + i) for i in range(n_sess)]
        deltas = {i: [] for i in range(n_sess)}
        results = {}
        for i in range(n_sess):
            server.open(f"s{i}", key=keys[i])
        assert server.capacity == 8 and server.totals["grows"] == 3
        cursors = [0] * n_sess
        while any(c < 96 for c in cursors):
            live = [i for i in range(n_sess)
                    if cursors[i] < 96 and f"s{i}" in server]
            batch = {}
            for i in live:
                n = int(rng.integers(8, 40))
                batch[f"s{i}"] = streams[i][cursors[i]: cursors[i] + n]
                cursors[i] = min(cursors[i] + n, 96)
            for sid, d in server.ingest_many(batch).items():
                deltas[int(sid[1:])].append(d)
            # drain finished sessions as they complete -> shrink mid-run
            for i in list(live):
                if cursors[i] >= 96:
                    results[i] = server.close(f"s{i}")
        assert server.totals["shrinks"] >= 1, server.totals
        assert server.capacity == server.min_slots == 1
        for i in range(n_sess):
            assert_session_matches_encode(
                results[i], deltas[i], streams[i], keys[i],
                f"autoscale seed={seed} session={i}")

    def test_autoscale_eviction_only_at_max(self, rng):
        """While the ladder has headroom, open pressure grows the table;
        eviction fires only once capacity == max_sessions."""
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              autoscale=True, min_slots=1, evict_idle=True)
        for i in range(4):
            server.open(f"s{i}")
            server.ingest(f"s{i}", make_stream(rng, 16))
        assert server.totals == {**server.totals, "grows": 2, "evicted": 0}
        assert server.capacity == 4
        server.open("s4")  # at max: LRU eviction, no further grow
        assert server.totals["evicted"] == 1 and server.totals["grows"] == 2
        assert "s0" in server.evicted

    def test_autoscale_validation(self):
        with pytest.raises(ValueError, match="min_slots"):
            StreamServer(CFG, max_sessions=4, min_slots=8)
        with pytest.raises(ValueError, match="min_slots"):
            StreamServer(CFG, max_sessions=4, min_slots=0)

    def test_pieces_ingest_matches_raw_ingest(self, rng):
        """``ingest_pieces_many`` fed the sender's own piece tuples yields
        the identical receiver state / outputs as raw-window ingest."""
        from repro.core.compress import compressor_finalize, pieces_on_wire
        from repro.core.symed import symed_encode_chunk

        ts = make_stream(rng, 128)
        key = jax.random.key(21)
        raw_srv = StreamServer(CFG, max_sessions=2, window_cap=WINDOW_CAP,
                               digitize_every_k=1)
        res_raw, deltas_raw = feed_session(raw_srv, "s", ts, key, rng)

        pcs_srv = StreamServer(CFG, max_sessions=2, window_cap=WINDOW_CAP,
                               digitize_every_k=1)
        pcs_srv.open("s", key=key)
        deltas, state, off = [], None, 0
        for c in range(0, 128, 32):
            w = ts[c: c + 32]
            state, ev = symed_encode_chunk(jnp.asarray(w), CFG, state)
            eps, steps = pieces_on_wire(ev, off)
            off += len(w)
            deltas.append(pcs_srv.ingest_pieces_many({"s": {
                "endpoints": eps, "steps": steps, "t_seen": off,
                "t0": float(ts[0])}})["s"])
        tail = compressor_finalize(state)
        if bool(tail.emit):
            deltas.append(pcs_srv.ingest_pieces_many({"s": {
                "endpoints": [float(tail.endpoint)], "steps": [off],
                "t_seen": off, "t0": float(ts[0])}})["s"])
        res_pcs = pcs_srv.close("s")
        assert_session_matches_encode(res_pcs, deltas, ts, key, "pieces-in")
        for name in res_raw["out"]:
            if name == "symbol_delta":
                continue  # closing-frame split differs (tail digitized at
                          # tail-ingest vs at close); the concat is checked
            np.testing.assert_array_equal(
                np.asarray(res_pcs["out"][name]),
                np.asarray(res_raw["out"][name]), err_msg=name)

    def test_close_never_fed_session(self):
        """A session closed before any points arrived yields an empty result
        (no nan telemetry from the 0/0 compression ratio)."""
        server = StreamServer(CFG, max_sessions=2, window_cap=8)
        server.open("a")
        res = server.close("a")
        assert res["n_pieces"] == 0 and res["t_seen"] == 0
        assert res["out"] is None and res["symbols"] == ""
        assert res["delta"]["n_new"] == 0
        server.open("b")  # slot is reusable
        assert server.active_sessions == 1


class TestResidentHotPath:
    """The retrace-free serving-loop contracts: shrink hysteresis, ladder
    pre-tracing, and the wire-out accounting definition."""

    def _oscillate(self, patience, seed, cycles=3):
        """Open/close session pairs across the quarter-occupancy boundary;
        return (per-session (result, deltas, ts, key), totals)."""
        rng = np.random.default_rng(9000 + seed)
        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=1, autoscale=True,
                              min_slots=1, shrink_patience=patience)
        sessions = {}
        for cycle in range(cycles):
            pair = [f"c{cycle}a", f"c{cycle}b"]
            data = {}
            for j, sid in enumerate(pair):
                ts = make_stream(rng, 64)
                key = jax.random.key(300 + 10 * cycle + j)
                server.open(sid, key=key)  # second open forces a grow
                data[sid] = (ts, key)
            deltas = {sid: [] for sid in pair}
            cursors = {sid: 0 for sid in pair}
            while any(c < 64 for c in cursors.values()):
                batch = {}
                for sid in pair:
                    if cursors[sid] < 64:
                        n = int(rng.integers(8, 40))
                        batch[sid] = data[sid][0][
                            cursors[sid]: cursors[sid] + n]
                        cursors[sid] = min(cursors[sid] + n, 64)
                for sid, d in server.ingest_many(batch).items():
                    deltas[sid].append(d)
            for sid in pair:  # drain: the second close crosses the boundary
                res = server.close(sid)
                sessions[sid] = (res, deltas[sid], *data[sid])
        return sessions, dict(server.totals)

    @given(st.integers(0, 2))
    @settings(max_examples=3, deadline=None)
    def test_shrink_hysteresis_stops_thrash_bitwise(self, seed):
        """A session count oscillating across the shrink boundary re-gathers
        the table every cycle at patience=1 but not at patience=3 -- and the
        patience setting never changes a single emitted byte (the walk-down
        is a pure permutation, so *when* it fires is unobservable in the
        delta stream)."""
        eager, t1 = self._oscillate(1, seed)
        patient, t3 = self._oscillate(3, seed)
        assert t1["shrinks"] >= 2, t1
        assert t3["shrinks"] == 0, t3
        assert t3["grows"] < t1["grows"], (t1, t3)
        assert set(eager) == set(patient)
        for sid, (res, deltas, ts, key) in eager.items():
            assert_session_matches_encode(
                res, deltas, ts, key, f"patience=1 {sid}")
        for sid, (res, deltas, ts, key) in patient.items():
            assert_session_matches_encode(
                res, deltas, ts, key, f"patience=3 {sid}")
            labels_e, eps_e = concat_delta(eager[sid][1], eager[sid][0])
            labels_p, eps_p = concat_delta(deltas, res)
            np.testing.assert_array_equal(labels_e, labels_p)
            np.testing.assert_array_equal(eps_e, eps_p)

    def test_pretrace_cache_flat_across_grow_shrink_grow(self, rng):
        """With the ladder pre-traced at init, a grow/shrink/grow cycle
        never compiles: the jit cache entry count stays flat through every
        capacity the server serves at."""
        from repro.launch.stream import _table_step

        server = StreamServer(CFG, max_sessions=4, window_cap=WINDOW_CAP,
                              digitize_every_k=1, autoscale=True,
                              min_slots=1, shrink_patience=1, pretrace=True)
        base = _table_step._cache_size()
        for cycle in range(2):  # grow 1->2->4, drain back to 1, again
            for i in range(3):
                sid = f"g{cycle}s{i}"
                server.open(sid, key=jax.random.key(40 + i))
                server.ingest(sid, make_stream(rng, WINDOW_CAP))
            for i in range(3):
                server.close(f"g{cycle}s{i}")
        assert server.totals["grows"] >= 3, server.totals
        assert server.totals["shrinks"] >= 3, server.totals
        assert _table_step._cache_size() == base

    def test_wire_out_ratio_below_one(self, rng):
        """Regression: ``wire_out_ratio`` divided outbound delta frames by
        the (already compressed) inbound bytes, reading > 1.0 on the pieces
        transport.  Against raw bytes it must sit below 1 for any window at
        or past the header-amortization bound (4 B header / 4 B-per-point =
        1 point per frame)."""
        from repro.core.symed import symed_encode_chunk
        from repro.core.compress import pieces_on_wire

        ts = make_stream(rng, 160)
        key = jax.random.key(77)
        for win in (8, 16, WINDOW_CAP):  # every window >= the bound
            server = StreamServer(CFG, max_sessions=2, window_cap=win,
                                  digitize_every_k=1)
            server.open("s", key=key)
            for c in range(0, 160, win):
                server.ingest("s", ts[c: c + win])
            server.close("s")
            rep = server.report(1.0)
            assert 0.0 < rep["wire_out_ratio"] < 1.0, (win, rep)
            assert rep["raw_bytes"] == 4.0 * 160

        # the transport shape that exposed the bug: compressed-in arrivals
        pcs = StreamServer(CFG, max_sessions=2, window_cap=WINDOW_CAP,
                           digitize_every_k=1)
        pcs.open("s", key=key)
        state, off = None, 0
        for c in range(0, 160, WINDOW_CAP):
            w = ts[c: c + WINDOW_CAP]
            state, ev = symed_encode_chunk(jnp.asarray(w), CFG, state)
            eps, steps = pieces_on_wire(ev, off)
            off += len(w)
            pcs.ingest_pieces_many({"s": {
                "endpoints": eps, "steps": steps, "t_seen": off,
                "t0": float(ts[0])}})
        pcs.close("s")
        rep = pcs.report(1.0)
        assert rep["wire_in_ratio"] < 1.0, rep
        assert 0.0 < rep["wire_out_ratio"] < 1.0, rep
