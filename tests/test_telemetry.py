"""SymED telemetry (numpy sender mirror) + straggler watchdog."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import compress_stream
from repro.train.telemetry import NumpySender, StepWatchdog, TelemetryHub


class TestNumpySender:
    def test_matches_jax_sender(self):
        """The host-side scalar mirror must emit at the same steps as the
        vectorized jax sender (same Alg. 1 semantics)."""
        rng = np.random.default_rng(0)
        ts = np.cumsum(rng.normal(0, 0.3, 300)).astype(np.float32)

        sender = NumpySender(tol=0.4, alpha=0.02, len_max=64)
        for t in ts:
            sender.push(t)
        np_steps = [s for s, _ in sender.wire][1:]  # skip the t0 hello

        ev = compress_stream(jnp.asarray(ts), tol=0.4, len_max=64, alpha=0.02)
        jax_steps = np.nonzero(np.asarray(ev["emit"]))[0].tolist()
        assert np_steps == jax_steps

    def test_compression_accounting(self):
        s = NumpySender(tol=0.5, alpha=0.05)
        for t in np.sin(np.linspace(0, 10, 500)):
            s.push(float(t))
        assert s.raw_bytes == 2000
        assert 0 < s.wire_bytes < s.raw_bytes
        assert s.compression_rate() < 0.5


class TestHub:
    def test_traffic_report_and_digitize(self):
        hub = TelemetryHub(tol=0.4, alpha=0.05)
        rng = np.random.default_rng(1)
        for i in range(300):
            hub.record("h0/loss", 3 * np.exp(-i / 80) + rng.normal(0, 0.02))
        rep = hub.traffic_report()
        assert rep["h0/loss"]["cr"] < 1.0
        dig = hub.digitize("h0/loss", k_max=8)
        assert dig is not None and int(dig["k"]) >= 1


class TestWatchdog:
    def test_flags_straggler_and_hang(self):
        dog = StepWatchdog(alpha=0.1, z_threshold=4.0, warmup=3)
        rng = np.random.default_rng(2)
        events = []
        for i in range(100):
            dt = 1.0 + rng.normal(0, 0.02)
            if i == 50:
                dt = 2.5      # straggler
            if i == 80:
                dt = 30.0     # hang
            ev = dog.observe(i, dt)
            if ev:
                events.append(ev)
        kinds = {e["step"]: e["kind"] for e in events}
        assert kinds.get(50) == "straggler"
        assert kinds.get(80) == "hang"
        # no false positives elsewhere
        assert set(kinds) == {50, 80}

    def test_quiet_on_steady_steps(self):
        dog = StepWatchdog(warmup=3)
        for i in range(50):
            assert dog.observe(i, 1.0) is None
