"""Per-arch smoke tests (reduced configs, CPU) + decode/prefill consistency.

Every assigned architecture: one forward/train step asserting output shapes
and finiteness, plus the teacher-forcing contract: logits from (prefill(n) +
k decode steps) must match prefill(n + k) -- this exercises KV caches, ring
buffers, RoPE phases, SSM/xLSTM recurrent states and cross-attention caches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, shapes_for
from repro.models import (
    count_params, decode_step, init_params, loss_fn, prefill,
)

ALL_ARCHS = sorted(ARCHS)


def _nodrop(cfg):
    """MoE capacity drops differ between prefill and decode batch shapes by
    construction; consistency tests pin no-drop capacity."""
    if cfg.n_experts:
        return dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    return cfg


def _extras(cfg, b, key=2, as_batch=False):
    kw = {}
    if cfg.frontend == "patches":
        kw["prefix_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(key), (b, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.frontend == "frames":
        kw["enc_frames"] = 0.1 * jax.random.normal(
            jax.random.key(key), (b, cfg.num_prefix_embeds, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_loss(self, arch):
        cfg = ARCHS[arch].reduced()
        params = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
        batch = {"tokens": toks, **_extras(cfg, 2)}
        loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
        assert np.isfinite(float(loss))
        # xent near ln(vocab) at init
        assert abs(float(metrics["xent"]) - np.log(cfg.vocab)) < 1.0

    def test_train_step_no_nans(self, arch):
        from repro.train.optimizer import OptConfig
        from repro.train.steps import init_train_state, make_train_step

        cfg = ARCHS[arch].reduced()
        oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        state = init_train_state(jax.random.key(0), cfg, oc)
        step = jax.jit(make_train_step(cfg, oc))
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
        batch = {"tokens": toks, **_extras(cfg, 2)}
        state, m = step(state, batch)
        state, m2 = step(state, batch)
        assert np.isfinite(float(m2["loss"]))
        assert int(state["step"]) == 2
        for leaf in jax.tree.leaves(state["params"]):
            assert np.isfinite(np.asarray(leaf)).all()

    def test_decode_matches_prefill(self, arch):
        cfg = _nodrop(ARCHS[arch].reduced())
        params = init_params(jax.random.key(0), cfg)
        n0, steps = 40, 4  # past the reduced window=32: exercises ring caches
        T = n0 + steps
        toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab)
        kw = _extras(cfg, 2)
        gt, _ = prefill(params, cfg, toks, max_len=T + 8, **kw)
        logits, state = prefill(params, cfg, toks[:, :n0], max_len=T + 8, **kw)
        for i in range(n0, T):
            logits, state = decode_step(params, cfg, state, toks[:, i: i + 1])
        err = float(jnp.max(jnp.abs(gt - logits)))
        scale = max(float(jnp.max(jnp.abs(gt))), 1.0)
        assert err < 2e-2 * scale, f"decode diverges from prefill: {err}"

    def test_param_count_positive(self, arch):
        cfg = ARCHS[arch]
        n = count_params(cfg)
        na = count_params(cfg, active_only=True)
        assert n > 0 and 0 < na <= n
        if cfg.n_experts:
            assert na < n  # MoE: active subset strictly smaller


class TestFullConfigs:
    """Exact public numbers spot-checks (full configs, shapes only)."""

    def test_layer_counts(self):
        expect = {
            "paligemma-3b": 18, "jamba-1.5-large-398b": 72, "whisper-small": 12,
            "gemma3-27b": 62, "codeqwen1.5-7b": 32, "nemotron-4-15b": 32,
            "command-r-35b": 40, "mixtral-8x7b": 32, "olmoe-1b-7b": 16,
            "xlstm-125m": 12,
        }
        for name, layers in expect.items():
            assert ARCHS[name].n_layers == layers, name

    def test_param_counts_plausible(self):
        # analytic totals should be within ~25% of the advertised sizes
        expect = {
            "jamba-1.5-large-398b": 398e9, "gemma3-27b": 27e9,
            "codeqwen1.5-7b": 7e9, "nemotron-4-15b": 15e9,
            "command-r-35b": 35e9, "mixtral-8x7b": 47e9,  # 8x7b total ~46.7B
            "olmoe-1b-7b": 7e9,
        }
        for name, n in expect.items():
            got = count_params(ARCHS[name])
            assert abs(got - n) / n < 0.30, (name, got, n)

    def test_active_params(self):
        # mixtral ~12.9B active of 46.7B
        a = count_params(ARCHS["mixtral-8x7b"], active_only=True)
        assert 10e9 < a < 16e9

    def test_long_ctx_assignment(self):
        runs_long = {a for a, c in ARCHS.items() if c.supports_long_ctx}
        assert runs_long == {
            "jamba-1.5-large-398b", "xlstm-125m", "mixtral-8x7b", "gemma3-27b",
        }
        for a, cfg in ARCHS.items():
            shapes = shapes_for(cfg)
            assert ("long_500k" in shapes) == (a in runs_long)

    def test_vocab_indivisible_fallback(self):
        """whisper's 51865 vocab must fall back to replication, not crash."""
        from jax.sharding import PartitionSpec as P

        from repro.sharding.partition import logical_to_spec

        class FakeMesh:  # rule resolution only touches names + shape
            axis_names = ("data", "model")

            class devices:  # noqa: N801
                shape = (16, 16)

        spec = logical_to_spec(("vocab", "fsdp"), (51865, 768), FakeMesh())
        assert spec[0] is None          # 51865 % 16 != 0 -> replicated
        assert spec == P(None, "data")  # d_model still FSDP-sharded

    def test_divisibility_fallback_chain(self):
        from jax.sharding import PartitionSpec as P

        from repro.sharding.partition import logical_to_spec

        class FakeMesh:
            axis_names = ("data", "model")

            class devices:  # noqa: N801
                shape = (16, 16)

        # paligemma: 8 q-heads fused with hd=256 -> fused dim divisible
        assert logical_to_spec(("fsdp", "qkv_fused"), (2048, 2048), FakeMesh()) \
            == P("data", "model")
        # mixtral: 8 experts indivisible -> moe_d picks up model on d
        assert logical_to_spec(("experts", "moe_d", "fsdp"),
                               (8, 4096, 28672), FakeMesh()) == P(None, "model", "data")
        # batch folds (pod, data) when pod exists, data alone otherwise
        class PodMesh:
            axis_names = ("pod", "data", "model")

            class devices:  # noqa: N801
                shape = (2, 16, 16)

        assert logical_to_spec(("batch", None), (256, 4096), PodMesh()) \
            == P(("pod", "data"), None)
