"""symlint (``repro.analysis``): rule fixtures, baseline/suppression
mechanics, the SL005 mutation battery, and the repo-wide smoke gate.

Every fixture project is built in ``tmp_path`` and analyzed through the real
engine (``load_project`` + ``analyze``), so the tests exercise the same
suppression/baseline partitioning the CLI uses.  The mutation test copies
the *actual* transport/receiver codec files, flips one byte of one struct
format string, and asserts SL005 catches the one-sided edit -- that is the
property the rule exists for.
"""
from pathlib import Path

import pytest

from repro.analysis.cli import find_root, main
from repro.analysis.engine import Baseline, analyze, load_project

REPO_ROOT = find_root(Path(__file__).resolve().parent)


def run(tmp_path, sources, rules, baseline=None):
    """Write ``{relpath: source}`` under tmp_path and analyze it."""
    for rel, text in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    project = load_project(tmp_path, [tmp_path])
    return analyze(project, rules, baseline)


def rules_of(result):
    return [f.rule for f in result.findings]


# --------------------------------------------------------------- SL001 compat


SL001_POS = """\
import jax
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map

def kernel(block):
    return pltpu.TPUMemorySpace.ANY

def grid(params):
    return params(dimension_semantics=("parallel",))

def mesh():
    return jax.make_mesh((1,), ("data",))
"""

SL001_NEG = """\
from repro.utils.jax_compat import MemorySpace, VMEM, tpu_compiler_params

def kernel(block):
    return MemorySpace.ANY, VMEM((8,), float)
"""


class TestSL001:
    def test_positive(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        msgs = [f.message for f in result.findings]
        assert len(result.findings) == 4
        assert any("jax.experimental.shard_map" in m for m in msgs)
        assert any("pltpu.TPUMemorySpace" in m for m in msgs)
        assert any("dimension_semantics" in m for m in msgs)
        assert any("jax.make_mesh" in m for m in msgs)

    def test_negative(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_NEG}, ["SL001"])
        assert result.findings == []

    def test_suppressed(self, tmp_path):
        src = SL001_POS.replace(
            "return pltpu.TPUMemorySpace.ANY",
            "return pltpu.TPUMemorySpace.ANY  # symlint: disable=SL001")
        result = run(tmp_path, {"mod.py": src}, ["SL001"])
        assert len(result.findings) == 3
        assert len(result.suppressed) == 1

    def test_baselined(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        bpath = tmp_path / "baseline.json"
        Baseline.write(bpath, result.findings, {})
        baseline = Baseline(bpath)
        again = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"], baseline)
        assert again.findings == []
        assert len(again.baselined) == 4
        assert again.exit_code == 0

    def test_compat_module_itself_exempt(self, tmp_path):
        result = run(
            tmp_path, {"utils/jax_compat.py": SL001_POS}, ["SL001"])
        assert result.findings == []

    def test_docstring_table_drives_banned_list(self, tmp_path):
        # a fixture jax_compat whose table bans a made-up name
        compat = (
            '"""Shims.\n\n'
            "====  ====\n"
            "a     b\n"
            "====  ====\n"
            "x     ``pltpu.MadeUpName``\n"
            "====  ====\n"
            '"""\n'
        )
        user = (
            "from jax.experimental.pallas import tpu as pltpu\n"
            "def f():\n"
            "    return pltpu.MadeUpName\n"
        )
        result = run(tmp_path, {"utils/jax_compat.py": compat,
                                "mod.py": user}, ["SL001"])
        assert [f.rule for f in result.findings] == ["SL001"]
        assert "MadeUpName" in result.findings[0].message


# -------------------------------------------------------------- SL002 retrace


SL002_BRANCH = """\
import jax

@jax.jit
def f(x, y):
    if x > 0:
        return y
    return -y
"""

SL002_STATIC_OK = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("first",))
def f(x, *, first):
    if first:
        return x * 2
    return x
"""

SL002_CONCRETIZE = """\
import jax

@jax.jit
def f(x):
    return float(x) + 1.0
"""

SL002_CLOSURE = """\
import jax

def outer(scale):
    @jax.jit
    def inner(x):
        return x * scale
    return inner
"""

SL002_LOOP_STATIC = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("k",))
def f(x, *, k):
    return x * k

def driver(x):
    out = []
    for i in range(8):
        out.append(f(x, k=i))
    return out
"""


class TestSL002:
    def test_branch_on_traced(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_BRANCH}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "`if` statement" in result.findings[0].message

    def test_static_branch_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_STATIC_OK}, ["SL002"])
        assert result.findings == []

    def test_none_check_ok(self, tmp_path):
        src = SL002_BRANCH.replace("if x > 0:", "if y is None:")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []

    def test_concretize_traced(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_CONCRETIZE}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "float()" in result.findings[0].message

    def test_closure_capture(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_CLOSURE}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "`scale`" in result.findings[0].message

    def test_loop_varying_static(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_LOOP_STATIC}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "loop-varying" in result.findings[0].message

    def test_suppressed(self, tmp_path):
        src = SL002_BRANCH.replace(
            "if x > 0:", "if x > 0:  # symlint: disable=SL002")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ------------------------------------------------------------- SL003 donation


SL003_REUSE = """\
import jax

@jax.jit
def step(state, x):
    return state + x

step = jax.jit(step, donate_argnums=(0,))

def driver(state, x):
    out = step(state, x)
    return state + out
"""

SL003_REBOUND = """\
import jax

def _step(state, x):
    return state + x

step = jax.jit(_step, donate_argnums=(0,))

def driver(state, xs):
    for x in xs:
        state = step(state, x)
    return state
"""

SL003_LOOP_NO_REBIND = """\
import jax

def _step(state, x):
    return state + x

step = jax.jit(_step, donate_argnums=(0,))

def driver(state, xs):
    out = []
    for x in xs:
        out.append(step(state, x))
    return out
"""


class TestSL003:
    def test_read_after_donate(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_REUSE}, ["SL003"])
        assert "SL003" in rules_of(result)
        assert "`state`" in result.findings[0].message

    def test_rebound_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_REBOUND}, ["SL003"])
        assert result.findings == []

    def test_loop_without_rebind(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_LOOP_NO_REBIND}, ["SL003"])
        assert "SL003" in rules_of(result)
        assert "loop" in result.findings[0].message

    def test_suppressed(self, tmp_path):
        src = SL003_REUSE.replace(
            "return state + out",
            "return state + out  # symlint: disable=SL003")
        result = run(tmp_path, {"mod.py": src}, ["SL003"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ------------------------------------------------------------- SL004 hostsync


SL004_SYNC = """\
import numpy as np
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.cumsum(x)
    return np.asarray(y)
"""

SL004_ANNOTATED = """\
import numpy as np
import jax
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.cumsum(x)
    return jax.device_get(y)  # sync: ok
"""

SL004_BRANCH = """\
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.any(x > 0)
    if y:
        return 1
    return 0
"""


class TestSL004:
    def test_sync_in_hot_path(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_SYNC}, ["SL004"])
        assert rules_of(result) == ["SL004"]
        assert "np.asarray()" in result.findings[0].message

    def test_annotated_sync_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_ANNOTATED}, ["SL004"])
        assert result.findings == []

    def test_branch_on_device_value(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_BRANCH}, ["SL004"])
        assert rules_of(result) == ["SL004"]
        assert "blocks on the device" in result.findings[0].message

    def test_unmarked_function_ignored(self, tmp_path):
        src = SL004_SYNC.replace("def hot(x):  # symlint: hot-path",
                                 "def cold(x):")
        result = run(tmp_path, {"mod.py": src}, ["SL004"])
        assert result.findings == []

    def test_suppressed(self, tmp_path):
        src = SL004_SYNC.replace(
            "return np.asarray(y)",
            "return np.asarray(y)  # symlint: disable=SL004")
        result = run(tmp_path, {"mod.py": src}, ["SL004"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ----------------------------------------------------------------- SL005 wire


CODEC_FILES = ("src/repro/launch/transport.py", "src/repro/core/receiver.py")


def codec_sources():
    return {rel: (REPO_ROOT / rel).read_text() for rel in CODEC_FILES}


class TestSL005:
    def test_real_codecs_consistent(self, tmp_path):
        result = run(tmp_path, codec_sources(), ["SL005"])
        assert result.findings == []

    @pytest.mark.parametrize("before,after", [
        ('"!IIB"', '"!IBB"'),     # encode/decode_closed header
        ('"!fII"', '"!fIH"'),     # pieces DATA header
        ('("endpoint", ">f4")', '("endpoint", ">f8")'),  # piece record
    ])
    def test_mutation_caught(self, tmp_path, before, after):
        sources = codec_sources()
        mutated = False
        for rel in list(sources):
            if before in sources[rel]:
                # flip the *first* occurrence: a one-sided edit
                sources[rel] = sources[rel].replace(before, after, 1)
                mutated = True
                break
        assert mutated, f"pattern {before!r} not found in codec files"
        result = run(tmp_path, sources, ["SL005"])
        assert any(f.rule == "SL005" for f in result.findings), (
            f"one-sided {before} -> {after} edit not caught")

    def test_unpaired_codec_flagged(self, tmp_path):
        src = (
            "import struct\n"
            "def encode_open(sid, mode, seed):\n"
            "    return struct.pack('!BI', mode, seed)\n"
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("decode_open" in f.message for f in result.findings)

    def test_offset_mismatch(self, tmp_path):
        src = (
            "import struct\n"
            "def encode_close(t, flag):\n"
            "    return struct.pack('!IB', t, flag) + struct.pack('!f', 0.5)\n"
            "def decode_close(buf):\n"
            "    t, flag = struct.unpack_from('!IB', buf)\n"
            "    tail = struct.unpack_from('!f', buf, 6)[0]\n"
            "    return t, flag, tail\n"
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("offset 6" in f.message for f in result.findings)

    def test_constant_contract(self, tmp_path):
        src = (
            "import numpy as np\n"
            "DELTA_SYMBOL_BYTES = 6.0\n"
            '_DELTA_REC = np.dtype([("label", "u1"), ("endpoint", ">f4")])\n'
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("DELTA_SYMBOL_BYTES" in f.message
                   for f in result.findings)


# ------------------------------------------------------- engine + repo gates


class TestEngine:
    def test_stale_baseline_entry_fails(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        bpath = tmp_path / "baseline.json"
        Baseline.write(bpath, result.findings, {})
        clean = run(tmp_path, {"clean.py": SL001_NEG}, ["SL001"],
                    Baseline(bpath))
        # the fixture with the violations is still in the sweep, so entries
        # are live; now analyze a sweep where they no longer match
        project = load_project(tmp_path / "sub", [])
        from repro.analysis.engine import analyze as analyze_fn
        result2 = analyze_fn(project, ["SL001"], Baseline(bpath))
        assert result2.stale_baseline
        assert result2.exit_code == 1
        assert clean.exit_code == 0  # live entries are not stale

    def test_parse_error_reported_not_raised(self, tmp_path):
        result = run(tmp_path, {"bad.py": "def broken(:\n"}, ["SL001"])
        assert result.parse_errors
        assert result.exit_code == 1

    def test_bare_disable_suppresses_all_rules(self, tmp_path):
        src = SL002_BRANCH.replace(
            "if x > 0:", "if x > 0:  # symlint: disable")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []
        assert len(result.suppressed) == 1

    def test_fingerprint_survives_line_moves(self, tmp_path):
        r1 = run(tmp_path, {"mod.py": SL002_BRANCH}, ["SL002"])
        shifted = "# a new leading comment\n\n" + SL002_BRANCH
        r2 = run(tmp_path, {"mod.py": shifted}, ["SL002"])
        assert (r1.findings[0].fingerprint
                == r2.findings[0].fingerprint)
        assert r1.findings[0].line != r2.findings[0].line


class TestRepoSmoke:
    def test_head_is_clean(self):
        """The committed tree passes all five rules against its baseline."""
        paths = [REPO_ROOT / d for d in ("src", "examples", "benchmarks")
                 if (REPO_ROOT / d).is_dir()]
        project = load_project(REPO_ROOT, paths)
        baseline = Baseline(REPO_ROOT / ".symlint-baseline.json")
        result = analyze(project, None, baseline)
        assert result.parse_errors == []
        assert result.findings == [], [f.to_json() for f in result.findings]
        assert result.stale_baseline == []
        assert result.exit_code == 0

    def test_cli_list_rules(self, capsys):
        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in ("SL001", "SL002", "SL003", "SL004", "SL005"):
            assert rid in out

    def test_cli_github_format_on_fixture(self, tmp_path, capsys, monkeypatch):
        (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
        (tmp_path / "mod.py").write_text(SL002_BRANCH)
        monkeypatch.chdir(tmp_path)
        code = main(["mod.py", "--format=github", "--no-baseline"])
        out = capsys.readouterr().out
        assert code == 1
        assert "::error file=mod.py" in out
