"""symlint (``repro.analysis``): rule fixtures, baseline/suppression
mechanics, the SL002-SL005 mutation batteries, the CFG dataflow paths, the
deep tier (SL006-SL008) with seeded defects, and the repo-wide smoke gate.

Every fixture project is built in ``tmp_path`` and analyzed through the real
engine (``load_project`` + ``analyze``), so the tests exercise the same
suppression/baseline partitioning the CLI uses.  The mutation batteries copy
*actual* repo files, seed one defect (one-sided struct edit, dropped
donation rebind, traced branch, un-annotated sync, gutted pretrace ladder,
f64 upcast), and assert the owning rule catches it -- that is the property
each rule exists for.
"""
import json
import subprocess
from pathlib import Path

import pytest

from repro.analysis.cli import find_root, main
from repro.analysis.engine import Baseline, analyze, load_project

REPO_ROOT = find_root(Path(__file__).resolve().parent)


def run(tmp_path, sources, rules, baseline=None, deep=False):
    """Write ``{relpath: source}`` under tmp_path and analyze it."""
    for rel, text in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    project = load_project(tmp_path, [tmp_path])
    if deep:
        from repro.analysis import deep as deep_mod
        deep_mod.prepare(project)
    return analyze(project, rules, baseline, include_deep=deep)


def rules_of(result):
    return [f.rule for f in result.findings]


# --------------------------------------------------------------- SL001 compat


SL001_POS = """\
import jax
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map

def kernel(block):
    return pltpu.TPUMemorySpace.ANY

def grid(params):
    return params(dimension_semantics=("parallel",))

def mesh():
    return jax.make_mesh((1,), ("data",))
"""

SL001_NEG = """\
from repro.utils.jax_compat import MemorySpace, VMEM, tpu_compiler_params

def kernel(block):
    return MemorySpace.ANY, VMEM((8,), float)
"""


class TestSL001:
    def test_positive(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        msgs = [f.message for f in result.findings]
        assert len(result.findings) == 4
        assert any("jax.experimental.shard_map" in m for m in msgs)
        assert any("pltpu.TPUMemorySpace" in m for m in msgs)
        assert any("dimension_semantics" in m for m in msgs)
        assert any("jax.make_mesh" in m for m in msgs)

    def test_negative(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_NEG}, ["SL001"])
        assert result.findings == []

    def test_suppressed(self, tmp_path):
        src = SL001_POS.replace(
            "return pltpu.TPUMemorySpace.ANY",
            "return pltpu.TPUMemorySpace.ANY  # symlint: disable=SL001")
        result = run(tmp_path, {"mod.py": src}, ["SL001"])
        assert len(result.findings) == 3
        assert len(result.suppressed) == 1

    def test_baselined(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        bpath = tmp_path / "baseline.json"
        Baseline.write(bpath, result.findings, {})
        baseline = Baseline(bpath)
        again = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"], baseline)
        assert again.findings == []
        assert len(again.baselined) == 4
        assert again.exit_code == 0

    def test_compat_module_itself_exempt(self, tmp_path):
        result = run(
            tmp_path, {"utils/jax_compat.py": SL001_POS}, ["SL001"])
        assert result.findings == []

    def test_docstring_table_drives_banned_list(self, tmp_path):
        # a fixture jax_compat whose table bans a made-up name
        compat = (
            '"""Shims.\n\n'
            "====  ====\n"
            "a     b\n"
            "====  ====\n"
            "x     ``pltpu.MadeUpName``\n"
            "====  ====\n"
            '"""\n'
        )
        user = (
            "from jax.experimental.pallas import tpu as pltpu\n"
            "def f():\n"
            "    return pltpu.MadeUpName\n"
        )
        result = run(tmp_path, {"utils/jax_compat.py": compat,
                                "mod.py": user}, ["SL001"])
        assert [f.rule for f in result.findings] == ["SL001"]
        assert "MadeUpName" in result.findings[0].message


# -------------------------------------------------------------- SL002 retrace


SL002_BRANCH = """\
import jax

@jax.jit
def f(x, y):
    if x > 0:
        return y
    return -y
"""

SL002_STATIC_OK = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("first",))
def f(x, *, first):
    if first:
        return x * 2
    return x
"""

SL002_CONCRETIZE = """\
import jax

@jax.jit
def f(x):
    return float(x) + 1.0
"""

SL002_CLOSURE = """\
import jax

def outer(scale):
    @jax.jit
    def inner(x):
        return x * scale
    return inner
"""

SL002_LOOP_STATIC = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("k",))
def f(x, *, k):
    return x * k

def driver(x):
    out = []
    for i in range(8):
        out.append(f(x, k=i))
    return out
"""


class TestSL002:
    def test_branch_on_traced(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_BRANCH}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "`if` statement" in result.findings[0].message

    def test_static_branch_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_STATIC_OK}, ["SL002"])
        assert result.findings == []

    def test_none_check_ok(self, tmp_path):
        src = SL002_BRANCH.replace("if x > 0:", "if y is None:")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []

    def test_concretize_traced(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_CONCRETIZE}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "float()" in result.findings[0].message

    def test_closure_capture(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_CLOSURE}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "`scale`" in result.findings[0].message

    def test_loop_varying_static(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL002_LOOP_STATIC}, ["SL002"])
        assert rules_of(result) == ["SL002"]
        assert "loop-varying" in result.findings[0].message

    def test_suppressed(self, tmp_path):
        src = SL002_BRANCH.replace(
            "if x > 0:", "if x > 0:  # symlint: disable=SL002")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ------------------------------------------------------------- SL003 donation


SL003_REUSE = """\
import jax

@jax.jit
def step(state, x):
    return state + x

step = jax.jit(step, donate_argnums=(0,))

def driver(state, x):
    out = step(state, x)
    return state + out
"""

SL003_REBOUND = """\
import jax

def _step(state, x):
    return state + x

step = jax.jit(_step, donate_argnums=(0,))

def driver(state, xs):
    for x in xs:
        state = step(state, x)
    return state
"""

SL003_LOOP_NO_REBIND = """\
import jax

def _step(state, x):
    return state + x

step = jax.jit(_step, donate_argnums=(0,))

def driver(state, xs):
    out = []
    for x in xs:
        out.append(step(state, x))
    return out
"""


class TestSL003:
    def test_read_after_donate(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_REUSE}, ["SL003"])
        assert "SL003" in rules_of(result)
        assert "`state`" in result.findings[0].message

    def test_rebound_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_REBOUND}, ["SL003"])
        assert result.findings == []

    def test_loop_without_rebind(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL003_LOOP_NO_REBIND}, ["SL003"])
        assert "SL003" in rules_of(result)
        assert "loop" in result.findings[0].message

    def test_suppressed(self, tmp_path):
        src = SL003_REUSE.replace(
            "return state + out",
            "return state + out  # symlint: disable=SL003")
        result = run(tmp_path, {"mod.py": src}, ["SL003"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ------------------------------------------------------------- SL004 hostsync


SL004_SYNC = """\
import numpy as np
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.cumsum(x)
    return np.asarray(y)
"""

SL004_ANNOTATED = """\
import numpy as np
import jax
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.cumsum(x)
    return jax.device_get(y)  # sync: ok
"""

SL004_BRANCH = """\
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    y = jnp.any(x > 0)
    if y:
        return 1
    return 0
"""


class TestSL004:
    def test_sync_in_hot_path(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_SYNC}, ["SL004"])
        assert rules_of(result) == ["SL004"]
        assert "np.asarray()" in result.findings[0].message

    def test_annotated_sync_ok(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_ANNOTATED}, ["SL004"])
        assert result.findings == []

    def test_branch_on_device_value(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL004_BRANCH}, ["SL004"])
        assert rules_of(result) == ["SL004"]
        assert "blocks on the device" in result.findings[0].message

    def test_unmarked_function_ignored(self, tmp_path):
        src = SL004_SYNC.replace("def hot(x):  # symlint: hot-path",
                                 "def cold(x):")
        result = run(tmp_path, {"mod.py": src}, ["SL004"])
        assert result.findings == []

    def test_suppressed(self, tmp_path):
        src = SL004_SYNC.replace(
            "return np.asarray(y)",
            "return np.asarray(y)  # symlint: disable=SL004")
        result = run(tmp_path, {"mod.py": src}, ["SL004"])
        assert result.findings == []
        assert len(result.suppressed) == 1


# ----------------------------------------------------------------- SL005 wire


CODEC_FILES = ("src/repro/launch/transport.py", "src/repro/core/receiver.py")


def codec_sources():
    return {rel: (REPO_ROOT / rel).read_text() for rel in CODEC_FILES}


class TestSL005:
    def test_real_codecs_consistent(self, tmp_path):
        result = run(tmp_path, codec_sources(), ["SL005"])
        assert result.findings == []

    @pytest.mark.parametrize("before,after", [
        ('"!IIB"', '"!IBB"'),     # encode/decode_closed header
        ('"!fII"', '"!fIH"'),     # pieces DATA header
        ('("endpoint", ">f4")', '("endpoint", ">f8")'),  # piece record
    ])
    def test_mutation_caught(self, tmp_path, before, after):
        sources = codec_sources()
        mutated = False
        for rel in list(sources):
            if before in sources[rel]:
                # flip the *first* occurrence: a one-sided edit
                sources[rel] = sources[rel].replace(before, after, 1)
                mutated = True
                break
        assert mutated, f"pattern {before!r} not found in codec files"
        result = run(tmp_path, sources, ["SL005"])
        assert any(f.rule == "SL005" for f in result.findings), (
            f"one-sided {before} -> {after} edit not caught")

    def test_unpaired_codec_flagged(self, tmp_path):
        src = (
            "import struct\n"
            "def encode_open(sid, mode, seed):\n"
            "    return struct.pack('!BI', mode, seed)\n"
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("decode_open" in f.message for f in result.findings)

    def test_offset_mismatch(self, tmp_path):
        src = (
            "import struct\n"
            "def encode_close(t, flag):\n"
            "    return struct.pack('!IB', t, flag) + struct.pack('!f', 0.5)\n"
            "def decode_close(buf):\n"
            "    t, flag = struct.unpack_from('!IB', buf)\n"
            "    tail = struct.unpack_from('!f', buf, 6)[0]\n"
            "    return t, flag, tail\n"
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("offset 6" in f.message for f in result.findings)

    def test_constant_contract(self, tmp_path):
        src = (
            "import numpy as np\n"
            "DELTA_SYMBOL_BYTES = 6.0\n"
            '_DELTA_REC = np.dtype([("label", "u1"), ("endpoint", ">f4")])\n'
        )
        result = run(tmp_path, {"mod.py": src}, ["SL005"])
        assert any("DELTA_SYMBOL_BYTES" in f.message
                   for f in result.findings)


# --------------------------------------- SL002/SL003/SL004 mutation batteries
#
# Mirror TestSL005.test_mutation_caught: copy the *actual* repo file, seed
# one defect, and assert the owning rule catches it.  Each battery first
# asserts the clean copy passes, so a firing can only come from the seed.


STREAM_SRC = "src/repro/launch/stream.py"
SYMED_SRC = "src/repro/core/symed.py"


def repo_source(rel):
    return {rel: (REPO_ROOT / rel).read_text()}


class TestMutationBatteries:
    def test_sl002_traced_branch_caught(self, tmp_path):
        sources = repo_source(SYMED_SRC)
        assert run(tmp_path, sources, ["SL002"]).findings == []
        needle = "    chunk = jnp.asarray(chunk, jnp.float32)"
        assert needle in sources[SYMED_SRC]
        sources[SYMED_SRC] = sources[SYMED_SRC].replace(
            needle,
            needle + "\n    if chunk[0] > 0:\n        chunk = -chunk", 1)
        result = run(tmp_path, sources, ["SL002"])
        assert any(f.rule == "SL002" and "`if` statement" in f.message
                   for f in result.findings), rules_of(result)

    def test_sl003_dropped_rebind_caught(self, tmp_path):
        sources = repo_source(STREAM_SRC)
        assert run(tmp_path, sources, ["SL003"]).findings == []
        needle = "self._table, info = _table_step("
        assert needle in sources[STREAM_SRC]
        # dropped rebind: the donated resident table is no longer reassigned
        # from the step's result, so the next round donates a dead buffer
        sources[STREAM_SRC] = sources[STREAM_SRC].replace(
            needle, "_stale, info = _table_step(", 1)
        result = run(tmp_path, sources, ["SL003"])
        assert any(f.rule == "SL003" and "self._table" in f.message
                   for f in result.findings), rules_of(result)

    def test_sl004_unannotated_sync_caught(self, tmp_path):
        sources = repo_source(STREAM_SRC)
        assert run(tmp_path, sources, ["SL004"]).findings == []
        needle = '                self.totals["steps"] += 1'
        assert needle in sources[STREAM_SRC]
        # seed a per-round host sync on the step's device output inside the
        # hot-path ingest loop, without the reviewed `# sync: ok` marker
        sources[STREAM_SRC] = sources[STREAM_SRC].replace(
            needle,
            needle + '\n                _t0 = float(info["t_seen"][0])', 1)
        result = run(tmp_path, sources, ["SL004"])
        assert any(f.rule == "SL004" and "float()" in f.message
                   for f in result.findings), rules_of(result)


# ----------------------------------------------- CFG dataflow paths (fixpoint)


CFG_LOOP_CARRY = """\
import jax.numpy as jnp

def hot(xs, n):  # symlint: hot-path
    prev = None
    for i in range(n):
        if i > 0:
            out = float(prev)
        prev = jnp.sum(xs[i])
    return prev
"""

CFG_BRANCH_CLEANSE_ONE = """\
import jax.numpy as jnp

def hot(x, cond):  # symlint: hot-path
    v = jnp.sum(x)
    if cond:
        v = 0.0
    return float(v)
"""

CFG_BRANCH_CLEANSE_BOTH = """\
import jax.numpy as jnp

def hot(x, cond):  # symlint: hot-path
    v = jnp.sum(x)
    if cond:
        v = 0.0
    else:
        v = 1.0
    return float(v)
"""

CFG_TRY_EDGE = """\
import jax.numpy as jnp

def hot(x):  # symlint: hot-path
    v = 0.0
    try:
        v = jnp.sum(x)
        v = host_value()
    except ValueError:
        return float(v)
    return v
"""


class TestCFGDataflow:
    """Flows only a fixpoint over a real CFG can see (the single-pass
    walker this engine replaced read statements once, in source order)."""

    def test_loop_carried_taint(self, tmp_path):
        # `prev` is tainted at the *bottom* of the loop body; the read at
        # the top only sees it through the loop's back edge
        result = run(tmp_path, {"mod.py": CFG_LOOP_CARRY}, ["SL004"])
        assert any("float()" in f.message for f in result.findings), \
            rules_of(result)

    def test_cleanse_in_one_branch_still_tainted(self, tmp_path):
        result = run(tmp_path, {"mod.py": CFG_BRANCH_CLEANSE_ONE}, ["SL004"])
        assert any("float()" in f.message for f in result.findings), \
            rules_of(result)

    def test_cleanse_in_both_branches_clean(self, tmp_path):
        result = run(tmp_path, {"mod.py": CFG_BRANCH_CLEANSE_BOTH},
                     ["SL004"])
        assert result.findings == []

    def test_taint_reaches_handler_via_exception_edge(self, tmp_path):
        # the handler can run after `v = jnp.sum(x)` but before the
        # cleansing host_value() rebind lands
        result = run(tmp_path, {"mod.py": CFG_TRY_EDGE}, ["SL004"])
        assert any("float()" in f.message for f in result.findings), \
            rules_of(result)


# ------------------------------------------------------- engine + repo gates


class TestEngine:
    def test_stale_baseline_entry_fails(self, tmp_path):
        result = run(tmp_path, {"mod.py": SL001_POS}, ["SL001"])
        bpath = tmp_path / "baseline.json"
        Baseline.write(bpath, result.findings, {})
        clean = run(tmp_path, {"clean.py": SL001_NEG}, ["SL001"],
                    Baseline(bpath))
        # the fixture with the violations is still in the sweep, so entries
        # are live; now analyze a sweep where they no longer match
        project = load_project(tmp_path / "sub", [])
        from repro.analysis.engine import analyze as analyze_fn
        result2 = analyze_fn(project, ["SL001"], Baseline(bpath))
        assert result2.stale_baseline
        assert result2.exit_code == 1
        assert clean.exit_code == 0  # live entries are not stale

    def test_parse_error_reported_not_raised(self, tmp_path):
        result = run(tmp_path, {"bad.py": "def broken(:\n"}, ["SL001"])
        assert result.parse_errors
        assert result.exit_code == 1

    def test_bare_disable_suppresses_all_rules(self, tmp_path):
        src = SL002_BRANCH.replace(
            "if x > 0:", "if x > 0:  # symlint: disable")
        result = run(tmp_path, {"mod.py": src}, ["SL002"])
        assert result.findings == []
        assert len(result.suppressed) == 1

    def test_fingerprint_survives_line_moves(self, tmp_path):
        r1 = run(tmp_path, {"mod.py": SL002_BRANCH}, ["SL002"])
        shifted = "# a new leading comment\n\n" + SL002_BRANCH
        r2 = run(tmp_path, {"mod.py": shifted}, ["SL002"])
        assert (r1.findings[0].fingerprint
                == r2.findings[0].fingerprint)
        assert r1.findings[0].line != r2.findings[0].line


class TestCompatTablePin:
    def test_fallback_tokens_match_docstring_table(self):
        """The frozen fallback banned-name table must stay in lock-step with
        the table parsed live from jax_compat.py's docstring -- the fallback
        exists only for sweeps that exclude the compat module, never to
        diverge.  The live table also documents the shim-side replacement
        names (harmless in the pltpu-attr bucket), so the pin compares the
        *effective* banned sets: kwargs and dotted paths must be identical,
        every ``pltpu.``-prefixed ban identical, and every fallback token
        must still exist in the docstring."""
        from repro.analysis.rules.compat import (
            FALLBACK_TOKENS, _classify, _docstring_tokens)
        project = load_project(REPO_ROOT, [REPO_ROOT / "src"])
        live = _docstring_tokens(project)
        assert live is not FALLBACK_TOKENS, \
            "docstring table not found -- pin test is comparing the " \
            "fallback with itself"
        missing = set(FALLBACK_TOKENS) - set(live)
        assert not missing, f"fallback bans names the docstring dropped: " \
            f"{sorted(missing)}"
        live_kwargs, _, live_paths = _classify(live)
        fb_kwargs, _, fb_paths = _classify(FALLBACK_TOKENS)
        assert live_kwargs == fb_kwargs
        assert live_paths == fb_paths
        live_pltpu = {t for t in live if t.startswith("pltpu.")}
        fb_pltpu = {t for t in FALLBACK_TOKENS if t.startswith("pltpu.")}
        assert live_pltpu == fb_pltpu


# ------------------------------------------------- deep tier (SL006 - SL008)


ENTRY_GOOD = """\
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))  # symlint: entry(drive=stream, budget=2, shapes=table-step, pair=chunk/table)
def step(state, x):
    return state + x
"""


class TestEntryRegistry:
    """The annotation parser is pure AST -- no jax import involved."""

    def _registry(self, tmp_path, sources):
        for rel, text in sources.items():
            p = tmp_path / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        from repro.analysis.deep import entry_registry
        return entry_registry(load_project(tmp_path, [tmp_path]))

    def test_parse_all_keys(self, tmp_path):
        entries, errors = self._registry(tmp_path, {"mod.py": ENTRY_GOOD})
        assert errors == []
        (e,) = entries
        assert (e.qualname, e.drive, e.budget, e.shapes) == (
            "step", "stream", 2, "table-step")
        assert (e.pair_label, e.pair_role) == ("chunk", "table")

    def test_inline_shapes_survive_comma_split(self, tmp_path):
        src = ENTRY_GOOD.replace(
            "entry(drive=stream, budget=2, shapes=table-step, "
            "pair=chunk/table)",
            "entry(budget=1, shapes=f32[4,8] i32[4], drive=stream)")
        entries, errors = self._registry(tmp_path, {"mod.py": src})
        assert errors == []
        assert entries[0].shapes == "f32[4,8] i32[4]"
        assert entries[0].budget == 1

    @pytest.mark.parametrize("mutant,expect", [
        ("drive=stream, budget=two", "not an int"),
        ("drive=stream, colour=red", "unknown"),
        ("pair=chunk", "slot or"),
        ("budget=0", "at least"),
    ])
    def test_malformed_annotation_is_error(self, tmp_path, mutant, expect):
        src = ENTRY_GOOD.replace(
            "entry(drive=stream, budget=2, shapes=table-step, "
            "pair=chunk/table)", f"entry({mutant})")
        entries, errors = self._registry(tmp_path, {"mod.py": src})
        assert entries == []
        assert len(errors) == 1 and expect in errors[0][2]

    def test_nested_def_is_error(self, tmp_path):
        src = (
            "def outer():\n"
            "    def inner(x):  # symlint: entry(drive=stream)\n"
            "        return x\n"
            "    return inner\n"
        )
        entries, errors = self._registry(tmp_path, {"mod.py": src})
        assert entries == []
        assert len(errors) == 1 and "module-level" in errors[0][2]

    def test_dangling_annotation_is_error(self, tmp_path):
        src = "x = 1  # symlint: entry(drive=stream)\n"
        entries, errors = self._registry(tmp_path, {"mod.py": src})
        assert entries == []
        assert len(errors) == 1 and "not attached" in errors[0][2]

    def test_repo_entries_present(self):
        from repro.analysis.deep import entry_registry
        paths = [REPO_ROOT / d for d in ("src", "examples", "benchmarks")
                 if (REPO_ROOT / d).is_dir()]
        entries, errors = entry_registry(load_project(REPO_ROOT, paths))
        assert errors == []
        names = {e.qualname for e in entries}
        assert {"_table_step", "_table_step_pieces", "_encode_chunk",
                "_receive_chunk", "_receive_finish", "digitize_span",
                "digitize_span_table", "digitize_pieces",
                "_mapped_runner"} <= names
        pairs = {(e.pair_label, e.pair_role) for e in entries
                 if e.pair_label}
        assert {("chunk", "slot"), ("chunk", "table"), ("pieces", "slot"),
                ("pieces", "table"), ("span", "slot"),
                ("span", "table")} <= pairs


class TestDeepTier:
    """Seeded-defect batteries: each deep rule must fire on a mutated copy
    of the real file it guards (and stay quiet without the seed -- HEAD
    cleanliness is asserted by CI's `symlint --deep` run, not re-paid here
    per test)."""

    def test_deep_rules_silent_without_prepare(self, tmp_path):
        result = run(tmp_path, {"mod.py": ENTRY_GOOD},
                     ["SL006", "SL007", "SL008"])
        assert result.findings == []

    def test_deep_rules_excluded_from_default_tier(self):
        from repro.analysis.engine import RULES
        import repro.analysis.rules  # noqa: F401
        assert {RULES[r].tier for r in ("SL006", "SL007", "SL008")} == {
            "deep"}
        assert {RULES[r].tier
                for r in ("SL001", "SL002", "SL003", "SL004", "SL005")} == {
            "ast"}

    def test_sl006_gutted_pretrace_trips_budget(self, tmp_path):
        text = (REPO_ROOT / STREAM_SRC).read_text()
        needle = ("ladder = self._ladder if self.autoscale "
                  "else [self.capacity]")
        assert needle in text
        # the warm-up no longer covers any rung: the first serving-loop
        # ingest of the measured window must now trace
        result = run(tmp_path,
                     {"stream_mut.py": text.replace(needle, "ladder = []")},
                     ["SL006"], deep=True)
        assert any(f.rule == "SL006" and "over its declared budget"
                   in f.message for f in result.findings), \
            [f.message for f in result.findings]

    def test_sl007_f64_upcast_trips_dtype_discipline(self, tmp_path):
        text = (REPO_ROOT / "src/repro/core/digitize.py").read_text()
        head, sep, tail = text.partition("def digitize_span_table(")
        needle = "lengths.astype(jnp.float32)"
        assert needle in tail
        tail = tail.replace(needle, "lengths.astype(jnp.float64)", 1)
        result = run(tmp_path, {"digitize_mut.py": head + sep + tail},
                     ["SL007"], deep=True)
        assert any(f.rule == "SL007" and "64-bit" in f.message
                   for f in result.findings), \
            [f.message for f in result.findings]

    def test_sl008_unaliasable_donation_fires_and_clean_passes(
            self, tmp_path):
        src = (
            "import functools\n"
            "import jax\n"
            "\n"
            "@functools.partial(jax.jit, donate_argnums=(0,))"
            "  # symlint: entry(shapes=f32[8] f32[8])\n"
            "def step_bad(state, x):\n"
            "    return state[:-1] + x[:-1]\n"
            "\n"
            "@functools.partial(jax.jit, donate_argnums=(0,))"
            "  # symlint: entry(shapes=f32[8] f32[8])\n"
            "def step_ok(state, x):\n"
            "    return state + x\n"
        )
        result = run(tmp_path, {"mod.py": src}, ["SL008"], deep=True)
        assert result.findings, "dropped donation not caught"
        assert all(f.rule == "SL008" and "step_bad" in f.message
                   for f in result.findings), \
            [f.message for f in result.findings]


class TestRepoSmoke:
    def test_head_is_clean(self):
        """The committed tree passes all five rules against its baseline."""
        paths = [REPO_ROOT / d for d in ("src", "examples", "benchmarks")
                 if (REPO_ROOT / d).is_dir()]
        project = load_project(REPO_ROOT, paths)
        baseline = Baseline(REPO_ROOT / ".symlint-baseline.json")
        result = analyze(project, None, baseline)
        assert result.parse_errors == []
        assert result.findings == [], [f.to_json() for f in result.findings]
        assert result.stale_baseline == []
        assert result.exit_code == 0

    def test_cli_list_rules(self, capsys):
        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in ("SL001", "SL002", "SL003", "SL004", "SL005",
                    "SL006", "SL007", "SL008"):
            assert rid in out

    def test_update_baseline_refuses_todo_placeholder(
            self, tmp_path, capsys, monkeypatch):
        (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
        (tmp_path / "mod.py").write_text(SL002_BRANCH)
        monkeypatch.chdir(tmp_path)
        bpath = tmp_path / "bl.json"
        code = main(["mod.py", "--update-baseline",
                     "--baseline", str(bpath)])
        out = capsys.readouterr().out
        assert code == 1
        assert "placeholder" in out
        # a written justification satisfies the gate on the next update
        doc = json.loads(bpath.read_text())
        doc["entries"][0]["justification"] = "reviewed: fixture only"
        bpath.write_text(json.dumps(doc))
        code = main(["mod.py", "--update-baseline",
                     "--baseline", str(bpath)])
        assert code == 0

    def test_changed_mode_filters_to_diff(self, tmp_path, capsys,
                                          monkeypatch):
        (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
        (tmp_path / "old.py").write_text(SL002_BRANCH)

        def git(*args):
            subprocess.run(
                ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
                cwd=tmp_path, check=True, capture_output=True)

        git("init", "-q")
        git("add", ".")
        git("commit", "-qm", "init")
        (tmp_path / "new.py").write_text(SL002_CONCRETIZE)
        monkeypatch.chdir(tmp_path)
        code = main(["old.py", "new.py", "--changed", "--no-baseline"])
        out = capsys.readouterr().out
        assert code == 1
        assert "new.py" in out
        assert "old.py" not in out

    def test_cli_github_format_on_fixture(self, tmp_path, capsys, monkeypatch):
        (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
        (tmp_path / "mod.py").write_text(SL002_BRANCH)
        monkeypatch.chdir(tmp_path)
        code = main(["mod.py", "--format=github", "--no-baseline"])
        out = capsys.readouterr().out
        assert code == 1
        assert "::error file=mod.py" in out
