"""Receiver-side digitization + reconstruction tests (paper Alg. 2/3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SymEDConfig, abba_encode, digitize_pieces, dtw_ref,
    reconstruct_from_pieces, reconstruct_from_symbols, symed_encode,
)
from repro.core.digitize import masked_kmeans, max_cluster_variance, scale_coords
from repro.core.metrics import compression_rate_abba, compression_rate_symed, drr
from repro.core.reconstruct import quantize_lengths

from conftest import make_stream


def _encode(rng, n=500, tol=0.4, **kw):
    cfg = SymEDConfig(tol=tol, alpha=0.02, n_max=256, k_max=32, len_max=128, **kw)
    ts = jnp.asarray(make_stream(rng, n))
    return ts, cfg, symed_encode(ts, cfg, jax.random.key(0))


class TestDigitize:
    def test_labels_within_alphabet(self, rng):
        _, _, out = _encode(rng)
        n, k = int(out["n_pieces"]), int(out["k"])
        labels = np.asarray(out["symbols"])[:n]
        assert k >= 1 and (labels >= 0).all() and (labels < k).all()

    def test_kmin_respected(self, rng):
        _, _, out = _encode(rng)
        assert int(out["k"]) >= min(3, int(out["n_pieces"]))

    def test_kmax_bounds_alphabet(self, rng):
        ts = jnp.asarray(make_stream(rng, 800))
        cfg = SymEDConfig(tol=0.05, alpha=0.02, n_max=512, k_max=8, len_max=64)
        out = symed_encode(ts, cfg, jax.random.key(0))
        assert int(out["k"]) <= 8

    def test_variance_bound_or_limits(self, rng):
        """After digitization: max cluster variance <= tol^2 OR k hit a limit."""
        ts, cfg, out = _encode(rng)
        n, k = int(out["n_pieces"]), int(out["k"])
        pieces = jnp.stack([out["pieces_len"].astype(jnp.float32),
                            out["pieces_inc"]], -1)
        mask = jnp.arange(pieces.shape[0]) < n
        scales, coords = scale_coords(pieces, mask, jnp.float32(cfg.scl))
        centers = out["centers"] * scales[None, :]
        err = float(max_cluster_variance(coords, mask,
                                         centers, out["symbols"], jnp.int32(k)))
        assert err <= cfg.tol ** 2 + 1e-3 or k >= min(cfg.k_max, n)

    def test_masked_kmeans_assigns_nearest(self, rng):
        pts = jnp.asarray(rng.normal(size=(40, 2)), jnp.float32)
        mask = jnp.ones((40,), bool)
        c0 = pts[:4]
        c, lab = masked_kmeans(pts, mask, jnp.pad(c0, ((0, 4), (0, 0))),
                               jnp.int32(4), iters=10)
        d = jnp.sum((pts[:, None] - c[None, :4]) ** 2, -1)
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(jnp.argmin(d, 1)))


class TestReconstruction:
    def test_pieces_beat_symbols(self, rng):
        """Paper headline: online (piece) reconstruction has lower DTW error
        than symbol reconstruction -- averaged over streams."""
        diffs = []
        for i in range(6):
            _, _, out = _encode(np.random.default_rng(i))
            diffs.append(float(out["re_symbols"]) - float(out["re_pieces"]))
        assert np.mean(diffs) > 0

    def test_reconstruction_length(self, rng):
        ts, _, out = _encode(rng, n=500)
        assert out["recon_pieces"].shape == ts.shape
        assert out["recon_symbols"].shape == ts.shape

    def test_piece_reconstruction_hits_endpoints(self, rng):
        """Interpolated chain passes through every transmitted endpoint."""
        ts, cfg, out = _encode(rng, n=300)
        rec = np.asarray(out["recon_pieces"])
        n = int(out["n_pieces"])
        lens = np.asarray(out["pieces_len"])[:n]
        incs = np.asarray(out["pieces_inc"])[:n]
        pos = np.cumsum(lens)
        vals = float(ts[0]) + np.cumsum(incs)
        for p, v in zip(pos, vals):
            assert rec[p] == pytest.approx(v, abs=1e-3)

    def test_tol_controls_error(self, rng):
        """Looser tol => worse (or equal) piece reconstruction, fewer pieces."""
        ts = jnp.asarray(make_stream(rng, 800))
        res = {}
        for tol in (0.1, 1.0):
            cfg = SymEDConfig(tol=tol, alpha=0.02, n_max=512, k_max=32, len_max=256)
            res[tol] = symed_encode(ts, cfg, jax.random.key(0))
        assert int(res[0.1]["n_pieces"]) > int(res[1.0]["n_pieces"])
        assert float(res[0.1]["re_pieces"]) <= float(res[1.0]["re_pieces"]) + 1e-3

    @given(st.lists(st.floats(0.5, 30.0), min_size=2, max_size=40))
    @settings(max_examples=30, deadline=None)
    def test_quantize_lengths_preserves_total(self, lens):
        arr = jnp.asarray(lens, jnp.float32)
        mask = jnp.ones((len(lens),), bool)
        q = np.asarray(quantize_lengths(arr, mask))
        assert (q >= 1).all()
        # ABBA cumulative rounding: total drifts < 1 from the real sum
        assert abs(q.sum() - float(np.asarray(arr).sum())) <= len(lens) * 0.5 + 1

    @given(st.lists(st.floats(0.05, 4.0), min_size=3, max_size=48),
           st.integers(0, 5))
    @settings(max_examples=30, deadline=None)
    def test_quantize_lengths_subunit_exact_invariant(self, lens, pad):
        """Regression: pieces that round to 0 used to be floored to 1 *after*
        the carry, silently inflating the total.  With the floor folded into
        the carry, the total equals the tight lower bound
        ``max_j(round(csum_j) + n - j)`` -- the smallest total any >=1-point
        allocation can reach once ``j`` pieces consumed ``round(csum_j)``
        points -- which *is* ``round(sum(lengths))`` whenever the floors can
        be absorbed (the bound is attained at ``j = n``).  Mask padding must
        contribute nothing."""
        n = len(lens)
        arr = jnp.asarray(list(lens) + [50.0] * pad, jnp.float32)
        mask = jnp.asarray([True] * n + [False] * pad)
        q = np.asarray(quantize_lengths(arr, mask))
        assert (q[:n] >= 1).all()
        assert (q[n:] == 0).all()
        r = np.asarray(jnp.round(jnp.cumsum(jnp.asarray(lens, jnp.float32))))
        bound = max(r[j] + (n - 1 - j) for j in range(n))
        assert q.sum() == max(bound, n)
        if bound == r[-1] >= n:  # floors absorbed: the ABBA invariant, exact
            assert q.sum() == r[-1]

    def test_quantize_lengths_subunit_carry_absorbs_floor(self):
        """Many sub-unit fractional lengths: forced >=1 floors borrow from
        the carry, so later pieces absorb the excess and the exact total
        round(0.4 + 2.6 + 0.4 + 2.6) = 6 survives (the old post-carry floor
        returned 7)."""
        arr = jnp.asarray([0.4, 2.6, 0.4, 2.6], jnp.float32)
        q = np.asarray(quantize_lengths(arr, jnp.ones((4,), bool)))
        assert q.tolist() == [1, 2, 1, 2]
        # degenerate: more live pieces than rounded points -> one point each
        arr = jnp.asarray([0.1] * 10, jnp.float32)
        q = np.asarray(quantize_lengths(arr, jnp.ones((10,), bool)))
        assert q.tolist() == [1] * 10


class TestMetrics:
    def test_dtw_identity_and_symmetry(self, rng):
        x = jnp.asarray(make_stream(rng, 120))
        y = jnp.asarray(make_stream(np.random.default_rng(5), 120))
        assert float(dtw_ref(x, x)) == pytest.approx(0.0, abs=1e-3)
        assert float(dtw_ref(x, y)) == pytest.approx(float(dtw_ref(y, x)), rel=1e-5)

    def test_dtw_leq_euclidean(self, rng):
        x = jnp.asarray(make_stream(rng, 100))
        y = x + jnp.asarray(np.random.default_rng(1).normal(0, 0.1, 100), jnp.float32)
        eu = float(jnp.sqrt(jnp.sum((x - y) ** 2)))
        assert float(dtw_ref(x, y)) <= eu + 1e-4

    @pytest.mark.parametrize("band", [0, 1, 3])
    def test_dtw_band_clamped_to_length_gap(self, rng, band):
        """Regression: band < |n - m| used to make the terminal cell
        unreachable, returning sqrt(1e30) as if it were a distance.  The
        effective radius clamps to max(band, |n-m|), so the distance stays
        finite and can only tighten (grow) versus full DTW."""
        x = jnp.asarray(make_stream(rng, 90))
        y = jnp.asarray(make_stream(np.random.default_rng(3), 50))
        d = float(dtw_ref(x, y, band=band))
        full = float(dtw_ref(x, y))
        assert d < 1e10, "terminal cell unreachable: _INF leaked out"
        assert d >= full - 1e-4
        # band == |n-m| is the tightest reachable corridor; smaller bands
        # clamp to it exactly
        assert d == pytest.approx(float(dtw_ref(x, y, band=40)), rel=1e-6)

    def test_dtw_band_zero_equal_lengths_is_euclidean(self, rng):
        """band=0 with equal lengths pins the diagonal path: DTW degenerates
        to the pointwise L2 distance (no clamp interference)."""
        x = jnp.asarray(make_stream(rng, 64))
        y = x + jnp.asarray(
            np.random.default_rng(4).normal(0, 0.2, 64), jnp.float32)
        eu = float(jnp.sqrt(jnp.sum((x - y) ** 2)))
        assert float(dtw_ref(x, y, band=0)) == pytest.approx(eu, rel=1e-5)

    def test_cr_formulas(self):
        # CR_SymED = n/N (one float per piece vs float per point)
        assert float(compression_rate_symed(jnp.int32(50), 1000)) == pytest.approx(0.05)
        # CR_ABBA = (8k + n) / 4N
        assert float(compression_rate_abba(jnp.int32(50), jnp.int32(5), 1000)) == \
            pytest.approx((8 * 5 + 50) / 4000)
        assert float(drr(jnp.int32(50), 1000)) == pytest.approx(0.05)

    def test_symed_cr_equals_drr(self, rng):
        _, _, out = _encode(rng)
        assert float(out["cr"]) == pytest.approx(float(out["drr"]))


class TestABBABaseline:
    def test_abba_pieces_cover_stream(self, rng):
        ts = jnp.asarray(make_stream(rng, 600))
        res = abba_encode(ts, n_max=256, tol=0.4, len_max=128, k_max=32)
        n = int(res.n_pieces)
        assert np.asarray(res.lengths)[:n].sum() == 599
        assert int(res.k) >= 3

    def test_abba_better_cr_than_symed(self, rng):
        """Paper Fig. 5b: ABBA transmits symbols+centers -> lower CR."""
        vals = []
        for i in range(4):
            ts = jnp.asarray(make_stream(np.random.default_rng(i), 800))
            res = abba_encode(ts, n_max=512, tol=0.5, len_max=256, k_max=32)
            cfg = SymEDConfig(tol=0.5, alpha=0.02, n_max=512, k_max=32, len_max=256)
            out = symed_encode(ts, cfg, jax.random.key(0), reconstruct=False)
            cr_abba = float(compression_rate_abba(res.n_pieces, res.k, 800))
            vals.append((cr_abba, float(out["cr"])))
        assert np.mean([a for a, s in vals]) < np.mean([s for a, s in vals])
