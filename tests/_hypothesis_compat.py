"""``hypothesis`` shim: real property testing when installed, deterministic
parametrized sampling otherwise.

The property tests in ``test_core_compress.py`` / ``test_core_digitize.py``
import ``given`` / ``settings`` / ``st`` from here.  With the ``hypothesis``
wheel present they get the real thing (shrinking, example database, ...).
Without it they still *run* -- ``@given`` degrades to a loop over seeded
deterministic draws from miniature strategy objects, so the properties are
checked on a fixed sample instead of being skipped wholesale.

Only the strategy combinators the suite uses are implemented:
``st.floats(lo, hi)``, ``st.integers(lo, hi)``,
``st.lists(elem, min_size=, max_size=)`` and ``st.sampled_from(seq)``.
"""
from __future__ import annotations

import functools

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _FALLBACK_EXAMPLES = 12  # draws per @given when hypothesis is absent

    class _Strategy:
        """A draw function ``rng -> value`` plus boundary examples."""

        def __init__(self, draw, boundary=()):
            self._draw = draw
            self._boundary = tuple(boundary)

        def draw(self, rng, i):
            # lead with the boundary examples, then seeded random draws
            if i < len(self._boundary):
                return self._boundary[i]
            return self._draw(rng)

    class st:  # noqa: N801 -- mirrors ``hypothesis.strategies`` spelling
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                boundary=(float(min_value), float(max_value)),
            )

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundary=(int(min_value), int(max_value)),
            )

        @staticmethod
        def sampled_from(elements):
            elements = tuple(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))],
                boundary=elements[:2],
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng, i + 1000) for i in range(n)]

            boundary = ()
            if min_size > 0:
                # smallest allowed list, deterministic elements
                boundary = (
                    [
                        elements.draw(_np.random.default_rng(7), i + 1000)
                        for i in range(min_size)
                    ],
                )
            return _Strategy(draw, boundary=boundary)

    import inspect as _inspect

    def given(*strategies_args, **strategies_kw):
        def decorate(fn):
            # hypothesis semantics: positional strategies fill the *trailing*
            # params.  Bind them by name (keyword) so tests that also take
            # pytest fixtures keep working when pytest passes those fixtures
            # as keywords.
            sig = _inspect.signature(fn)
            params = list(sig.parameters.values())
            n_pos = len(strategies_args)
            trailing = [p.name for p in params[len(params) - n_pos:]] if n_pos else []

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for i in range(_FALLBACK_EXAMPLES):
                    rng = _np.random.default_rng(0xC0FFEE + 7919 * i)
                    named = dict(zip(trailing,
                                     (s.draw(rng, i) for s in strategies_args)))
                    named.update(
                        {k: s.draw(rng, i) for k, s in strategies_kw.items()})
                    fn(*args, **named, **kwargs)

            # hide the strategy-bound params from pytest's fixture resolution
            # (keep e.g. ``self`` and real fixtures).
            bound = set(trailing) | set(strategies_kw)
            del wrapper.__wrapped__  # stop signature() following back to fn
            wrapper.__signature__ = sig.replace(
                parameters=[p for p in params if p.name not in bound])
            return wrapper

        return decorate

    def settings(*_a, **_kw):  # max_examples/deadline are no-ops here
        def decorate(fn):
            return fn

        return decorate


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
