"""Flight-recorder battery: metrics math, span ring, exposition, and wiring.

Five layers:

* ``TestBuckets`` / ``TestHistogram`` -- the log-bucket scheme and the
  bucket-derived quantiles, checked against numpy ground truth (the
  recorder's p50/p99/p999 must track real quantiles within the bucket
  width bound, not just be self-consistent).
* ``TestSpanRing`` -- ring wraparound accounting and Chrome trace-event
  JSON schema validity (the document must load in Perfetto unmodified).
* ``TestRegistry`` / ``TestPrometheus`` -- get-or-create vs callback
  registration semantics and the text exposition format (cumulative
  monotone buckets, ``+Inf`` == count, derived quantile gauges).
* ``TestServingIntegration`` -- a loopback ``TransportServer`` scraped
  over HTTP mid-process: the ``/metrics`` text and ``/metrics.json``
  snapshot must agree with the stream server's own ``report``; and the
  recorder must be *inert* when disabled (bitwise-identical deltas,
  no ``"obs"`` report key).
* ``TestSweepInclusion`` -- ``src/repro/obs`` is inside the symlint
  sweep, so the zero-host-sync hot-path contract is machine-checked.
"""
import json
import threading
import urllib.request
from pathlib import Path

import numpy as np
import pytest

from conftest import make_stream

from repro.core.symed import SymEDConfig
from repro.launch.stream import StreamServer
from repro.launch.transport import SenderClient, TransportServer, session_seed
from repro.obs import Observability, as_obs, disabled
from repro.obs.metrics import (
    N_BUCKETS, Histogram, MetricsRegistry, NULL_INSTRUMENT,
    bucket_bounds, bucket_index,
)
from repro.obs.tracing import SpanTracer, annotate
from repro.obs.export import PROM_CONTENT_TYPE, ObsHTTPServer, prometheus_text

CFG = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                  len_max=32, n_max=64, lloyd_iters=5)


# ------------------------------------------------------------- bucket scheme


class TestBuckets:
    def test_bounds_partition_the_line(self):
        """Buckets tile [0, inf): hi of bucket i is lo of bucket i+1, and
        the lower bound maps back to its own index."""
        prev_hi = 0
        for i in range(2048):
            lo, hi = bucket_bounds(i)
            assert lo == prev_hi, i
            assert hi > lo, i
            assert bucket_index(lo) == i
            assert bucket_index(hi - 1) == i
            assert bucket_index(hi) == i + 1
            prev_hi = hi

    def test_index_monotone_and_value_in_bounds(self):
        rng = np.random.default_rng(42)
        vals = sorted(int(v) for v in
                      np.concatenate([rng.integers(0, 1 << b, size=64)
                                      for b in (4, 10, 20, 32, 48, 62)]))
        prev = -1
        for v in vals:
            i = bucket_index(v)
            lo, hi = bucket_bounds(i)
            assert lo <= v < hi
            assert i >= prev  # monotone in value
            prev = i

    def test_relative_width_bound(self):
        """Each bucket spans <= 25% of its lower bound (quantile error
        bound) once past the exact unit buckets."""
        for i in range(4, 2048):
            lo, hi = bucket_bounds(i)
            assert (hi - lo) * 4 <= lo

    def test_covers_64bit_nanoseconds(self):
        assert bucket_index((1 << 63) - 1) < N_BUCKETS


# ---------------------------------------------------------------- histogram


class TestHistogram:
    def test_quantiles_vs_numpy(self):
        """Bucket-midpoint quantiles track numpy within the bucket width
        bound on a heavy-tailed latency-like distribution."""
        rng = np.random.default_rng(7)
        samples = np.exp(rng.normal(12.0, 1.2, size=20000)).astype(np.int64)
        h = Histogram("t", unit="ns")
        for v in samples:
            h.observe(int(v))
        for q in (0.5, 0.9, 0.99, 0.999):
            got = h.quantile(q)
            want = float(np.quantile(samples, q))
            assert abs(got - want) / want < 0.15, (q, got, want)

    def test_empty_and_single(self):
        h = Histogram("t")
        assert h.quantile(0.5) == 0.0
        assert h.mean == 0.0
        h.observe(1000)
        lo, hi = bucket_bounds(bucket_index(1000))
        assert h.quantile(0.5) == (lo + hi) / 2.0
        assert h.quantile(0.999) == (lo + hi) / 2.0
        assert h.count == 1 and h.total == 1000

    def test_observe_n_equals_repeated_observe(self):
        a, b = Histogram("a"), Histogram("b")
        for v in (3, 77, 1 << 20):
            a.observe_n(v, 5)
            for _ in range(5):
                b.observe(v)
        assert a.buckets == b.buckets
        assert (a.count, a.total) == (b.count, b.total)
        a.observe_n(123, 0)  # no-op
        assert a.count == b.count

    def test_negative_clamped_to_zero(self):
        h = Histogram("t")
        h.observe(-5)
        assert h.buckets[0] == 1 and h.total == 0


# ---------------------------------------------------------------- span ring


class TestSpanRing:
    def test_wraparound_keeps_newest_oldest_first(self):
        tr = SpanTracer(capacity=8)
        for i in range(20):
            tr.instant(f"ev{i}")
        assert tr.recorded == 20
        assert tr.dropped == 12
        evs = tr.events()
        assert [e[0] for e in evs] == [f"ev{i}" for i in range(12, 20)]
        ts = [e[2] for e in evs]
        assert ts == sorted(ts)  # oldest first

    def test_under_capacity_no_drops(self):
        tr = SpanTracer(capacity=8)
        for i in range(5):
            tr.instant(f"ev{i}")
        assert tr.dropped == 0
        assert [e[0] for e in tr.events()] == [f"ev{i}" for i in range(5)]

    def test_disabled_records_nothing(self):
        tr = SpanTracer(capacity=8, enabled=False)
        tr.instant("x")
        tr.add("y", 0)
        with tr.span("z"):
            pass
        assert tr.recorded == 0 and tr.events() == []

    def test_span_context_manager(self):
        tr = SpanTracer(capacity=8)
        with tr.span("work", {"k": 1}):
            pass
        (name, ph, _, dur, args), = tr.events()
        assert (name, ph, args) == ("work", "X", {"k": 1})
        assert dur >= 0

    def test_chrome_trace_schema(self, tmp_path):
        """The written document is valid Chrome trace-event JSON: list of
        events with name/ph/ts/pid/tid, durations on X, scope on i."""
        tr = SpanTracer(capacity=16, pid=7)
        t0 = tr._t0_ns
        tr.add_span("dispatch", t0 + 1000, t0 + 51000, {"rounds": 2})
        tr.instant("grow", {"capacity": 4})
        path = tmp_path / "trace.json"
        tr.write(str(path), tid=3)
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert doc["otherData"]["dropped_events"] == 0
        evs = doc["traceEvents"]
        assert len(evs) == 2
        for ev in evs:
            assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
            assert (ev["pid"], ev["tid"]) == (7, 3)
            assert ev["ts"] >= 0.0
        span, instant = evs
        assert span["ph"] == "X" and span["dur"] == pytest.approx(50.0)
        assert span["ts"] == pytest.approx(1.0)  # relative to tracer epoch
        assert span["args"] == {"rounds": 2}
        assert instant["ph"] == "i" and instant["s"] == "t"

    def test_annotate_is_context_manager(self):
        with annotate("symed.table_step"):
            pass  # must not raise, with or without a live profiler


# ----------------------------------------------------------------- registry


class TestRegistry:
    def test_value_instruments_get_or_create(self):
        m = MetricsRegistry()
        c1 = m.counter("x_total", "help")
        c2 = m.counter("x_total")
        assert c1 is c2
        assert m.counter("x_total", labels={"mode": "raw"}) is not c1

    def test_kind_mismatch_raises(self):
        m = MetricsRegistry()
        m.counter("x_total")
        with pytest.raises(ValueError, match="already registered"):
            m.gauge("x_total")

    def test_callback_duplicates_refused(self):
        m = MetricsRegistry()
        m.counter_fn("cb_total", "h", lambda: 1.0)
        with pytest.raises(ValueError, match="already registered"):
            m.counter_fn("cb_total", "h", lambda: 2.0)

    def test_disabled_registry_hands_out_null(self):
        m = MetricsRegistry(enabled=False)
        h = m.histogram("t")
        assert h is NULL_INSTRUMENT
        h.observe(5)  # all no-ops
        assert m.counter_fn("c", "h", lambda: 1.0) is NULL_INSTRUMENT
        assert m.instruments() == []

    def test_snapshot_shape_and_units(self):
        m = MetricsRegistry()
        m.counter("c_total").inc(3)
        m.gauge("g").set(1.5)
        h = m.histogram("lat_seconds", unit="ns")
        h.observe(2_000_000)  # 2 ms
        snap = m.snapshot()
        assert snap["counters"] == {"c_total": 3.0}
        assert snap["gauges"] == {"g": 1.5}
        d = snap["histograms"]["lat_seconds"]
        assert d["count"] == 1.0
        assert d["sum"] == pytest.approx(2e-3)
        assert 1e-3 < d["p50"] < 4e-3  # scaled to seconds


# --------------------------------------------------------------- exposition


class TestPrometheus:
    def test_exposition_format(self):
        m = MetricsRegistry()
        m.counter("req_total", "requests", labels={"mode": "raw"}).inc(4)
        m.gauge("conns", "open connections").set(2)
        h = m.histogram("lat_seconds", "latency", unit="ns")
        for v in (100, 100, 5000, 90000):
            h.observe(v)
        text = prometheus_text(m)
        lines = text.splitlines()
        assert "# TYPE req_total counter" in lines
        assert 'req_total{mode="raw"} 4' in lines
        assert "# TYPE conns gauge" in lines
        assert "conns 2" in lines
        assert "# HELP lat_seconds latency" in lines
        assert "# TYPE lat_seconds histogram" in lines
        assert "lat_seconds_count 4" in lines
        # derived quantile gauges are grep-able without PromQL
        for q in ("p50", "p99", "p999"):
            assert any(line.startswith(f"lat_seconds_{q} ") for line in lines)

    def test_buckets_cumulative_and_inf_equals_count(self):
        m = MetricsRegistry()
        h = m.histogram("lat_seconds", unit="ns")
        rng = np.random.default_rng(3)
        for v in rng.integers(1, 1 << 30, size=500):
            h.observe(int(v))
        text = prometheus_text(m)
        cums, les = [], []
        for line in text.splitlines():
            if not line.startswith("lat_seconds_bucket"):
                continue
            lbl, val = line.rsplit(" ", 1)
            cums.append(int(val))
            le = lbl.split('le="', 1)[1].rstrip('"}')
            les.append(float("inf") if le == "+Inf" else float(le))
        assert cums == sorted(cums)  # cumulative monotone
        assert les == sorted(les)    # ascending upper bounds
        assert cums[-1] == 500 and les[-1] == float("inf")


# -------------------------------------------------- loopback serving scrape


class _Loopback:
    """A served StreamServer on 127.0.0.1 with a deterministic shutdown."""

    def __init__(self, expect_sessions, **server_kw):
        kw = dict(max_sessions=4, window_cap=32, digitize_every_k=1)
        kw.update(server_kw)
        self.stream = StreamServer(CFG, **kw)
        self.transport = TransportServer(self.stream, port=0)
        self.thread = threading.Thread(
            target=self.transport.serve,
            kwargs={"expect_sessions": expect_sessions}, daemon=True)
        self.thread.start()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.thread.join(timeout=60)
        assert not self.thread.is_alive(), "transport server failed to exit"


def _http_get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.headers.get("Content-Type"), resp.read().decode()


def _prom_value(text, series):
    for line in text.splitlines():
        if line.startswith(series + " "):
            return float(line.rsplit(" ", 1)[1])
    raise AssertionError(f"series {series!r} not in exposition:\n{text}")


class TestServingIntegration:
    def test_loopback_scrape_matches_report(self, rng):
        """Drive real senders over a socket, scrape /metrics over HTTP, and
        require the exposition to agree with the server's own report."""
        obs = Observability(trace_capacity=256)
        streams = {f"obs-{i}": make_stream(rng, 96) for i in range(3)}
        sids = list(streams)
        with _Loopback(expect_sessions=len(sids), obs=obs) as lb:
            exporter = ObsHTTPServer(obs, port=0)
            try:
                client = SenderClient("127.0.0.1", lb.transport.port, CFG,
                                      mode="raw")
                for sid in sids:
                    client.open(sid, session_seed(sid, 5))
                    client.send(sid, streams[sid])
                results = {sid: client.close(sid) for sid in sids}
                assert all(r["t_seen"] == 96 for r in results.values())
                ctype, text = _http_get(exporter.url + "/metrics")
                assert ctype == PROM_CONTENT_TYPE
                _, snap_raw = _http_get(exporter.url + "/metrics.json")
                snap = json.loads(snap_raw)
                _, trace_raw = _http_get(exporter.url + "/trace")
            finally:
                client.shutdown()
                exporter.close()

        rep = lb.stream.report(wall_seconds=1.0)
        # stream-side series agree with the report totals
        assert _prom_value(text, "symed_points_in_total") == rep["points_in"]
        assert _prom_value(text, "symed_symbols_out_total") == rep["symbols_out"]
        assert _prom_value(text, "symed_frames_out_total") == rep["frames_out"]
        assert _prom_value(text, "symed_sessions_opened_total") == len(sids)
        assert _prom_value(text, "symed_sessions_closed_total") == len(sids)
        # transport-side series agree with the transport's own counts
        assert _prom_value(
            text, 'transport_frames_in_total{type="open"}') == len(sids)
        assert _prom_value(
            text, 'transport_frames_in_total{type="close"}') == len(sids)
        assert _prom_value(
            text, "transport_sessions_closed_total") == len(sids)
        assert _prom_value(text, 'transport_frames_in_total{type="data"}') > 0
        assert _prom_value(text, "transport_rx_bytes_total") > 0
        assert _prom_value(text, "transport_tx_bytes_total") > 0
        # the paper's per-symbol latency instrument is populated (close-path
        # flushes have no arrival stamp, so count <= symbols_out)
        lat_count = _prom_value(text, "symed_symbol_latency_seconds_count")
        assert 0 < lat_count <= rep["symbols_out"]
        p99 = _prom_value(text, "symed_symbol_latency_seconds_p99")
        assert p99 > 0.0
        # the JSON snapshot endpoint mirrors the report's obs merge
        assert rep["obs"]["counters"]["symed_points_in_total"] \
            == snap["counters"]["symed_points_in_total"]
        assert snap["histograms"]["symed_symbol_latency_seconds"]["p99"] > 0
        assert snap["spans_recorded"] > 0
        # the trace endpoint serves loadable Chrome trace events
        trace = json.loads(trace_raw)
        names = {ev["name"] for ev in trace["traceEvents"]}
        assert "stream.dispatch" in names or "stream.harvest" in names

    def test_disabled_obs_is_inert_and_bitwise_identical(self, rng):
        """obs=False must cost nothing *and* change nothing: same deltas,
        no report key, shared null instruments."""
        ts = make_stream(rng, 96)
        outs = {}
        for flag in (True, False):
            srv = StreamServer(CFG, max_sessions=2, window_cap=32,
                               digitize_every_k=1, obs=flag)
            srv.open("s0")
            srv.ingest("s0", ts)
            outs[flag] = srv.close("s0")
            rep = srv.report(wall_seconds=1.0)
            if flag:
                assert "obs" in rep
            else:
                assert "obs" not in rep
                assert not srv.obs.enabled
                assert srv.obs is disabled()
        np.testing.assert_array_equal(outs[True]["delta"]["labels"],
                                      outs[False]["delta"]["labels"])
        np.testing.assert_array_equal(outs[True]["delta"]["endpoints"],
                                      outs[False]["delta"]["endpoints"])
        assert outs[True]["symbols"] == outs[False]["symbols"]

    def test_as_obs_normalization(self):
        bundle = Observability()
        assert as_obs(bundle) is bundle
        assert as_obs(False) is disabled()
        fresh_a, fresh_b = as_obs(None), as_obs(True)
        assert fresh_a.enabled and fresh_b.enabled
        assert fresh_a is not fresh_b  # per-server registries never collide

    def test_two_servers_never_collide_on_callbacks(self):
        """Each StreamServer gets its own registry by default, so callback
        registration (which refuses duplicates) stays safe."""
        a = StreamServer(CFG, max_sessions=2, window_cap=32)
        b = StreamServer(CFG, max_sessions=2, window_cap=32)
        assert a.obs is not b.obs


# ------------------------------------------------------------ symlint sweep


class TestSweepInclusion:
    def test_obs_files_inside_default_sweep(self):
        """src/repro/obs is covered by the symlint sweep, so the hot-path
        contract (no device syncs in recording paths) is machine-checked."""
        from repro.analysis.cli import find_root
        from repro.analysis.engine import DEFAULT_SWEEP, load_project

        root = find_root(Path(__file__).resolve().parent)
        project = load_project(root, [root / p for p in DEFAULT_SWEEP
                                      if (root / p).exists()])
        rels = set(project.files)
        for mod in ("metrics", "tracing", "export", "__init__"):
            assert f"src/repro/obs/{mod}.py" in rels
