"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


class TestEwmaKernel:
    @pytest.mark.parametrize("b,t", [(1, 64), (3, 300), (8, 1024), (17, 257), (256, 96)])
    @pytest.mark.parametrize("alpha", [0.01, 0.05, 0.2])
    def test_matches_ref(self, b, t, alpha):
        ts = jnp.asarray(RNG.normal(0, 2, (b, t)), jnp.float32)
        m1, v1 = ops.ewma_scan(ts, alpha)
        m2, v2 = ref.ewma_scan_ref(ts, alpha)
        np.testing.assert_allclose(m1, m2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("block_t", [64, 128, 512])
    def test_block_shapes(self, block_t):
        from repro.kernels.ewma import ewma_scan_pallas

        ts = jnp.asarray(RNG.normal(0, 1, (4, 777)), jnp.float32)
        m1, v1 = ewma_scan_pallas(ts, 0.02, block_t=block_t, interpret=True)
        m2, v2 = ref.ewma_scan_ref(ts, 0.02)
        np.testing.assert_allclose(m1, m2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)

    def test_paper_init(self):
        ts = jnp.asarray(RNG.normal(0, 1, (2, 50)), jnp.float32)
        m, v = ops.ewma_scan(ts, 0.02)
        np.testing.assert_allclose(m[:, 0], ts[:, 0], rtol=1e-6)
        np.testing.assert_allclose(v[:, 0], 1.0, rtol=1e-6)

    def test_large_values(self):
        """Chunked rescaling keeps f32 precision for offset streams."""
        ts = jnp.asarray(RNG.normal(1000, 5, (2, 512)), jnp.float32)
        m1, v1 = ops.ewma_scan(ts, 0.05)
        m2, v2 = ref.ewma_scan_ref(ts, 0.05)
        np.testing.assert_allclose(m1, m2, rtol=1e-4)
        np.testing.assert_allclose(v1, v2, rtol=1e-3, atol=1e-2)


class TestKmeansKernel:
    @pytest.mark.parametrize("s,n,d,k", [
        (1, 16, 2, 3), (3, 50, 2, 7), (2, 200, 2, 100), (1, 64, 8, 5),
        (2, 128, 128, 16), (1, 300, 2, 1),
    ])
    def test_matches_ref(self, s, n, d, k):
        x = jnp.asarray(RNG.normal(size=(s, n, d)), jnp.float32)
        mask = jnp.asarray(RNG.random((s, n)) > 0.25, jnp.float32)
        c = jnp.asarray(RNG.normal(size=(s, k, d)), jnp.float32)
        act = jnp.asarray(RNG.random((s, k)) > 0.2, jnp.float32)
        act = act.at[:, 0].set(1.0)  # at least one active center
        l1, s1, c1 = ops.kmeans_assign(x, mask, c, act)
        l2, s2, c2 = ref.kmeans_assign_ref(x, mask, c, act)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-6)

    def test_block_n_tiling(self):
        from repro.kernels.kmeans import kmeans_assign_pallas

        x = jnp.asarray(RNG.normal(size=(2, 500, 2)), jnp.float32)
        mask = jnp.ones((2, 500), jnp.float32)
        c = jnp.asarray(RNG.normal(size=(2, 10, 2)), jnp.float32)
        act = jnp.ones((2, 10), jnp.float32)
        l1, s1, c1 = kmeans_assign_pallas(x, mask, c, act, block_n=128,
                                          interpret=True)
        l2, s2, c2 = ref.kmeans_assign_ref(x, mask, c, act)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)

    def test_lloyd_step_contract(self):
        """new_centers from (sums, counts) must equal masked means."""
        x = jnp.asarray(RNG.normal(size=(1, 80, 2)), jnp.float32)
        mask = jnp.ones((1, 80), jnp.float32)
        c = jnp.asarray(RNG.normal(size=(1, 4, 2)), jnp.float32)
        act = jnp.ones((1, 4), jnp.float32)
        labels, sums, counts = ops.kmeans_assign(x, mask, c, act)
        for j in range(4):
            sel = np.asarray(labels[0]) == j
            if sel.any():
                np.testing.assert_allclose(
                    np.asarray(sums[0, j] / counts[0, j]),
                    np.asarray(x[0])[sel].mean(0), rtol=1e-4)


class TestDtwKernel:
    @pytest.mark.parametrize("b,n", [(1, 32), (4, 150), (8, 128), (3, 257), (16, 64)])
    def test_matches_ref_full(self, b, n):
        x = jnp.asarray(RNG.normal(size=(b, n)).cumsum(1), jnp.float32)
        y = x + jnp.asarray(RNG.normal(0, 0.3, (b, n)), jnp.float32)
        d1 = ops.dtw(x, y)
        d2 = ref.dtw_batch_ref(x, y)
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("band", [5, 20, 64])
    def test_matches_ref_banded(self, band):
        x = jnp.asarray(RNG.normal(size=(4, 200)).cumsum(1), jnp.float32)
        y = x + jnp.asarray(RNG.normal(0, 0.2, (4, 200)), jnp.float32)
        d1 = ops.dtw(x, y, band=band)
        d2 = ref.dtw_batch_ref(x, y, band=band)
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)

    def test_identity_zero(self):
        x = jnp.asarray(RNG.normal(size=(3, 90)), jnp.float32)
        np.testing.assert_allclose(ops.dtw(x, x), 0.0, atol=1e-4)

    def test_band_zero_matches_ref(self):
        """Regression: the degenerate band=0 corridor (diagonal-only path)
        must agree between kernel and ref -- and stay finite, not leak the
        _BIG unreachable-cell sentinel."""
        x = jnp.asarray(RNG.normal(size=(3, 96)).cumsum(1), jnp.float32)
        y = x + jnp.asarray(RNG.normal(0, 0.2, (3, 96)), jnp.float32)
        d1 = np.asarray(ops.dtw(x, y, band=0))
        d2 = np.asarray(ref.dtw_batch_ref(x, y, band=0))
        assert (d1 < 1e10).all()
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)
        # band=0 == pointwise L2 on equal-length pairs
        eu = np.sqrt(np.sum((np.asarray(x) - np.asarray(y)) ** 2, axis=1))
        np.testing.assert_allclose(d1, eu, rtol=1e-4)

    def test_band_tightens_distance(self):
        """Narrower band restricts warping -> distance monotone non-decreasing."""
        x = jnp.asarray(RNG.normal(size=(2, 100)).cumsum(1), jnp.float32)
        y = jnp.asarray(RNG.normal(size=(2, 100)).cumsum(1), jnp.float32)
        d_full = np.asarray(ops.dtw(x, y))
        d_b10 = np.asarray(ops.dtw(x, y, band=10))
        d_b3 = np.asarray(ops.dtw(x, y, band=3))
        assert (d_b3 >= d_b10 - 1e-4).all()
        assert (d_b10 >= d_full - 1e-4).all()


class TestMaskedKmeansTable:
    """Slot-table Lloyd loop (``core.digitize.masked_kmeans_table``): the
    vmapped reference path must be bitwise-equal to per-slot
    ``masked_kmeans``; the fused-kernel path matches to float tolerance with
    masked labels zeroed (the documented contract that keeps
    ``use_kernel=False`` on bitwise-checked CPU deployments)."""

    def _problem(self, s, n_max, k_max, seed):
        rng = np.random.default_rng(seed)
        coords = jnp.asarray(rng.normal(size=(s, n_max, 2)), jnp.float32)
        n_valid = rng.integers(1, n_max + 1, size=(s,))
        mask = jnp.asarray(np.arange(n_max)[None, :] < n_valid[:, None])
        k = jnp.asarray(rng.integers(1, k_max + 1, size=(s,)), jnp.int32)
        c_init = jnp.asarray(rng.normal(size=(s, k_max, 2)), jnp.float32)
        return coords, mask, c_init, k

    @pytest.mark.parametrize("s,n_max,k_max", [(1, 16, 4), (4, 64, 8), (7, 33, 5)])
    def test_ref_path_bitwise_vs_per_slot(self, s, n_max, k_max):
        from repro.core.digitize import masked_kmeans, masked_kmeans_table

        coords, mask, c_init, k = self._problem(s, n_max, k_max, 11)
        ct, lt = masked_kmeans_table(coords, mask, c_init, k, iters=5)
        cv, lv = jax.vmap(
            lambda co, m, ci, kk: masked_kmeans(co, m, ci, kk, 5)
        )(coords, mask, c_init, k)
        np.testing.assert_array_equal(np.asarray(lt), np.asarray(lv))
        np.testing.assert_array_equal(np.asarray(ct), np.asarray(cv))

    @pytest.mark.parametrize("s,n_max,k_max", [(2, 32, 4), (5, 48, 8)])
    def test_kernel_path_matches_ref(self, s, n_max, k_max):
        from repro.core.digitize import masked_kmeans_table

        coords, mask, c_init, k = self._problem(s, n_max, k_max, 23)
        c_ref, l_ref = masked_kmeans_table(coords, mask, c_init, k, iters=5)
        c_krn, l_krn = masked_kmeans_table(coords, mask, c_init, k, iters=5,
                                           use_kernel=True)
        np.testing.assert_allclose(np.asarray(c_krn), np.asarray(c_ref),
                                   rtol=1e-5, atol=1e-5)
        valid = np.asarray(mask, bool)
        np.testing.assert_array_equal(
            np.asarray(l_krn)[valid], np.asarray(l_ref)[valid])
        assert (np.asarray(l_krn)[~valid] == 0).all()


class TestDigitizeSpanTable:
    """The fused table digitize (``digitize_span_table`` /
    ``digitizer_table_step``) against vmapped per-slot ``digitize_span``:
    bitwise on every DigitizerState leaf and emitted symbol, including
    resumption across split spans (the streaming-cadence shape)."""

    CFGK = dict(tol=0.5, scl=1.0, k_min=3, k_max_active=8, lloyd_iters=5)

    def _table(self, s, n_max, k_max, seed):
        from repro.core.digitize import digitizer_init

        rng = np.random.default_rng(seed)
        keys = jax.random.split(jax.random.key(seed), s)
        state = jax.vmap(lambda kk: digitizer_init(n_max, k_max, kk))(keys)
        lengths = jnp.asarray(rng.integers(1, 9, size=(s, n_max)), jnp.float32)
        incs = jnp.asarray(rng.normal(0, 2, size=(s, n_max)), jnp.float32)
        hi = jnp.asarray(rng.integers(0, n_max + 1, size=(s,)), jnp.int32)
        return state, lengths, incs, hi

    def _assert_state_equal(self, a, b, msg):
        for name in a._fields:
            la, lb = getattr(a, name), getattr(b, name)
            if name == "key":
                la, lb = jax.random.key_data(la), jax.random.key_data(lb)
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=f"{msg}: {name}")

    @pytest.mark.parametrize("s,n_max", [(1, 12), (4, 24), (6, 16)])
    def test_bitwise_vs_vmapped_per_slot(self, s, n_max):
        from repro.core.digitize import digitize_span, digitize_span_table

        state, lengths, incs, hi = self._table(s, n_max, 8, 31 + s)
        lo = jnp.zeros((s,), jnp.int32)
        st_t, sy_t = digitize_span_table(state, lengths, incs, lo, hi,
                                         **self.CFGK)
        st_v, sy_v = jax.vmap(
            lambda st, le, ic, l, h: digitize_span(st, le, ic, l, h,
                                                   **self.CFGK)
        )(state, lengths, incs, lo, hi)
        self._assert_state_equal(st_t, st_v, f"s={s}")
        np.testing.assert_array_equal(np.asarray(sy_t), np.asarray(sy_v))

    def test_split_spans_resume_bitwise(self):
        """Digesting [0, mid) then [mid, hi) must equal one [0, hi) pass --
        per lane, with ragged mids (the arrival-cadence property)."""
        from repro.core.digitize import digitize_span_table

        s, n_max = 5, 20
        state, lengths, incs, hi = self._table(s, n_max, 8, 99)
        rng = np.random.default_rng(7)
        mid = jnp.asarray(
            [int(rng.integers(0, int(h) + 1)) for h in np.asarray(hi)],
            jnp.int32)
        lo = jnp.zeros((s,), jnp.int32)
        st_one, sy_one = digitize_span_table(state, lengths, incs, lo, hi,
                                             **self.CFGK)
        st_a, sy_a = digitize_span_table(state, lengths, incs, lo, mid,
                                         **self.CFGK)
        st_b, sy_b = digitize_span_table(st_a, lengths, incs, mid, hi,
                                         **self.CFGK)
        self._assert_state_equal(st_b, st_one, "split-resume")
        idx = np.arange(n_max)[None, :]
        in_a = idx < np.asarray(mid)[:, None]
        merged = np.where(in_a, np.asarray(sy_a), np.asarray(sy_b))
        np.testing.assert_array_equal(merged, np.asarray(sy_one))
