"""Checkpoint system: atomicity, roundtrip, elastic resharding, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    CheckpointManager, latest_step, restore_checkpoint, save_checkpoint,
)


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {
            "embed": jax.random.normal(k, (64, 16)),
            "blocks": (
                {"wq": jax.random.normal(k, (4, 16, 16)), "ln1": jnp.ones((4, 16))},
            ),
        },
        "step": jnp.asarray(7, jnp.int32),
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        state = _state()
        save_checkpoint(tmp_path, 7, state)
        restored, manifest = restore_checkpoint(tmp_path, 7, state)
        assert manifest["step"] == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_into_abstract_target(self, tmp_path):
        state = _state()
        save_checkpoint(tmp_path, 1, state)
        target = jax.eval_shape(lambda: _state())
        restored, _ = restore_checkpoint(tmp_path, 1, target)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["embed"]),
            np.asarray(state["params"]["embed"]))

    def test_latest_and_gc(self, tmp_path):
        state = _state()
        for s in (10, 20, 30, 40):
            save_checkpoint(tmp_path, s, state, keep=2)
        assert latest_step(tmp_path) == 40
        kept = sorted(p.name for p in tmp_path.glob("ckpt_*"))
        assert kept == ["ckpt_00000030", "ckpt_00000040"]

    def test_atomic_no_tmp_left(self, tmp_path):
        save_checkpoint(tmp_path, 5, _state())
        assert not list(tmp_path.glob(".tmp-*"))

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 3, _state())
        bad = _state()
        bad["params"]["embed"] = jnp.zeros((65, 16))
        with pytest.raises(ValueError, match="shape"):
            restore_checkpoint(tmp_path, 3, bad)

    def test_manager_resume(self, tmp_path):
        mgr = CheckpointManager(tmp_path, every=2)
        state = _state()
        assert mgr.maybe_save(1, state) is None
        assert mgr.maybe_save(2, state) is not None
        restored, manifest = mgr.restore_latest(state)
        assert manifest["step"] == 2

    def test_empty_dir_restore(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        restored, manifest = mgr.restore_latest(_state())
        assert restored is None and manifest is None


class TestElasticReshard:
    """Restore onto a different device layout (subprocess: needs >1 device)."""

    def test_reshard_subprocess(self, tmp_path):
        import subprocess
        import sys

        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import save_checkpoint, restore_checkpoint

state = {{"w": jnp.arange(64.0).reshape(8, 8)}}
save_checkpoint(r"{tmp_path}", 1, state)

# "new cluster": restore onto a 4-device mesh (elastic downsize), sharded
from repro.utils.jax_compat import make_mesh
mesh = make_mesh((4,), ("data",), devices=jax.devices()[:4])
shard = {{"w": NamedSharding(mesh, P("data", None))}}
restored, _ = restore_checkpoint(r"{tmp_path}", 1, state, shardings=shard)
assert restored["w"].sharding.num_devices == 4
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
print("RESHARD_OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, env=env, cwd=os.path.dirname(
                                 os.path.dirname(os.path.abspath(__file__))))
        assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
