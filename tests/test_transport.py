"""Wire-transport battery: framing edge cases + loopback socket equivalence.

Two layers:

* ``TestFraming`` -- the codec alone.  TCP delivers byte *streams*, so the
  property battery re-slices a multi-frame byte string at random boundaries
  and requires the ``FrameDecoder`` to reassemble the identical frame
  sequence (partial length prefixes, frames split mid-payload, many frames
  per read).
* ``TestLoopback`` -- a real ``TransportServer`` on 127.0.0.1 with
  ``SenderClient``s in the test process.  The service contract carries over
  the socket: for raw-in and compressed-in senders alike, the concatenated
  DELTA frames plus the CLOSED closing frame are bitwise-equal to one-shot
  ``symed_encode`` -- including runs where the slot table autoscaled, and
  with sessions interleaving DATA over one connection.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import make_stream

from repro.core.compress import compress_stream
from repro.core.receiver import (
    delta_frame_bytes, pack_delta_frame, pack_piece_tuples,
    unpack_delta_frame, unpack_piece_tuples,
)
from repro.core.symed import SymEDConfig, symed_encode
from repro.launch.stream import StreamServer
from repro.launch.transport import (
    CLOSE, DATA, DELTA, ERROR, OPEN, FrameDecoder, SenderClient,
    TransportServer, decode_close, decode_data_pieces, decode_data_raw,
    encode_close, encode_data_pieces, encode_data_raw, encode_delta,
    encode_error, encode_open, session_seed,
)

CFG = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                  len_max=32, n_max=64, lloyd_iters=5)


# ------------------------------------------------------------------ framing


class TestFraming:
    def test_frame_roundtrip_each_type(self):
        dec = FrameDecoder()
        w = np.linspace(-1, 1, 7, dtype=np.float32)
        eps = np.asarray([0.5, -2.0], np.float32)
        steps = np.asarray([3, 9], np.int32)
        wire = (encode_open("sess-a", 1, 0xDEADBEEF)
                + encode_data_raw("sess-a", w)
                + encode_data_pieces("sess-a", 1.5, 17, eps, steps)
                + encode_close("sess-a", 17, -2.5)
                + encode_delta("sess-a", [1, 2], [0.1, 0.2])
                + encode_error("sess-a", "nope"))
        frames = dec.feed(wire)
        assert [f.type for f in frames] == [OPEN, DATA, DATA, CLOSE, DELTA,
                                            ERROR]
        assert all(f.sid == "sess-a" for f in frames)
        np.testing.assert_array_equal(decode_data_raw(frames[1].payload), w)
        t0, t_seen, e, s = decode_data_pieces(frames[2].payload)
        assert (t0, t_seen) == (1.5, 17)
        np.testing.assert_array_equal(e, eps)
        np.testing.assert_array_equal(s, steps)
        assert decode_close(frames[3].payload) == (17, -2.5)
        labels, endpoints = unpack_delta_frame(frames[4].payload)
        np.testing.assert_array_equal(labels, [1, 2])
        np.testing.assert_array_equal(endpoints,
                                      np.asarray([0.1, 0.2], np.float32))

    @given(st.integers(0, 31))
    @settings(max_examples=16, deadline=None)
    def test_partial_frames_across_recv_boundaries(self, seed):
        """Any re-slicing of the byte stream decodes to the same frames --
        split mid-length-prefix, mid-sid, mid-payload, or many per read."""
        rng = np.random.default_rng(7100 + seed)
        frames_in = []
        wire = b""
        for i in range(int(rng.integers(2, 8))):
            sid = f"s{int(rng.integers(0, 4))}"
            kind = int(rng.integers(0, 3))
            if kind == 0:
                wire += encode_open(sid, i % 2, i)
                frames_in.append((OPEN, sid))
            elif kind == 1:
                w = rng.normal(size=int(rng.integers(1, 40))).astype(np.float32)
                wire += encode_data_raw(sid, w)
                frames_in.append((DATA, sid))
            else:
                wire += encode_close(sid, int(rng.integers(0, 100)))
                frames_in.append((CLOSE, sid))
        dec = FrameDecoder()
        out = []
        pos = 0
        while pos < len(wire):
            n = int(rng.integers(1, 11))
            out.extend(dec.feed(wire[pos: pos + n]))
            pos += n
        assert [(f.type, f.sid) for f in out] == frames_in
        assert not dec.feed(b"")  # nothing buffered mid-frame

    def test_bad_length_prefix_rejected(self):
        dec = FrameDecoder()
        with pytest.raises(ValueError, match="bad frame length"):
            dec.feed(b"\xff\xff\xff\xff rest")
        with pytest.raises(ValueError, match="bad frame length"):
            FrameDecoder().feed(b"\x00\x00\x00\x01x")

    def test_delta_frame_bytes_matches_packed_length(self):
        """The accounted DELTA bytes are the *actual* wire bytes."""
        for n in (0, 1, 7):
            buf = pack_delta_frame(np.arange(n), np.arange(n, dtype=np.float32))
            assert len(buf) == float(delta_frame_bytes(n))

    def test_piece_tuples_roundtrip(self):
        eps = np.asarray([1.25, -3.5, 0.0], np.float32)
        steps = np.asarray([5, 111, 65000], np.int32)
        e, s = unpack_piece_tuples(pack_piece_tuples(eps, steps), 3)
        np.testing.assert_array_equal(e, eps)
        np.testing.assert_array_equal(s, steps)


# ----------------------------------------------------------------- loopback


class _Loopback:
    """A served StreamServer on 127.0.0.1 with a deterministic shutdown."""

    def __init__(self, expect_sessions, **server_kw):
        kw = dict(max_sessions=4, window_cap=32, digitize_every_k=1)
        kw.update(server_kw)
        self.stream = StreamServer(CFG, **kw)
        self.transport = TransportServer(self.stream, port=0)
        self.thread = threading.Thread(
            target=self.transport.serve,
            kwargs={"expect_sessions": expect_sessions}, daemon=True)
        self.thread.start()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.thread.join(timeout=60)
        assert not self.thread.is_alive(), "transport server failed to exit"


def _feed_and_close(client, sids, streams, rng, lo=1, hi=49):
    """Deliver each stream in ragged interleaved arrivals, then close all."""
    cursors = {sid: 0 for sid in sids}
    while any(cursors[sid] < len(streams[sid]) for sid in sids):
        for sid in sids:
            if cursors[sid] >= len(streams[sid]):
                continue
            n = int(rng.integers(lo, hi))
            client.send(sid, streams[sid][cursors[sid]: cursors[sid] + n])
            cursors[sid] += n
    return {sid: client.close(sid) for sid in sids}


def _assert_matches_encode(client, sid, ts, seed, res):
    labels, endpoints = client.delta_concat(sid)
    key = jax.random.key(session_seed(sid, seed))
    ref = symed_encode(jnp.asarray(ts[: res["t_seen"]]), CFG, key,
                       reconstruct=False)
    n = int(ref["n_pieces"])
    assert res["n_pieces"] == n, sid
    np.testing.assert_array_equal(
        labels, np.asarray(ref["symbols_online"])[:n],
        err_msg=f"{sid}: delta labels over the wire")
    ev = compress_stream(jnp.asarray(ts[: res["t_seen"]]), tol=CFG.tol,
                         len_max=CFG.len_max, alpha=CFG.alpha)
    want_eps = list(np.asarray(ev["endpoint"])[np.asarray(ev["emit"])])
    if bool(ev["tail"].emit):
        want_eps.append(float(ev["tail"].endpoint))
    np.testing.assert_array_equal(
        endpoints, np.asarray(want_eps, np.float32),
        err_msg=f"{sid}: delta endpoints over the wire")


@pytest.mark.parametrize("mode", ["raw", "pieces"])
def test_loopback_bitwise(mode, rng):
    """Interleaved sessions over one socket, both transport modes: the
    returned delta stream is bitwise-equal to one-shot symed_encode."""
    seed = 5
    streams = {f"t-{mode}-{i}": make_stream(rng, 128) for i in range(3)}
    sids = list(streams)
    with _Loopback(expect_sessions=len(sids)) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG, mode=mode)
        for sid in sids:
            client.open(sid, session_seed(sid, seed))
        results = _feed_and_close(client, sids, streams, rng)
        for sid in sids:
            assert results[sid]["t_seen"] == 128
            _assert_matches_encode(client, sid, streams[sid], seed,
                                   results[sid])
        client.shutdown()


def test_loopback_pieces_compresses_wire(rng):
    """Compressed-in mode puts measurably less than 4 B/point on the wire,
    and the server's wire_in accounting sees it."""
    streams = {f"c-{i}": make_stream(rng, 160) for i in range(2)}
    with _Loopback(expect_sessions=2) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG,
                              mode="pieces")
        for sid in streams:
            client.open(sid, session_seed(sid, 0))
        results = _feed_and_close(client, list(streams), streams, rng,
                                  lo=20, hi=41)
        client.shutdown()
    points = sum(r["t_seen"] for r in results.values())
    assert client.payload_bytes < 4.0 * points, (
        client.payload_bytes, 4.0 * points)
    rep = lb.stream.report(1.0)
    assert 0 < rep["wire_in_ratio"] < 1.0, rep["wire_in_ratio"]
    # StreamServer books the logical hello (4 B at open) while the client
    # books the CLOSE header -- the two counts differ only by that per-
    # session scaffolding
    assert abs(rep["wire_in_bytes"] - client.payload_bytes) <= 2 * len(streams)
    summ = lb.transport.summary()
    assert summ["pieces_ratio"] < 1.0
    assert summ["payload_bytes_pieces"] == pytest.approx(client.payload_bytes)


def test_raw_and_pieces_modes_agree(rng):
    """The same stream + digitizer seed through either transport mode yields
    the identical symbol stream (the compressed-in scatter reproduces the
    raw-mode receiver state bitwise)."""
    ts = make_stream(rng, 128)
    out = {}
    for mode in ("raw", "pieces"):
        with _Loopback(expect_sessions=1) as lb:
            client = SenderClient("127.0.0.1", lb.transport.port, CFG,
                                  mode=mode)
            client.open("same", 1234)
            for c in range(0, 128, 24):
                client.send("same", ts[c: c + 24])
            res = client.close("same")
            out[mode] = (res["n_pieces"], *client.delta_concat("same"))
            client.shutdown()
    assert out["raw"][0] == out["pieces"][0]
    np.testing.assert_array_equal(out["raw"][1], out["pieces"][1])
    np.testing.assert_array_equal(out["raw"][2], out["pieces"][2])


def test_close_unknown_session_keeps_serving(rng):
    """A CLOSE for a session the receiver never saw earns an ERROR frame;
    the connection and the server survive it."""
    ts = make_stream(rng, 96)
    with _Loopback(expect_sessions=1) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG, mode="raw")
        client.sock.sendall(encode_close("ghost"))
        with pytest.raises(RuntimeError, match="unknown session"):
            client._drain(block=True)
        # same connection, same decoder: a real session still round-trips
        client.open("real", session_seed("real", 0))
        client.send("real", ts)
        res = client.close("real")
        _assert_matches_encode(client, "real", ts, 0, res)
        client.shutdown()


def test_duplicate_open_rejected(rng):
    with _Loopback(expect_sessions=1) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG, mode="raw")
        client.open("dup", 0)
        client.sock.sendall(encode_open("dup", 0, 0))
        with pytest.raises(RuntimeError, match="already open"):
            client._drain(block=True)
        client.send("dup", make_stream(rng, 96))
        client.close("dup")
        client.shutdown()


def test_eviction_over_transport(rng):
    """LRU eviction reaches the sender as an unsolicited CLOSED(evicted):
    close() returns the parked prefix result instead of erroring, the
    prefix delta stream verifies bitwise, and the client's other sessions
    are unaffected."""
    seed = 3
    streams = {f"e-{i}": make_stream(rng, 96) for i in range(3)}
    sids = list(streams)

    def wait_delta(client, sid):
        # sync point: the server has ingested this session's data (DATA is
        # staged within a tick; LRU order needs the ingest to have happened
        # before the eviction-triggering OPEN arrives)
        while not client._sessions[sid].deltas:
            client._drain(block=True)

    with _Loopback(expect_sessions=3, max_sessions=2,
                   evict_idle=True) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG, mode="raw")
        client.open(sids[0], session_seed(sids[0], seed))
        client.open(sids[1], session_seed(sids[1], seed))
        client.send(sids[0], streams[sids[0]][:40])
        wait_delta(client, sids[0])
        client.send(sids[1], streams[sids[1]])
        wait_delta(client, sids[1])
        client.open(sids[2], session_seed(sids[2], seed))  # evicts e-0 (LRU)
        client.send(sids[2], streams[sids[2]])
        res0 = client.close(sids[0])   # already settled by the eviction
        assert res0["evicted"] and res0["t_seen"] == 40
        _assert_matches_encode(client, sids[0], streams[sids[0]], seed, res0)
        for sid in sids[1:]:
            res = client.close(sid)
            assert not res["evicted"]
            _assert_matches_encode(client, sid, streams[sid], seed, res)
        client.shutdown()
    assert lb.stream.totals["evicted"] == 1


def test_malformed_payload_drops_conn_not_server(rng):
    """Garbage inside a well-framed body must not kill the serve loop: the
    offending connection is dropped, other tenants keep streaming."""
    import struct as _struct

    from repro.launch.transport import OPEN as _OPEN

    ts = make_stream(rng, 96)
    with _Loopback(expect_sessions=1) as lb:
        bad = SenderClient("127.0.0.1", lb.transport.port, CFG, mode="raw")
        # OPEN frame with a truncated payload (sid present, body too short)
        sid_b = b"bad"
        body = _struct.pack("!BB", _OPEN, len(sid_b)) + sid_b + b"\x01"
        bad.sock.sendall(_struct.pack("!I", len(body)) + body)
        good = SenderClient("127.0.0.1", lb.transport.port, CFG, mode="raw")
        good.open("good", session_seed("good", 0))
        good.send("good", ts)
        res = good.close("good")
        _assert_matches_encode(good, "good", ts, 0, res)
        good.shutdown()
        bad.shutdown()


def test_loopback_autoscale_resizes_preserve_deltas(rng):
    """Sessions arriving over the wire force table grows (1 -> 4) and the
    drain-down forces shrinks; every session's delta stream stays bitwise."""
    seed = 9
    streams = {f"a-{i}": make_stream(rng, 96) for i in range(4)}
    sids = list(streams)
    # shrink_patience=1: with only 4 sessions the drain gives just two
    # low-occupancy closes, so the default patience would (correctly) hold
    # capacity at 4.  Patience semantics are covered by the hysteresis
    # battery in test_stream_service.py; here we want the resizes to fire
    # so the wire-level delta streams are exercised across them.
    with _Loopback(expect_sessions=4, max_sessions=4, autoscale=True,
                   min_slots=1, shrink_patience=1) as lb:
        client = SenderClient("127.0.0.1", lb.transport.port, CFG,
                              mode="pieces")
        for sid in sids:
            client.open(sid, session_seed(sid, seed))
        results = _feed_and_close(client, sids, streams, rng, lo=16, hi=33)
        for sid in sids:
            _assert_matches_encode(client, sid, streams[sid], seed,
                                   results[sid])
        client.shutdown()
    assert lb.stream.totals["grows"] >= 2, lb.stream.totals
    assert lb.stream.totals["shrinks"] >= 1, lb.stream.totals
    assert lb.stream.capacity == 1
