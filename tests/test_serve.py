"""Serving-driver smoke tests: unified prefix accounting for the KV cache.

``launch.serve`` allocates the decode KV cache (``max_len``) from the same
rule ``prefill`` uses for ``s_total`` -- the prefix length is derived from
the frontend input that actually gets prepended to the decoder sequence,
not from string-matching the frontend name.  A miscount doesn't crash: XLA
*clamps* the out-of-range cache writes, silently corrupting the last slot.
These tests pin the accounting for every frontend shape (none / patches /
frames) and run the reduced serve loop end-to-end with a generation longer
than the prompt (the regime where an undercounted ``max_len`` overruns).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import frontend_inputs, serve


@pytest.mark.parametrize("arch,expect_prefix", [
    ("olmoe-1b-7b", 0),       # frontend "none"
    ("paligemma-3b", 8),      # "patches": prefix_embeds prepend to the decoder
    ("whisper-small", 0),     # "frames": cross-attended memory, no prepend
])
def test_prefix_accounting_matches_prefill(arch, expect_prefix):
    """frontend_inputs' prefix length equals what prefill adds to s_total."""
    cfg = get_config(arch).reduced()
    kw, prefix_len = frontend_inputs(cfg, batch=2)
    assert prefix_len == expect_prefix
    want = kw["prefix_embeds"].shape[1] if "prefix_embeds" in kw else 0
    assert prefix_len == want


@pytest.mark.parametrize("arch", ["whisper-small", "paligemma-3b"])
def test_serve_long_generation_smoke(arch):
    """Reduced-config serve with gen > prompt_len: the decode loop must stay
    inside the KV allocation (serve asserts pos + steps <= max_len) and
    produce the requested token grid."""
    cfg = get_config(arch).reduced()
    tokens, stats = serve(cfg, batch=2, prompt_len=6, gen=10)
    assert tokens.shape == (2, 10)
    toks = np.asarray(tokens)
    assert ((toks >= 0) & (toks < cfg.vocab)).all()
    for v in stats.values():
        assert np.isfinite(v)
