"""End-to-end behaviour tests: the paper's system + the training framework.

Covers: full SymED pipeline claims (paper Sec. 4), fault-tolerant training
(fail -> restore -> continue), the symbol data pipeline, the 512-device
dry-run machinery (subprocess), and the int8 gradient compression math.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_stream

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestPaperClaims:
    """Trend-level reproduction of the paper's evaluation (Sec. 4.3)."""

    def test_symed_follows_abba_error_curve(self, rng):
        """Fig. 5a: SymED symbol RE tracks ABBA's within a small factor."""
        from repro.core import SymEDConfig, abba_encode, dtw_ref, symed_encode
        from repro.core.reconstruct import reconstruct_from_symbols

        ratios = []
        for seed in range(3):
            ts = make_stream(np.random.default_rng(seed), 600)
            out = symed_encode(
                jnp.asarray(ts),
                SymEDConfig(tol=0.5, alpha=0.01, n_max=256, k_max=32, len_max=128),
                jax.random.key(0))
            res = abba_encode(jnp.asarray(ts), n_max=256, tol=0.5, len_max=128,
                              k_max=32)
            rec_n = reconstruct_from_symbols(
                res.labels, res.centers, res.n_pieces,
                jnp.float32((ts[0] - float(res.mean)) / float(res.std)), len(ts))
            re_abba = float(dtw_ref(jnp.asarray(ts), rec_n * res.std + res.mean))
            ratios.append(float(out["re_symbols"]) / max(re_abba, 1e-6))
        assert 0.3 < np.mean(ratios) < 4.0

    def test_online_beats_offline_reconstruction(self, rng):
        """Paper headline: piece RE below symbol RE on average."""
        from repro.core import SymEDConfig, symed_encode

        cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=256, k_max=32, len_max=128)
        rp, rs = [], []
        for seed in range(5):
            ts = jnp.asarray(make_stream(np.random.default_rng(seed), 600))
            out = symed_encode(ts, cfg, jax.random.key(0))
            rp.append(float(out["re_pieces"]))
            rs.append(float(out["re_symbols"]))
        assert np.mean(rp) < np.mean(rs)

    def test_wire_traffic_markedly_below_raw(self, rng):
        from repro.core import SymEDConfig, symed_encode

        ts = jnp.asarray(make_stream(rng, 1000))
        cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=512, k_max=32, len_max=256)
        out = symed_encode(ts, cfg, jax.random.key(0), reconstruct=False)
        assert float(out["wire_bytes"]) < 0.35 * 4 * 1000  # << raw


class TestTrainingFaultTolerance:
    def test_fail_restore_continue(self, tmp_path):
        """Simulated node failure mid-run; restart resumes from checkpoint
        and reaches the target step count."""
        sys.path.insert(0, os.path.join(REPO, "examples"))
        from train_lm import small_config

        from repro.launch.train import train_loop

        cfg = small_config(vocab=128)
        with pytest.raises(RuntimeError, match="simulated node failure"):
            train_loop(cfg, steps=6, batch=2, seq=64, ckpt_dir=str(tmp_path),
                       ckpt_every=2, fail_at_step=4, log_every=100)
        state, report = train_loop(cfg, steps=6, batch=2, seq=64,
                                   ckpt_dir=str(tmp_path), ckpt_every=2,
                                   log_every=100)
        assert int(state["step"]) == 6
        assert np.isfinite(report["loss_history"]).all()

    def test_loss_decreases(self):
        sys.path.insert(0, os.path.join(REPO, "examples"))
        from train_lm import small_config

        from repro.launch.train import train_loop

        cfg = small_config(vocab=128)
        _, report = train_loop(cfg, steps=20, batch=4, seq=128, log_every=100)
        h = report["loss_history"]
        assert np.mean(h[-3:]) < np.mean(h[:3]) - 0.1


class TestDataPipeline:
    def test_symbol_batches(self):
        from repro.core.symed import SymEDConfig
        from repro.data import SymbolPipeline, SymbolTokenizer, TokenBatcher

        tok = SymbolTokenizer(k_max=32)
        pipe = SymbolPipeline(
            SymEDConfig(tol=0.5, alpha=0.02, n_max=128, k_max=32, len_max=128),
            tok, stream_len=512, slab=8)
        batcher = TokenBatcher(pipe, batch=4, seq_len=64)
        it = iter(batcher)
        b = next(it)
        batcher.close()
        assert b.shape == (4, 64) and b.dtype == np.int32
        assert (b >= 0).all() and (b < tok.vocab_size).all()


class TestGradCompression:
    def test_quantized_psum_math(self):
        """int8 round-trip error bounded by scale/127; error feedback carries
        the residual."""
        from jax.sharding import PartitionSpec as P

        from repro.train.steps import quantized_psum_mean
        from repro.utils.jax_compat import make_mesh, shard_map

        g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 0.01, (64, 64)),
                              jnp.float32)}
        mesh = make_mesh((1,), ("pod",))

        def f(gg):
            return quantized_psum_mean(gg, "pod", 1)

        out, efb = shard_map(
            f, mesh, in_specs=(P(),), out_specs=(P(), P()),
        )(g)
        err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert err.max() <= scale + 1e-7
        np.testing.assert_allclose(
            np.asarray(efb["w"], np.float32) + np.asarray(out["w"]),
            np.asarray(g["w"]), atol=scale * 0.6)


class TestDryRunMachinery:
    """The 512-device path, exercised in a subprocess (own XLA_FLAGS)."""

    @pytest.mark.slow
    def test_small_arch_cell_compiles(self):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
             "--shape", "decode_32k", "--mesh", "multipod", "--out",
             "/tmp/test_dryrun"],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=560,
        )
        assert "OK " in out.stdout, (out.stdout[-1000:], out.stderr[-1000:])

    def test_hlo_collective_parser(self):
        from repro.utils.hlo import (
            collective_wire_bytes, parse_collectives, split_computations,
            while_trip_counts,
        )

        hlo = """
HloModule test
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%sum
  ROOT %t = tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(9)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(%a), replica_groups=[4,2]<=[8], dimensions={0}
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %done = f32[64] get-tuple-element(%w), index=1
}
"""
        comps = split_computations(hlo)
        assert "body.1" in comps and "main" in comps
        trips = while_trip_counts(comps)
        assert trips.get("body.1") == 9
        colls = parse_collectives(hlo)
        ops = {c["op"]: c for c in colls}
        assert ops["all-reduce"]["count"] == 9.0     # x trip count
        assert ops["all-gather"]["count"] == 1.0
        wire = collective_wire_bytes(colls)
        # ar: 9 * 2*256*(3/4); ag: 512*(1/2)
        assert wire == pytest.approx(9 * 2 * 256 * 0.75 + 512 * 0.5)

    def test_analytic_flops_sane(self):
        from repro.configs import ARCHS
        from repro.utils.flopcount import cell_flops

        fl = cell_flops(ARCHS["codeqwen1.5-7b"], "train_4k")
        # 6*N*D: 6 * ~8.2e9 * (256*4096 tokens) ~ 5.2e16; executed = 4x fwd
        assert 2e16 < fl["model"] < 8e16
        assert fl["executed"] == pytest.approx(4 * fl["fwd"])
        dec = cell_flops(ARCHS["codeqwen1.5-7b"], "decode_32k")
        assert dec["model"] < 1e16  # one token per sequence
