"""Shared fixtures.  NOTE: no XLA_FLAGS here by design -- smoke tests and
benches must see the real single CPU device; only the dry-run entrypoint
forces 512 host devices (and multi-device tests spawn subprocesses)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_stream(rng, n=400, kind="mixed"):
    t = np.linspace(0, 12, n)
    if kind == "mixed":
        x = np.cumsum(rng.normal(0, 0.3, n)) + 2.0 * np.sin(t)
    elif kind == "sine":
        x = np.sin(t) + rng.normal(0, 0.05, n)
    elif kind == "walk":
        x = np.cumsum(rng.normal(0, 1.0, n))
    else:
        raise ValueError(kind)
    return x.astype(np.float32)
