"""Sender-side unit + property tests (paper Alg. 1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.compress import (
    _bridge_error_raw, bridge_error_direct, compress_stream,
)
from repro.core.normalize import ewm_scan
from repro.core.receiver import compact_events

from conftest import make_stream


class TestBridgeError:
    """O(1) incremental bridge error == O(m) direct recompute (exact)."""

    @given(st.lists(st.floats(-100, 100), min_size=3, max_size=64))
    @settings(max_examples=60, deadline=None)
    def test_incremental_matches_direct(self, vals):
        seg = np.asarray(vals, np.float32)
        v = seg - seg[0]
        h = np.arange(len(seg), dtype=np.float64)
        s0, s1, s2 = v.sum(), (h * v).sum(), (v * v).sum()
        e_inc = float(_bridge_error_raw(
            jnp.float32(s0), jnp.float32(s1), jnp.float32(s2),
            jnp.float32(v[-1]), jnp.float32(len(seg) - 1)))
        e_dir = float(bridge_error_direct(jnp.asarray(seg)))
        assert e_inc == pytest.approx(e_dir, rel=1e-3, abs=1e-2)

    def test_line_has_zero_error(self):
        seg = jnp.linspace(0.0, 5.0, 33)
        assert float(bridge_error_direct(seg)) < 1e-6

    def test_error_affine_invariance(self, rng):
        """Bridge residual: shift-invariant, scales with sigma^2 -- the
        identity that makes err_norm = err_raw / EWMV exact."""
        seg = jnp.asarray(rng.normal(0, 1, 21), jnp.float32)
        base = float(bridge_error_direct(seg))
        shifted = float(bridge_error_direct(seg + 37.5))
        scaled = float(bridge_error_direct(3.0 * seg))
        assert shifted == pytest.approx(base, rel=1e-3, abs=1e-3)
        assert scaled == pytest.approx(9.0 * base, rel=1e-3)


class TestNormalize:
    def test_paper_initialization(self, rng):
        ts = jnp.asarray(make_stream(rng, 50))
        m, v = ewm_scan(ts, 0.02)
        assert float(m[0]) == pytest.approx(float(ts[0]))
        assert float(v[0]) == 1.0

    def test_matches_numpy_recurrence(self, rng):
        ts = make_stream(rng, 200)
        m, v = ewm_scan(jnp.asarray(ts), 0.05)
        em, ev = ts[0], 1.0
        for j in range(1, len(ts)):
            em = 0.05 * ts[j] + 0.95 * em
            ev = 0.05 * (ts[j] - em) ** 2 + 0.95 * ev
        assert float(m[-1]) == pytest.approx(em, rel=1e-4)
        assert float(v[-1]) == pytest.approx(ev, rel=1e-4)

    @given(st.floats(0.01, 0.3))
    @settings(max_examples=20, deadline=None)
    def test_constant_stream_converges(self, alpha):
        ts = jnp.full((100,), 5.0)
        m, v = ewm_scan(ts, alpha)
        assert float(m[-1]) == pytest.approx(5.0, rel=1e-4)
        assert float(v[-1]) < 1.0  # decays from init toward 0


class TestCompression:
    def test_piece_chain_covers_stream(self, rng):
        ts = make_stream(rng, 500)
        ev = compress_stream(jnp.asarray(ts), tol=0.4, len_max=128, alpha=0.02)
        wire = compact_events(ev, n_max=256, t0=jnp.float32(ts[0]))
        n = int(wire["n_pieces"])
        lens = np.asarray(wire["lengths"])[:n]
        assert lens.sum() == len(ts) - 1      # polygonal chain spans T
        assert (lens >= 1).all()

    def test_receiver_reconstructs_sender_pieces(self, rng):
        """Alg. 2: arrival-gap lengths + endpoint-diff increments are exact."""
        ts = make_stream(rng, 400)
        ev = compress_stream(jnp.asarray(ts), tol=0.4, len_max=64, alpha=0.02)
        wire = compact_events(ev, n_max=256, t0=jnp.float32(ts[0]))
        emit = np.asarray(ev["emit"])
        gt_len = np.asarray(ev["length"])[emit]
        gt_inc = np.asarray(ev["inc"])[emit]
        n = len(gt_len)
        np.testing.assert_array_equal(np.asarray(wire["lengths"])[:n], gt_len)
        np.testing.assert_allclose(np.asarray(wire["incs"])[:n], gt_inc, atol=1e-5)

    def test_tolerance_monotonicity(self, rng):
        """Lower tol => more pieces (paper Fig. 5 premise)."""
        ts = jnp.asarray(make_stream(rng, 800))
        counts = []
        for tol in (0.1, 0.5, 1.5):
            ev = compress_stream(ts, tol=tol, len_max=512, alpha=0.01)
            counts.append(int(ev["n_pieces"]))
        assert counts[0] >= counts[1] >= counts[2]
        assert counts[0] > counts[2]

    def test_len_max_bound(self, rng):
        ts = jnp.asarray(np.zeros(300, np.float32))  # flat: only len_max cuts
        ev = compress_stream(ts, tol=0.5, len_max=32, alpha=0.02)
        wire = compact_events(ev, n_max=64, t0=jnp.float32(0))
        lens = np.asarray(wire["lengths"])[: int(wire["n_pieces"])]
        assert lens.max() <= 32

    def test_batched_matches_single(self, rng):
        streams = np.stack([make_stream(rng, 300) for _ in range(4)])
        ev_b = compress_stream(jnp.asarray(streams), tol=0.4, len_max=64, alpha=0.02)
        for i in range(4):
            ev_1 = compress_stream(jnp.asarray(streams[i]), tol=0.4, len_max=64,
                                   alpha=0.02)
            np.testing.assert_array_equal(
                np.asarray(ev_b["emit"][i]), np.asarray(ev_1["emit"]))

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_determinism(self, seed):
        ts = jnp.asarray(make_stream(np.random.default_rng(seed), 200))
        a = compress_stream(ts, tol=0.3, len_max=64, alpha=0.02)
        b = compress_stream(ts, tol=0.3, len_max=64, alpha=0.02)
        assert int(a["n_pieces"]) == int(b["n_pieces"])
        np.testing.assert_array_equal(np.asarray(a["emit"]), np.asarray(b["emit"]))

    @given(st.floats(1.5, 200.0), st.floats(-50.0, 50.0))
    @settings(max_examples=20, deadline=None)
    def test_scale_shift_equivariance(self, scale, shift):
        """Online z-normalization makes segmentation scale/shift invariant
        (the reason the sender normalizes at all).  EWMV_0 = 1.0 is an
        *absolute* init, so equivariance only holds once the damped window
        adapts -- compare after warmup (paper Sec. 4.2 notes the same
        early-stream transient)."""
        ts = make_stream(np.random.default_rng(7), 300)
        a = compress_stream(jnp.asarray(ts), tol=0.4, len_max=64, alpha=0.02)
        b = compress_stream(jnp.asarray(ts * scale + shift), tol=0.4,
                            len_max=64, alpha=0.02)
        ea, eb = np.asarray(a["emit"])[100:], np.asarray(b["emit"])[100:]
        assert (ea != eb).mean() < 0.05
