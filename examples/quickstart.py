"""Quickstart: the paper's running example (Fig. 3).

A ~230-point stream is pushed through the full SymED pipeline --
sender (online normalization + O(1) compression), one-float-per-piece wire,
receiver (piece construction + online k-means digitization) -- then
reconstructed both ways and scored with DTW.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.symed_paper import PAPER_RUNNING_EXAMPLE
from repro.core import symed_encode, symbols_to_string


def make_series(n=230, seed=7):
    """Noisy two-regime series, qualitatively like the paper's Fig. 1/3."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, n)
    base = np.where(t < 0.35, 2.2 * t / 0.35, 2.2 - 1.4 * (t - 0.35) / 0.3)
    base = np.where(t > 0.65, 0.8 + 2.0 * (t - 0.65), base)
    return (base + rng.normal(0, 0.08, n)).astype(np.float32)


def ascii_plot(series, recon, width=72, height=12):
    lo, hi = min(series.min(), recon.min()), max(series.max(), recon.max())
    rows = [[" "] * width for _ in range(height)]
    for arr, ch in ((series, "."), (recon, "#")):
        idx = np.linspace(0, len(arr) - 1, width).astype(int)
        for x, i in enumerate(idx):
            y = int((arr[i] - lo) / (hi - lo + 1e-9) * (height - 1))
            rows[height - 1 - y][x] = ch
    return "\n".join("".join(r) for r in rows)


def main():
    ts = make_series()
    cfg = PAPER_RUNNING_EXAMPLE  # tol=0.4, alpha=0.02, scl=0 (1D), paper Sec. 4.2
    out = symed_encode(jnp.asarray(ts), cfg, jax.random.key(0))

    n = int(out["n_pieces"])
    print(f"stream length        : {len(ts)} points ({4 * len(ts)} raw bytes)")
    print(f"pieces transmitted   : {n}  ({int(out['wire_bytes'])} wire bytes)")
    print(f"compression rate     : {float(out['cr']):.3f}  (paper avg 0.095)")
    print(f"dimension reduction  : {float(out['drr']):.3f}")
    print(f"alphabet size k      : {int(out['k'])}")
    print(f"symbols              : {symbols_to_string(out['symbols'], out['n_pieces'])}")
    print(f"DTW error (pieces)   : {float(out['re_pieces']):.3f}   <- online reconstruction")
    print(f"DTW error (symbols)  : {float(out['re_symbols']):.3f}   <- offline reconstruction")
    print()
    print("original (.) vs online reconstruction (#):")
    print(ascii_plot(ts, np.asarray(out["recon_pieces"])))


if __name__ == "__main__":
    main()
