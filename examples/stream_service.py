"""Resident SymED session service, in miniature.

Three sensor streams connect to one ``StreamServer``; their windows arrive
interleaved and ragged, and symbols leave the service *while the streams
are still running* -- each ``ingest`` returns the symbol-delta frame the
paper's downstream consumers (ABBA-VSM-style classifiers) would read off
the wire.  At the end, each session's closing output is bitwise what the
offline ``symed_encode`` would have produced -- the service changes the
serving shape, never the answer.

Run:  PYTHONPATH=src python examples/stream_service.py
"""
import numpy as np

from repro.core.symed import SymEDConfig, symbols_to_string
from repro.launch.stream import StreamServer


def make_streams(n, length, seed=0):
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 14, length)
    return [
        (np.cumsum(rng.normal(0, 0.3, length))
         + 2.0 * np.sin(t + i)).astype(np.float32)
        for i in range(n)
    ]


def main():
    length, window = 384, 48
    cfg = SymEDConfig(tol=0.4, alpha=0.02, n_max=128, k_max=16, len_max=128)
    server = StreamServer(cfg, max_sessions=4, window_cap=window,
                          digitize_every_k=1, dtw_every=4)
    streams = make_streams(3, length, seed=7)
    sids = [f"sensor-{i}" for i in range(3)]
    for sid in sids:
        server.open(sid)

    rng = np.random.default_rng(1)
    cursors = [0] * 3
    print(f"{'tick':>4}  {'session':<9} {'arrived':>7} {'delta':>5}  symbols")
    tick = 0
    while any(c < length for c in cursors):
        tick += 1
        batch = {}
        for i, sid in enumerate(sids):
            if cursors[i] >= length or rng.random() < 0.3:
                continue  # this sensor is quiet this tick
            n = int(rng.integers(16, 2 * window))
            batch[sid] = streams[i][cursors[i]: cursors[i] + n]
            cursors[i] = min(cursors[i] + n, length)
        for sid, delta in server.ingest_many(batch).items():
            if delta["n_new"]:
                syms = symbols_to_string(delta["labels"], delta["n_new"])
                print(f"{tick:>4}  {sid:<9} {len(batch[sid]):>7} "
                      f"{delta['n_new']:>5}  +{syms!r}")

    print("\n-- closing sessions " + "-" * 40)
    for i, sid in enumerate(sids):
        res = server.close(sid)
        print(f"{sid}: {res['n_pieces']} pieces -> {res['symbols']!r}"
              + (f"  (DTW monitor {res['dtw']:.2f})" if res["dtw"] else ""))

    rep = server.report(1.0)
    print(f"\nwire in  : {int(rep['bytes_in'])} bytes "
          f"({int(rep['points_in'])} points)")
    print(f"wire out : {int(rep['bytes_out'])} bytes "
          f"({int(rep['symbols_out'])} symbols in "
          f"{int(rep['frames_out'])} delta frames, "
          f"{int(rep['steps'])} batched table steps)")


if __name__ == "__main__":
    main()
