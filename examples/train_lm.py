"""End-to-end driver: train a language model ON SymED SYMBOL STREAMS.

The paper's pitch is analytics directly on symbols; the framework's flagship
analytic is sequence modeling: fleets of sensors are SymED-compressed, the
symbol streams become tokens, and the model zoo trains on them.

Default preset is CPU-friendly (~6M params, 60 steps, visibly falling loss).
``--full`` switches to the ~100M-param config of the deliverable (same code
path; a few hundred steps is a TPU-or-overnight run on this container):

  PYTHONPATH=src python examples/train_lm.py                 # quick preset
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, attn
from repro.data.tokenizer import SymbolTokenizer
from repro.launch.train import lm100m_config, train_loop


def small_config(vocab: int) -> ModelConfig:
    return ModelConfig(
        name="symlm-6m", family="dense", d_model=192, n_heads=4, n_kv_heads=4,
        d_ff=768, vocab=vocab, head_dim=48, block_pattern=(attn("global"),),
        n_blocks=6, mlp_kind="swiglu", tie_embeddings=True,
        supports_long_ctx=False, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M-param config")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    vocab = SymbolTokenizer(k_max=64).vocab_size
    cfg = lm100m_config(vocab) if args.full else small_config(vocab)
    n = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n / 1e6:.1f}M params, vocab={cfg.vocab} "
          f"(SymED symbols), {args.steps} steps @ batch={args.batch} seq={args.seq}")

    _, report = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir,
    )
    hist = report["loss_history"]
    print(f"[train_lm] loss {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"({100 * (1 - hist[-1] / hist[0]):.1f}% reduction)")


if __name__ == "__main__":
    main()
