"""Fleet-scale SymED: symbolize thousands of streams, sharded over the mesh.

This is the paper's edge scenario at pod scale, driven through the
``repro.launch.fleet`` runtime: every device owns a slab of sender+receiver
pairs (shard_map over the ``data`` axis), ingestion is chunked/online
(``--chunk``), and wire traffic / compression rate are aggregated fleet-wide
with on-mesh reductions.

Run:  PYTHONPATH=src python examples/edge_fleet.py --streams 512 --length 1024
(on the TPU target the same script runs with mesh=(16,16) and
streams in the millions; on CPU it uses every available device)
"""
import argparse
import time

import jax
import numpy as np

from repro.core.symed import SymEDConfig
from repro.data.synthetic import make_fleet
from repro.launch.fleet import fleet_data_mesh, fleet_report, run_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=256)
    ap.add_argument("--length", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=256,
                    help="online ingestion window; 0 = whole-stream")
    ap.add_argument("--tol", type=float, default=0.5)
    ap.add_argument("--alpha", type=float, default=0.01)
    args = ap.parse_args()

    n_dev = jax.device_count()
    mesh = fleet_data_mesh(n_dev)
    streams = max(args.streams - args.streams % n_dev, n_dev)
    fleet = make_fleet(streams, args.length, seed=0)
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)

    t0 = time.time()
    out, tele = run_fleet(
        fleet, cfg, jax.random.key(0), mesh,
        chunk_len=args.chunk or None, reconstruct=True,
    )
    jax.block_until_ready(out["n_pieces"])
    rep = fleet_report(tele, time.time() - t0)

    n_pieces = np.asarray(out["n_pieces"])
    print(f"devices                 : {n_dev}")
    print(f"ingestion               : "
          f"{'chunked(%d)' % args.chunk if args.chunk else 'whole-stream'}")
    print(f"streams                 : {streams} x {args.length} points")
    print(f"wall time               : {rep['wall_seconds']:.2f}s "
          f"({rep['points_per_s'] / 1e6:.2f} Mpoints/s)")
    print(f"mean pieces/stream      : {n_pieces.mean():.1f}")
    print(f"mean compression rate   : {rep['compression_rate']:.4f} "
          f"(paper avg 0.095)")
    print(f"fleet raw bytes         : {int(rep['raw_bytes']):,}")
    print(f"fleet wire bytes        : {int(rep['wire_bytes']):,} "
          f"({100 * rep['compression_rate']:.1f}% of raw)")
    print(f"mean DTW err (pieces)   : {np.asarray(out['re_pieces']).mean():.3f}")
    print(f"mean DTW err (symbols)  : {np.asarray(out['re_symbols']).mean():.3f}")
    print(f"mean alphabet size      : {np.asarray(out['k']).mean():.1f}")


if __name__ == "__main__":
    main()
