"""Fleet-scale SymED: symbolize thousands of streams, sharded over the mesh.

This is the paper's edge scenario at pod scale, driven through the
``repro.launch.fleet`` runtime: every device owns a slab of sender+receiver
pairs (shard_map over the ``data`` axis, or the flattened ``pod x data`` grid
with ``--pods``), ingestion is the streaming receiver (``--chunk`` windows
with ``--digitize-every`` cadence, so symbols stream out online), and wire
traffic / compression rate are aggregated fleet-wide with hierarchical
on-mesh reductions.

Run:  PYTHONPATH=src python examples/edge_fleet.py --streams 512 --length 1024
(on the TPU target the same script runs with mesh=(16,16) and
streams in the millions; on CPU it uses every available device)
"""
import argparse
import time

import jax
import numpy as np

from repro.core.symed import SymEDConfig
from repro.data.synthetic import make_fleet
from repro.launch.fleet import (
    describe_ingestion, fleet_report, resolve_fleet_mesh, run_fleet,
    validate_cli_args,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=256)
    ap.add_argument("--length", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=None,
                    help="streaming ingestion window; 0 = whole-stream "
                         "(default: min(256, length))")
    ap.add_argument("--digitize-every", type=int, default=1,
                    help="digitize cadence k (symbols stream out every k "
                         "windows; 0 = once at end-of-stream)")
    ap.add_argument("--pods", type=int, default=1,
                    help="shard over a (pod, data) mesh with this many pods")
    ap.add_argument("--tol", type=float, default=0.5)
    ap.add_argument("--alpha", type=float, default=0.01)
    args = ap.parse_args()

    if args.chunk is None:
        args.chunk = min(256, args.length)  # default adapts to short streams
    if not args.chunk:
        args.digitize_every = 0  # cadence default is meaningless whole-stream
    validate_cli_args(ap, args)
    n_dev = jax.device_count()
    try:
        mesh, mesh_axes, layout = resolve_fleet_mesh(args.pods, n_dev)
    except ValueError as e:
        ap.error(str(e))
    streams = max(args.streams - args.streams % n_dev, n_dev)
    fleet = make_fleet(streams, args.length, seed=0)
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)

    t0 = time.perf_counter()
    out, tele = run_fleet(
        fleet, cfg, jax.random.key(0), mesh,
        chunk_len=args.chunk or None,
        digitize_every_k=args.digitize_every or None,
        reconstruct=True, axis=mesh_axes,
    )
    jax.block_until_ready(out["n_pieces"])
    rep = fleet_report(tele, time.perf_counter() - t0)

    n_pieces = np.asarray(out["n_pieces"])
    mode = describe_ingestion(args.chunk, args.digitize_every)
    print(f"devices                 : {n_dev}  ({layout})")
    print(f"ingestion               : {mode}")
    print(f"streams                 : {streams} x {args.length} points")
    print(f"wall time               : {rep['wall_seconds']:.2f}s "
          f"({rep['points_per_s'] / 1e6:.2f} Mpoints/s)")
    print(f"symbol latency          : {rep['ms_per_symbol']:.3f} ms/symbol "
          f"(paper: 42ms single-CPU)")
    print(f"mean pieces/stream      : {n_pieces.mean():.1f}")
    print(f"mean compression rate   : {rep['compression_rate']:.4f} "
          f"(paper avg 0.095)")
    print(f"fleet raw bytes         : {int(rep['raw_bytes']):,}")
    print(f"fleet wire bytes        : {int(rep['wire_bytes']):,} "
          f"({100 * rep['compression_rate']:.1f}% of raw)")
    print(f"fleet wire-out bytes    : {int(rep['wire_out_bytes']):,} "
          f"(symbol-delta frames, {rep['wire_out_ratio']:.2f}x wire in)")
    print(f"mean DTW err (pieces)   : {np.asarray(out['re_pieces']).mean():.3f}")
    print(f"mean DTW err (symbols)  : {np.asarray(out['re_symbols']).mean():.3f}")
    print(f"mean alphabet size      : {np.asarray(out['k']).mean():.1f}")


if __name__ == "__main__":
    main()
