"""Fleet-scale SymED: symbolize thousands of streams, sharded over the mesh.

This is the paper's edge scenario at pod scale: every device owns a slab of
sender+receiver pairs (shard_map over the ``data`` axis); the wire traffic,
compression rate and reconstruction error are aggregated fleet-wide.

Run:  PYTHONPATH=src python examples/edge_fleet.py --streams 512 --length 1024
(on the TPU target the same script runs with mesh=(16,16) and
streams in the millions; on CPU it uses every available device)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=256)
    ap.add_argument("--length", type=int, default=1024)
    ap.add_argument("--tol", type=float, default=0.5)
    ap.add_argument("--alpha", type=float, default=0.01)
    args = ap.parse_args()

    n_dev = jax.device_count()
    streams = args.streams - args.streams % n_dev
    fleet = make_fleet(streams, args.length, seed=0)
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)

    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sharding = NamedSharding(mesh, P("data", None))
    fleet_sharded = jax.device_put(fleet, sharding)

    @jax.jit
    def run(slab, key):
        return symed_batch(slab, cfg, key, reconstruct=True)

    t0 = time.time()
    out = run(fleet_sharded, jax.random.key(0))
    jax.block_until_ready(out["n_pieces"])
    dt = time.time() - t0

    n_pieces = np.asarray(out["n_pieces"])
    wire = np.asarray(out["wire_bytes"])
    raw = 4 * args.length
    print(f"devices                 : {n_dev}")
    print(f"streams                 : {streams} x {args.length} points")
    print(f"wall time               : {dt:.2f}s "
          f"({streams * args.length / dt / 1e6:.2f} Mpoints/s)")
    print(f"mean pieces/stream      : {n_pieces.mean():.1f}")
    print(f"mean compression rate   : {(wire / raw).mean():.4f} (paper avg 0.095)")
    print(f"fleet raw bytes         : {streams * raw:,}")
    print(f"fleet wire bytes        : {int(wire.sum()):,} "
          f"({100 * wire.sum() / (streams * raw):.1f}% of raw)")
    print(f"mean DTW err (pieces)   : {np.asarray(out['re_pieces']).mean():.3f}")
    print(f"mean DTW err (symbols)  : {np.asarray(out['re_symbols']).mean():.3f}")
    print(f"mean alphabet size      : {np.asarray(out['k']).mean():.1f}")


if __name__ == "__main__":
    main()
