"""Minimal real sender->receiver link over a loopback socket.

The paper's deployment shape end to end: a ``TransportServer`` (edge node)
in a background thread, and two ``SenderClient``s (IoT nodes) on the same
process -- one shipping raw windows, one running the SymED compressor
locally and shipping only finished piece tuples.  Both receive the edge's
symbol-delta frames back over the socket; the pieces sender demonstrates
the paper's headline wire saving.

    PYTHONPATH=src python examples/transport_link.py
"""
import threading

import numpy as np

from repro.core.symed import SymEDConfig
from repro.data.synthetic import make_fleet
from repro.launch.stream import StreamServer
from repro.launch.transport import SenderClient, TransportServer, session_seed

N_STREAMS, LENGTH, WINDOW = 3, 256, 32


def run_sender(port: int, cfg: SymEDConfig, mode: str, data: np.ndarray):
    client = SenderClient("127.0.0.1", port, cfg, mode=mode)
    sids = [f"{mode}-{i}" for i in range(len(data))]
    for sid in sids:
        client.open(sid, session_seed(sid, 0))
    for c in range(0, LENGTH, WINDOW):          # interleave the sessions
        for i, sid in enumerate(sids):
            client.send(sid, data[i, c: c + WINDOW])
    results = {sid: client.close(sid) for sid in sids}
    symbols = sum(r["n_pieces"] for r in results.values())
    points = sum(r["t_seen"] for r in results.values())
    print(f"  {mode:>6} sender: {len(sids)} sessions, {points} points -> "
          f"{symbols} symbols, {int(client.payload_bytes)} payload B "
          f"({client.payload_bytes / (4 * points):.3f} of raw)")
    client.shutdown()


def main():
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=256, k_max=32, len_max=256)
    server = StreamServer(cfg, max_sessions=8, window_cap=WINDOW,
                          digitize_every_k=1, autoscale=True, min_slots=1)
    transport = TransportServer(server, port=0)
    thread = threading.Thread(
        target=transport.serve,
        kwargs={"expect_sessions": 2 * N_STREAMS}, daemon=True)
    thread.start()
    print(f"edge receiver listening on 127.0.0.1:{transport.port}")

    data = np.asarray(make_fleet(N_STREAMS, LENGTH, seed=4))
    for mode in ("pieces", "raw"):
        run_sender(transport.port, cfg, mode, data)
    thread.join(timeout=60)

    rep = server.report(1.0)
    print(f"edge totals: {int(rep['points_in'])} points in, "
          f"{int(rep['wire_in_bytes'])} wire-in B "
          f"(ratio {rep['wire_in_ratio']:.3f}), "
          f"{int(rep['bytes_out'])} wire-out B in "
          f"{int(rep['frames_out'])} delta frames; "
          f"table grew {int(rep['grows'])}x, shrank {int(rep['shrinks'])}x")


if __name__ == "__main__":
    main()
