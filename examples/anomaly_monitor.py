"""SymED telemetry + straggler watchdog demo (paper Alg. 1 dogfooded).

Simulates a 16-host training fleet emitting per-step wall times and losses.
The coordinator runs the resident ``repro.launch.stream.StreamServer``: one
session per telemetry stream (32 total), fed through the batched donated
table step once per round, with the slot table autoscaling from
``min_slots`` up as sessions open.  The symbol-delta frames the service
emits are the bytes a dashboard would receive -- their size *is* the wire
accounting -- and the EWMA/EWMV z-score watchdog flags the injected
straggler and hang from the raw step times on the host side.

Run:  PYTHONPATH=src python examples/anomaly_monitor.py
"""
import numpy as np

from repro.core.symed import SymEDConfig
from repro.launch.stream import StreamServer
from repro.train.telemetry import StepWatchdog

N_HOSTS = 16
STEPS = 400
ROUND = 16          # telemetry points buffered per batched ingest round
METRICS = ("step_time", "loss")


def simulate(server: StreamServer):
    rng = np.random.default_rng(3)
    dogs = {h: StepWatchdog(alpha=0.1, z_threshold=4.0) for h in range(N_HOSTS)}
    events = []
    deltas = {}          # sid -> accumulated symbol-delta wire bytes
    raw_bytes = 0.0

    for sid in (f"host{h:02d}/{m}" for h in range(N_HOSTS) for m in METRICS):
        server.open(sid)
    pending = {sid: [] for sid in server.session_ids()}

    for step in range(STEPS):
        for host in range(N_HOSTS):
            dt = rng.normal(1.0, 0.03)
            if host == 7 and 200 <= step < 220:     # injected slow host
                dt += 0.8
            if host == 3 and step == 350:           # injected hang
                dt = 15.0
            loss = 3.0 * np.exp(-step / 150) + rng.normal(0, 0.02)
            pending[f"host{host:02d}/step_time"].append(dt)
            pending[f"host{host:02d}/loss"].append(loss)
            ev = dogs[host].observe(step, dt)
            if ev:
                events.append((host, ev))
        if (step + 1) % ROUND == 0:
            out = server.ingest_many(pending)       # one device program
            for sid, d in out.items():
                deltas[sid] = deltas.get(sid, 0.0) + d["bytes"]
                raw_bytes += 4.0 * len(pending[sid])
            pending = {sid: [] for sid in pending}
    return events, deltas, raw_bytes


def main():
    # small buffers: 400-point telemetry streams need nowhere near the
    # paper-scale defaults, and trace time tracks n_max/len_max/k_max
    cfg = SymEDConfig(tol=0.4, alpha=0.05, n_max=256, len_max=64, k_max=12)
    server = StreamServer(
        cfg, max_sessions=2 * N_HOSTS, window_cap=ROUND,
        autoscale=True, min_slots=4, seed=11)
    events, deltas, raw_bytes = simulate(server)
    peak_capacity = server.capacity  # close() lets autoscale shrink back

    closed = {sid: server.close(sid) for sid in list(server.session_ids())}
    wire_bytes = sum(deltas.values()) + sum(
        c["delta"]["bytes"] for c in closed.values())

    print(f"telemetry streams     : {len(closed)} "
          f"(slot table grew 4 -> {peak_capacity})")
    print(f"batched device steps  : {server.totals['steps']}")
    print(f"raw bytes             : {raw_bytes:,.0f}")
    print(f"wire bytes            : {wire_bytes:,.0f}  "
          f"(CR={wire_bytes / raw_bytes:.3f}, paper avg 0.095)")

    sym = closed["host07/step_time"]["symbols"]
    print(f"host07 step_time syms : {sym[:60]}{'...' if len(sym) > 60 else ''}"
          f"  (n_pieces={closed['host07/step_time']['n_pieces']})")

    print("\nwatchdog events:")
    for host, ev in events:
        print(f"  host{host:02d} step {ev['step']:3d}: {ev['kind']:9s} "
              f"dt={ev['dt']:.2f}s z={ev['z']:.1f}")
    flagged = {h for h, e in events}
    assert 7 in flagged and 3 in flagged, "injected anomalies must be caught"
    assert wire_bytes < raw_bytes, "symbol deltas must beat raw telemetry"
    print("\ninjected straggler (host07) and hang (host03) both detected.")


if __name__ == "__main__":
    main()
