"""SymED telemetry + straggler watchdog demo (paper Alg. 1 dogfooded).

Simulates a 16-host training fleet emitting per-step wall times and losses;
each host runs a SymED *sender* (O(1) state, numpy scalars), the coordinator
*receives* one float per piece and (i) accounts the telemetry bandwidth
saved, (ii) digitizes streams into symbols, (iii) flags the injected
straggler and hang through the EWMA/EWMV z-score watchdog.

Run:  PYTHONPATH=src python examples/anomaly_monitor.py
"""
import numpy as np

from repro.core.symed import symbols_to_string
from repro.train.telemetry import StepWatchdog, TelemetryHub


def simulate():
    rng = np.random.default_rng(3)
    hub = TelemetryHub(tol=0.4, alpha=0.05)
    dogs = {h: StepWatchdog(alpha=0.1, z_threshold=4.0) for h in range(16)}
    events = []

    for step in range(400):
        for host in range(16):
            dt = rng.normal(1.0, 0.03)
            if host == 7 and 200 <= step < 220:     # injected slow host
                dt += 0.8
            if host == 3 and step == 350:           # injected hang
                dt = 15.0
            loss = 3.0 * np.exp(-step / 150) + rng.normal(0, 0.02)
            hub.record_metrics(f"host{host:02d}", {"step_time": dt, "loss": loss})
            ev = dogs[host].observe(step, dt)
            if ev:
                events.append((host, ev))
    return hub, events


def main():
    hub, events = simulate()

    report = hub.traffic_report()
    raw = sum(r["raw_bytes"] for r in report.values())
    wire = sum(r["wire_bytes"] for r in report.values())
    print(f"telemetry streams     : {len(report)}")
    print(f"raw bytes             : {raw:,}")
    print(f"wire bytes            : {wire:,}  (CR={wire / raw:.3f}, "
          f"paper avg 0.095)")

    dig = hub.digitize("host07/step_time", k_max=8)
    if dig is not None:
        n = int(np.asarray(dig["state"].n))
        s = symbols_to_string(np.asarray(dig["labels"]), n)
        print(f"host07 step_time syms : {s}  (k={int(dig['k'])})")

    print("\nwatchdog events:")
    for host, ev in events:
        print(f"  host{host:02d} step {ev['step']:3d}: {ev['kind']:9s} "
              f"dt={ev['dt']:.2f}s z={ev['z']:.1f}")
    flagged = {h for h, e in events}
    assert 7 in flagged and 3 in flagged, "injected anomalies must be caught"
    print("\ninjected straggler (host07) and hang (host03) both detected.")


if __name__ == "__main__":
    main()
