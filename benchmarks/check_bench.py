"""Bench regression gate for the resident stream service.

Two checks, both sized for the CI ``bench-artifacts`` job:

1. **resident_speedup diff** -- compares the freshly generated
   ``BENCH_fleet.json`` against the committed one (read from ``git show
   HEAD:BENCH_fleet.json`` by default, so the fresh run may overwrite the
   worktree copy in place) and fails if ``resident_speedup`` dropped by
   more than ``--rel-tol`` (CI-noise allowance).  The committed artifact is
   the perf trajectory; this stops a "resident tick got slower than the
   slab rerun again" regression from merging silently.
2. **compiled-program cache flatness** -- spins up a ladder-pre-traced
   autoscaled ``StreamServer``, drives a grow/shrink/grow cycle, and fails
   if the donated table step compiled *anything* new: the serving loop's
   retrace-free contract, asserted against the live jit cache rather than
   inferred from timings.

    PYTHONPATH=src python -m benchmarks.check_bench --fresh BENCH_fleet.json
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def load_baseline(spec: str):
    """``@HEAD`` reads the committed artifact; anything else is a path."""
    if spec == "@HEAD":
        proc = subprocess.run(
            ["git", "show", "HEAD:BENCH_fleet.json"],
            capture_output=True, text=True)
        if proc.returncode != 0:
            return None
        return json.loads(proc.stdout)
    with open(spec) as f:
        return json.load(f)


def check_speedup(fresh: dict, base: dict, rel_tol: float) -> bool:
    f = float(fresh["summary"]["stream_service"]["resident_speedup"])
    b = float(base["summary"]["stream_service"]["resident_speedup"])
    floor = b * (1.0 - rel_tol)
    ok = f >= floor
    print(f"resident_speedup: fresh={f:.3f} committed={b:.3f} "
          f"floor={floor:.3f} -> {'ok' if ok else 'REGRESSION'}")
    return ok


def check_cache_flat() -> bool:
    import numpy as np

    from repro.core.symed import SymEDConfig
    from repro.launch.stream import StreamServer, _table_step

    cfg = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                      len_max=32, n_max=64, lloyd_iters=5)
    srv = StreamServer(cfg, max_sessions=4, window_cap=32, autoscale=True,
                       min_slots=1, shrink_patience=1, pretrace=True)
    base = _table_step._cache_size()
    rng = np.random.default_rng(0)
    for cycle in range(2):  # grow 1->2->4, drain to 1, grow again
        for i in range(3):
            sid = f"c{cycle}s{i}"
            srv.open(sid)
            srv.ingest(sid, rng.normal(size=32).astype(np.float32))
        for i in range(3):
            srv.close(f"c{cycle}s{i}")
    now = _table_step._cache_size()
    grows, shrinks = srv.totals["grows"], srv.totals["shrinks"]
    ok = now == base and grows >= 3 and shrinks >= 3
    print(f"compiled cache entries: {base} -> {now} across "
          f"grows={grows} shrinks={shrinks} -> "
          f"{'ok (flat)' if ok else 'FAIL (traced during serving)'}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--fresh", default="BENCH_fleet.json",
                    help="freshly generated artifact to gate")
    ap.add_argument("--baseline", default="@HEAD",
                    help="committed artifact (@HEAD: git show HEAD:...)")
    ap.add_argument("--rel-tol", type=float, default=0.25,
                    help="allowed fractional resident_speedup drop (sized "
                         "for shared-runner timing noise: the gate catches "
                         "structural regressions like the 0.68x inversion, "
                         "not percent-level jitter)")
    ap.add_argument("--skip-cache-check", action="store_true",
                    help="only diff the artifacts (no jax work)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    base = load_baseline(args.baseline)
    ok = True
    if base is None:
        print(f"no committed baseline ({args.baseline}); speedup gate "
              "skipped")
    else:
        ok = check_speedup(fresh, base, args.rel_tol) and ok
    if not args.skip_cache_check:
        ok = check_cache_flat() and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
