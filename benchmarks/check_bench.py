"""Bench regression gate for the resident stream service.

Four checks, all sized for the CI ``bench-artifacts`` job:

1. **resident_speedup diff** -- compares the freshly generated
   ``BENCH_fleet.json`` against the committed one (read from ``git show
   HEAD:BENCH_fleet.json`` by default, so the fresh run may overwrite the
   worktree copy in place) and fails if ``resident_speedup`` dropped by
   more than ``--rel-tol`` (CI-noise allowance).  The committed artifact is
   the perf trajectory; this stops a "resident tick got slower than the
   slab rerun again" regression from merging silently.
2. **scale-row diff** -- the 8/32/64-session resident-tick throughput rows
   against the same baseline (ROADMAP item 1's >32-session knee, tracked
   as numbers rather than a footnote), with a wider ``--scale-rel-tol``
   because large-table ticks jitter more on shared runners.
3. **obs overhead** -- the flight recorder's instrumented-vs-disabled
   resident-tick pair (both measured in the *fresh* artifact, so no
   baseline is involved) must stay within ``--obs-tol`` (5%), with a small
   absolute floor so sub-millisecond scheduler jitter on a fast tick does
   not read as a fractional regression.
4. **compiled-program cache flatness** -- spins up a ladder-pre-traced
   autoscaled ``StreamServer``, drives a grow/shrink/grow cycle, and fails
   if the donated table step compiled *anything* new: the serving loop's
   retrace-free contract, asserted against the live jit cache rather than
   inferred from timings.
5. **transport/workload diff** (``--transport-fresh``) -- compares a fresh
   ``BENCH_transport.json`` (schema ``bench_transport/v1``, written by
   ``python -m repro.workload --out``) against the committed one.  The
   schedule-determined integers of every scenario row (event/window
   counts, sessions opened/closed/evicted, points in, queue depth,
   drains) must match *exactly* -- a seeded trace replay is deterministic,
   so any drift is a behavior change, not noise -- and the fresh run must
   carry zero SLO violations.  Latency quantiles and delta hashes are
   reported but not gated (they vary across machines / jax builds).

    PYTHONPATH=src python -m benchmarks.check_bench --fresh BENCH_fleet.json
    PYTHONPATH=src python -m benchmarks.check_bench --skip-fleet \
        --skip-cache-check --transport-fresh BENCH_transport.json
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def load_baseline(spec: str, name: str = "BENCH_fleet.json"):
    """``@HEAD`` reads the committed artifact; anything else is a path."""
    if spec == "@HEAD":
        proc = subprocess.run(
            ["git", "show", f"HEAD:{name}"],
            capture_output=True, text=True)
        if proc.returncode != 0:
            return None
        return json.loads(proc.stdout)
    with open(spec) as f:
        return json.load(f)


def check_speedup(fresh: dict, base: dict, rel_tol: float) -> bool:
    f = float(fresh["summary"]["stream_service"]["resident_speedup"])
    b = float(base["summary"]["stream_service"]["resident_speedup"])
    floor = b * (1.0 - rel_tol)
    ok = f >= floor
    print(f"resident_speedup: fresh={f:.3f} committed={b:.3f} "
          f"floor={floor:.3f} -> {'ok' if ok else 'REGRESSION'}")
    return ok


def check_scale_rows(fresh: dict, base: dict, rel_tol: float) -> bool:
    """Per-session-count resident-tick throughput vs the committed artifact."""
    f_scale = fresh["summary"]["stream_service"].get("scale", {})
    b_scale = base["summary"]["stream_service"].get("scale", {})
    if not b_scale:
        print("scale rows: no committed baseline entries; gate skipped")
        return True
    ok = True
    for name in sorted(b_scale):
        if name not in f_scale:
            print(f"scale {name}: missing from fresh artifact -> FAIL")
            ok = False
            continue
        f = float(f_scale[name]["points_per_s"])
        b = float(b_scale[name]["points_per_s"])
        floor = b * (1.0 - rel_tol)
        row_ok = f >= floor
        print(f"scale {name}: fresh={f:.0f} pts/s committed={b:.0f} "
              f"floor={floor:.0f} -> {'ok' if row_ok else 'REGRESSION'}")
        ok = ok and row_ok
    return ok


def check_obs_overhead(fresh: dict, tol: float, abs_floor_ms: float) -> bool:
    """Instrumented-vs-disabled resident tick, both from the fresh artifact."""
    obs = fresh["summary"]["stream_service"].get("obs")
    if obs is None:
        print("obs overhead: no obs section in fresh artifact -> FAIL")
        return False
    on = float(obs["tick_ms_obs_on"])
    off = float(obs["tick_ms_obs_off"])
    frac = (on - off) / max(off, 1e-12)
    ok = frac <= tol or (on - off) <= abs_floor_ms
    print(f"obs overhead: on={on:.3f}ms off={off:.3f}ms "
          f"frac={frac:+.4f} (tol {tol:.2f}, abs floor {abs_floor_ms}ms) "
          f"-> {'ok' if ok else 'TOO EXPENSIVE'}")
    return ok


def check_cache_flat() -> bool:
    import numpy as np

    from repro.core.symed import SymEDConfig
    from repro.launch.stream import StreamServer, _table_step

    cfg = SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3, k_max=8,
                      len_max=32, n_max=64, lloyd_iters=5)
    srv = StreamServer(cfg, max_sessions=4, window_cap=32, autoscale=True,
                       min_slots=1, shrink_patience=1, pretrace=True)
    base = _table_step._cache_size()
    rng = np.random.default_rng(0)
    for cycle in range(2):  # grow 1->2->4, drain to 1, grow again
        for i in range(3):
            sid = f"c{cycle}s{i}"
            srv.open(sid)
            srv.ingest(sid, rng.normal(size=32).astype(np.float32))
        for i in range(3):
            srv.close(f"c{cycle}s{i}")
    now = _table_step._cache_size()
    grows, shrinks = srv.totals["grows"], srv.totals["shrinks"]
    ok = now == base and grows >= 3 and shrinks >= 3
    print(f"compiled cache entries: {base} -> {now} across "
          f"grows={grows} shrinks={shrinks} -> "
          f"{'ok (flat)' if ok else 'FAIL (traced during serving)'}")
    return ok


# schedule-determined per-scenario integers: a seeded trace replay is
# deterministic, so these must match the committed baseline *exactly*
TRANSPORT_EXACT_KEYS = (
    "events", "windows", "sessions", "opened", "closed", "evicted",
    "points_in", "max_queue_depth", "drains",
)


def check_transport(fresh: dict, base) -> bool:
    """Diff ``bench_transport/v1`` scenario rows against the committed
    artifact; always require the fresh run to be violation-free."""
    ok = True
    schema = fresh.get("schema")
    if schema != "bench_transport/v1":
        print(f"transport: unexpected schema {schema!r} -> FAIL")
        ok = False
    for row in fresh.get("rows", []):
        viol = row.get("violations", [])
        if viol:
            print(f"transport {row['scenario']}: SLO violations in fresh "
                  f"run -> FAIL: {viol}")
            ok = False
    if base is None:
        print("transport: no committed baseline; determinism diff skipped")
        return ok
    b_rows = {r["scenario"]: r for r in base.get("rows", [])}
    for row in fresh.get("rows", []):
        name = row["scenario"]
        b = b_rows.pop(name, None)
        if b is None:
            print(f"transport {name}: new scenario (no baseline row); "
                  "determinism diff skipped")
            continue
        drift = [
            f"{k}={row.get(k)}!={b.get(k)}" for k in TRANSPORT_EXACT_KEYS
            if int(row.get(k, -1)) != int(b.get(k, -1))
        ]
        if abs(float(row.get("evict_rate", 0.0))
               - float(b.get("evict_rate", 0.0))) > 1e-9:
            drift.append(f"evict_rate={row.get('evict_rate')}"
                         f"!={b.get('evict_rate')}")
        hash_note = ("" if row.get("delta_sha256") == b.get("delta_sha256")
                     else " (delta hash differs: machine/jax-build "
                          "dependent, not gated)")
        if drift:
            print(f"transport {name}: DRIFT {', '.join(drift)} -> FAIL"
                  f"{hash_note}")
            ok = False
        else:
            print(f"transport {name}: deterministic counters match "
                  f"(p99={row.get('p99_symbol_ms', 0.0):.1f}ms, not gated)"
                  f"{hash_note} -> ok")
    for name in sorted(b_rows):
        print(f"transport {name}: missing from fresh artifact -> FAIL")
        ok = False
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--fresh", default="BENCH_fleet.json",
                    help="freshly generated artifact to gate")
    ap.add_argument("--baseline", default="@HEAD",
                    help="committed artifact (@HEAD: git show HEAD:...)")
    ap.add_argument("--rel-tol", type=float, default=0.25,
                    help="allowed fractional resident_speedup drop (sized "
                         "for shared-runner timing noise: the gate catches "
                         "structural regressions like the 0.68x inversion, "
                         "not percent-level jitter)")
    ap.add_argument("--scale-rel-tol", type=float, default=0.35,
                    help="allowed fractional points_per_s drop on the "
                         "8/32/64-session scale rows (wider than --rel-tol: "
                         "big-table ticks jitter more on shared runners)")
    ap.add_argument("--obs-tol", type=float, default=0.05,
                    help="allowed fractional obs-on vs obs-off resident-tick "
                         "overhead (the flight recorder's cost contract)")
    ap.add_argument("--obs-abs-floor-ms", type=float, default=0.3,
                    help="absolute obs-overhead allowance: differences under "
                         "this many ms pass regardless of the fraction")
    ap.add_argument("--skip-cache-check", action="store_true",
                    help="only diff the artifacts (no jax work)")
    ap.add_argument("--skip-fleet", action="store_true",
                    help="skip the BENCH_fleet.json checks (workload-smoke "
                         "runs only the transport gate)")
    ap.add_argument("--transport-fresh", default=None, metavar="PATH",
                    help="freshly generated BENCH_transport.json to gate")
    ap.add_argument("--transport-baseline", default="@HEAD",
                    help="committed transport artifact "
                         "(@HEAD: git show HEAD:BENCH_transport.json)")
    args = ap.parse_args()

    ok = True
    if not args.skip_fleet:
        with open(args.fresh) as f:
            fresh = json.load(f)
        base = load_baseline(args.baseline)
        if base is None:
            print(f"no committed baseline ({args.baseline}); speedup + scale "
                  "gates skipped")
        else:
            ok = check_speedup(fresh, base, args.rel_tol) and ok
            ok = check_scale_rows(fresh, base, args.scale_rel_tol) and ok
        ok = check_obs_overhead(
            fresh, args.obs_tol, args.obs_abs_floor_ms) and ok
        if not args.skip_cache_check:
            ok = check_cache_flat() and ok
    if args.transport_fresh is not None:
        with open(args.transport_fresh) as f:
            t_fresh = json.load(f)
        t_base = load_baseline(args.transport_baseline,
                               "BENCH_transport.json")
        ok = check_transport(t_fresh, t_base) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
