"""Shared benchmark utilities: datasets, timed calls, paper-protocol means."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import FAMILIES, make_dataset

# benchmark-scale defaults (paper: 22 datasets x ~14 series x ~1673 points;
# here: 5 synthetic families x N series x L points -- same protocol, equal
# weights per family then mean over families)
N_SERIES = 4
LENGTH = 1000
TOLS = tuple(round(0.1 * i, 1) for i in range(1, 21, 2))  # 0.1..1.9


def datasets(n_series: int = N_SERIES, length: int = LENGTH) -> Dict[str, np.ndarray]:
    return {f: make_dataset(f, n_series, length, seed=11) for f in FAMILIES}


def equal_weight_mean(per_family: Dict[str, np.ndarray]) -> float:
    """Paper protocol: mean within dataset, then across datasets."""
    return float(np.mean([np.mean(v) for v in per_family.values()]))


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)[0])
    return out, (time.perf_counter() - t0) / iters


def symed_over_datasets(cfg: SymEDConfig, data: Dict[str, np.ndarray],
                        reconstruct: bool = True):
    out = {}
    for fam, series in data.items():
        out[fam] = symed_batch(jnp.asarray(series), cfg, jax.random.key(0),
                               reconstruct=reconstruct)
    return out
