"""Paper Fig. 5 reproduction suite: one function per sub-figure.

  5a  reconstruction error (DTW) vs tol  -- ABBA symbols / SymED symbols /
      SymED pieces (the paper's headline: pieces ~half the symbol error)
  5b  compression rate vs tol            -- CR_ABBA < CR_SymED (Eq. 3)
  5c  dimension-reduction rate vs tol
  5d  per-symbol latency (sender / receiver)
  5e  total conversion latency (ABBA offline vs SymED online)

Each returns CSV rows (name, us_per_call, derived) and a summary dict that
EXPERIMENTS.md quotes.  Synthetic UCR-like families stand in for the archive
(see repro/data/synthetic.py); the paper's equal-weight protocol is kept.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abba_encode, dtw_ref
from repro.core.metrics import compression_rate_abba
from repro.core.reconstruct import reconstruct_from_symbols
from repro.core.symed import SymEDConfig, symed_encode

from benchmarks.common import LENGTH, TOLS, datasets, equal_weight_mean, timed, symed_over_datasets


def _symed_cfg(tol):
    return SymEDConfig(tol=tol, alpha=0.01, scl=1.0, n_max=256, k_max=64,
                       len_max=256)


def _abba_recon_scores(series: np.ndarray, tol: float) -> np.ndarray:
    """ABBA: encode offline, reconstruct from symbols, DTW in raw space."""
    scores = []
    for row in series:
        res = abba_encode(jnp.asarray(row), n_max=256, tol=tol, scl=1.0,
                          len_max=256, k_max=64)
        rec_n = reconstruct_from_symbols(
            res.labels, res.centers, res.n_pieces,
            jnp.float32((row[0] - float(res.mean)) / float(res.std)),
            len(row),
        )
        rec = rec_n * res.std + res.mean
        scores.append(float(dtw_ref(jnp.asarray(row), rec)))
    return np.asarray(scores)


def run(tols=TOLS) -> Tuple[List[tuple], Dict]:
    data = datasets()
    rows: List[tuple] = []
    summary = {"tol": list(tols), "re_abba": [], "re_symed_sym": [],
               "re_symed_pieces": [], "cr_abba": [], "cr_symed": [],
               "drr_abba": [], "drr_symed": [],
               "sender_ms_per_symbol": None, "receiver_ms_per_symbol": None,
               "total_s_abba": None, "total_s_symed": None}

    # ---- 5a/5b/5c sweeps ---------------------------------------------------
    for tol in tols:
        cfg = _symed_cfg(tol)
        t0 = time.perf_counter()
        enc = symed_over_datasets(cfg, data)
        jax.block_until_ready(enc[next(iter(enc))]["n_pieces"])
        dt = time.perf_counter() - t0

        re_p = equal_weight_mean({f: np.asarray(o["re_pieces"]) for f, o in enc.items()})
        re_s = equal_weight_mean({f: np.asarray(o["re_symbols"]) for f, o in enc.items()})
        cr_s = equal_weight_mean({f: np.asarray(o["cr"]) for f, o in enc.items()})
        drr_s = equal_weight_mean({f: np.asarray(o["drr"]) for f, o in enc.items()})

        abba_re, abba_cr, abba_drr = {}, {}, {}
        for fam, series in data.items():
            res = [abba_encode(jnp.asarray(r), n_max=256, tol=tol, scl=1.0,
                               len_max=256, k_max=64) for r in series]
            abba_cr[fam] = np.asarray([
                float(compression_rate_abba(x.n_pieces, x.k, LENGTH)) for x in res
            ])
            abba_drr[fam] = np.asarray([
                float(x.n_pieces) / LENGTH for x in res
            ])
            abba_re[fam] = _abba_recon_scores(series, tol)

        summary["re_abba"].append(equal_weight_mean(abba_re))
        summary["re_symed_sym"].append(re_s)
        summary["re_symed_pieces"].append(re_p)
        summary["cr_abba"].append(equal_weight_mean(abba_cr))
        summary["cr_symed"].append(cr_s)
        summary["drr_abba"].append(equal_weight_mean(abba_drr))
        summary["drr_symed"].append(drr_s)
        rows.append((f"fig5_sweep_tol{tol}", 1e6 * dt, re_p))

    # ---- 5d: per-symbol online latencies ------------------------------------
    stream = jnp.asarray(data["sensor"][0])
    cfg = _symed_cfg(0.5)
    from repro.core.compress import compress_stream
    from repro.core.digitize import digitize_pieces
    from repro.core.receiver import compact_events

    ev, t_send = timed(
        lambda: compress_stream(stream, tol=0.5, len_max=256, alpha=0.01))
    wire = compact_events(ev, n_max=256, t0=stream[0])
    n = max(int(wire["n_pieces"]), 1)
    _, t_recv = timed(
        lambda: digitize_pieces(wire["lengths"], wire["incs"], wire["n_pieces"],
                                jax.random.key(0), k_cap=64, tol=0.5, scl=1.0,
                                k_min=3, k_max_active=64))
    summary["sender_ms_per_symbol"] = 1e3 * t_send / n
    summary["receiver_ms_per_symbol"] = 1e3 * t_recv / n
    rows.append(("fig5d_sender_per_symbol", 1e6 * t_send / n, n))
    rows.append(("fig5d_receiver_per_symbol", 1e6 * t_recv / n, n))

    # ---- 5e: total conversion latency ---------------------------------------
    _, t_abba = timed(lambda: abba_encode(stream, n_max=256, tol=0.5, scl=1.0,
                                          len_max=256, k_max=64))
    _, t_symed = timed(lambda: symed_encode(stream, cfg, jax.random.key(0),
                                            reconstruct=True))
    summary["total_s_abba"] = t_abba
    summary["total_s_symed"] = t_symed
    rows.append(("fig5e_abba_total", 1e6 * t_abba, float(t_abba)))
    rows.append(("fig5e_symed_total", 1e6 * t_symed, float(t_symed)))
    return rows, summary
