"""Regenerate the data-driven tables inside EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m benchmarks.build_experiments
Reads results/dryrun/*.json, results/perf/*.json, results/bench_summary.json;
rewrites the blocks between the AUTOGEN markers in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks.roofline import load_cells, table

PERF_DIR = Path("results/perf")
SUMMARY = Path("results/bench_summary.json")
DOC = Path("EXPERIMENTS.md")


def fig5_table(summary: dict) -> str:
    f = summary.get("fig5")
    if not f:
        return "_benchmarks not yet run_"
    hdr = "| tol | RE ABBA (sym) | RE SymED (sym) | RE SymED (pieces) | CR ABBA | CR SymED | DRR ABBA | DRR SymED |"
    lines = [hdr, "|" + "---|" * 8]
    for i, tol in enumerate(f["tol"]):
        lines.append(
            f"| {tol} | {f['re_abba'][i]:.2f} | {f['re_symed_sym'][i]:.2f} "
            f"| {f['re_symed_pieces'][i]:.2f} | {f['cr_abba'][i]:.4f} "
            f"| {f['cr_symed'][i]:.4f} | {f['drr_abba'][i]:.4f} "
            f"| {f['drr_symed'][i]:.4f} |"
        )
    lines.append("")
    lines.append(
        f"Per-symbol latency (CPU container): sender "
        f"{f['sender_ms_per_symbol']:.2f} ms, receiver "
        f"{f['receiver_ms_per_symbol']:.2f} ms (paper, RPi 4B: 30 ms / 12 ms). "
        f"Total conversion: ABBA {f['total_s_abba']:.2f} s vs SymED "
        f"{f['total_s_symed']:.2f} s (paper: 2.0 s vs 5.3 s)."
    )
    return "\n".join(lines)


def perf_table() -> str:
    rows = []
    for p in sorted(PERF_DIR.glob("*.json")):
        c = json.loads(p.read_text())
        r, m = c["roofline"], c["memory"]
        tag = p.stem
        rows.append(
            f"| {tag} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {m['peak_bytes_per_dev'] / 2**30:.2f} |"
        )
    if not rows:
        return "_no perf variants recorded_"
    hdr = "| variant | compute_s | memory_s | collective_s | dominant | peak GiB/dev |"
    return "\n".join([hdr, "|" + "---|" * 6] + rows)


def replace_block(text: str, name: str, content: str) -> str:
    pat = re.compile(
        rf"(<!-- AUTOGEN:{name} -->).*?(<!-- /AUTOGEN:{name} -->)", re.S
    )
    return pat.sub(lambda m: f"{m.group(1)}\n{content}\n{m.group(2)}", text)


def main():
    doc = DOC.read_text()
    summary = json.loads(SUMMARY.read_text()) if SUMMARY.exists() else {}
    doc = replace_block(doc, "ROOFLINE", table(load_cells()))
    doc = replace_block(doc, "FIG5", fig5_table(summary))
    doc = replace_block(doc, "PERF", perf_table())
    DOC.write_text(doc)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
