"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On this CPU container the interpret-mode timing validates dispatch overheads
only; the DERIVED column is the max abs error vs the oracle (the correctness
contract).  The same harness runs compiled on TPU.
"""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import timed


def run() -> Tuple[List[tuple], dict]:
    rng = np.random.default_rng(0)
    rows: List[tuple] = []

    # ewma: fleet-shaped (streams x time)
    ts = jnp.asarray(rng.normal(0, 2, (64, 2048)), jnp.float32)
    (m1, v1), t_k = timed(lambda: ops.ewma_scan(ts, 0.02))
    (m2, v2), t_r = timed(lambda: ref.ewma_scan_ref(ts, 0.02))
    err = float(jnp.max(jnp.abs(v1 - v2)))
    rows.append(("ewma_pallas_64x2048", 1e6 * t_k, err))
    rows.append(("ewma_ref_64x2048", 1e6 * t_r, err))

    # kmeans: SymED receiver shape (D=2) and MXU-shaped D=128
    for d in (2, 128):
        x = jnp.asarray(rng.normal(size=(8, 256, d)), jnp.float32)
        mask = jnp.ones((8, 256), jnp.float32)
        c = jnp.asarray(rng.normal(size=(8, 64, d)), jnp.float32)
        act = jnp.ones((8, 64), jnp.float32)
        (l1, s1, c1), t_k = timed(lambda: ops.kmeans_assign(x, mask, c, act))
        (l2, s2, c2), t_r = timed(lambda: ref.kmeans_assign_ref(x, mask, c, act))
        err = float(jnp.max(jnp.abs(s1 - s2)))
        rows.append((f"kmeans_pallas_8x256x{d}", 1e6 * t_k, err))
        rows.append((f"kmeans_ref_8x256x{d}", 1e6 * t_r, err))

    # dtw: reconstruction-error evaluation shape
    x = jnp.asarray(rng.normal(size=(8, 512)).cumsum(1), jnp.float32)
    y = x + jnp.asarray(rng.normal(0, 0.3, (8, 512)), jnp.float32)
    d1, t_k = timed(lambda: ops.dtw(x, y, band=64))
    d2, t_r = timed(lambda: ref.dtw_batch_ref(x, y, band=64))
    err = float(jnp.max(jnp.abs(d1 - d2)))
    rows.append(("dtw_pallas_8x512_band64", 1e6 * t_k, err))
    rows.append(("dtw_ref_8x512_band64", 1e6 * t_r, err))

    return rows, {"max_err": max(r[2] for r in rows)}
