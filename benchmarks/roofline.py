"""Roofline table builder: aggregates the dry-run JSONs into the
EXPERIMENTS.md Sec. Roofline table (per arch x shape x mesh: three terms,
dominant bottleneck, MODEL_FLOPS ratio, memory fit)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

DRYRUN_DIR = Path("results/dryrun")
HBM_PER_CHIP = 16 * 2 ** 30  # v5e


def load_cells(directory: Path = DRYRUN_DIR) -> List[dict]:
    cells = []
    for p in sorted(directory.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def table(cells: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | peak GiB/dev | fits | useful ratio |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for c in cells:
        r, m = c["roofline"], c["memory"]
        peak = m["peak_bytes_per_dev"] / 2 ** 30
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {peak:.2f} | {'Y' if peak * 2**30 <= HBM_PER_CHIP else 'N'} "
            f"| {c['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(lines)


def run() -> Tuple[List[tuple], dict]:
    cells = load_cells()
    rows = []
    for c in cells:
        r = c["roofline"]
        dom_s = r[f"{r['dominant']}_s"]
        rows.append((
            f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}",
            1e6 * dom_s,  # dominant term in us
            c["useful_flops_ratio"],
        ))
    return rows, {"n_cells": len(cells), "table": table(cells)}


if __name__ == "__main__":
    print(table(load_cells()))
