"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One suite per paper table/figure (Fig. 5a-e), plus the kernel microbench,
fleet-throughput scale-out, and the roofline aggregation over dry-run JSONs.
Prints ``name,us_per_call,derived`` CSV; writes the machine-readable summary
to results/bench_summary.json (EXPERIMENTS.md quotes it).
"""
import json
import os
import sys
from pathlib import Path


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import fig5_suite, fleet_scale, kernels_bench, roofline

    all_rows = []
    summaries = {}

    for name, mod in (
        ("fig5", fig5_suite), ("kernels", kernels_bench),
        ("fleet", fleet_scale), ("roofline", roofline),
    ):
        try:
            rows, summary = mod.run()
        except FileNotFoundError as e:  # roofline needs dry-run outputs
            print(f"# {name}: skipped ({e})", file=sys.stderr)
            continue
        all_rows.extend(rows)
        summaries[name] = {k: v for k, v in summary.items() if k != "table"}

    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")

    out = Path("results")
    out.mkdir(exist_ok=True)
    (out / "bench_summary.json").write_text(json.dumps(summaries, indent=2))
    print(f"# summary -> {out / 'bench_summary.json'}", file=sys.stderr)


if __name__ == "__main__":
    main()
