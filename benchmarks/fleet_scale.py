"""Fleet-throughput benchmark (the TPU adaptation's headline table):
streams/second for the batched SymED pipeline as the slab grows."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet

from benchmarks.common import timed


def run() -> Tuple[List[tuple], dict]:
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=32, len_max=128)
    rows: List[tuple] = []
    summary = {}
    for n_streams in (16, 64, 256):
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        out, dt = timed(
            lambda f=fleet: symed_batch(f, cfg, jax.random.key(0),
                                        reconstruct=False),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        rows.append((f"fleet_{n_streams}x512", 1e6 * dt, pts / dt))
        summary[f"streams_{n_streams}"] = {
            "points_per_s": pts / dt,
            "mean_pieces": float(jnp.mean(out["n_pieces"])),
        }
    return rows, summary
