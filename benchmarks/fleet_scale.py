"""Fleet-throughput benchmark (the TPU adaptation's headline table):
streams/second for the batched SymED pipeline as the slab grows, plus the
sharded ``repro.launch.fleet`` runtime (shard_map over the ``data`` axis,
chunked online ingestion) on whatever devices exist."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet
from repro.launch.fleet import fleet_data_mesh, run_fleet

from benchmarks.common import timed


def run() -> Tuple[List[tuple], dict]:
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=32, len_max=128)
    rows: List[tuple] = []
    summary = {}
    for n_streams in (16, 64, 256):
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        out, dt = timed(
            lambda f=fleet: symed_batch(f, cfg, jax.random.key(0),
                                        reconstruct=False),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        rows.append((f"fleet_{n_streams}x512", 1e6 * dt, pts / dt))
        summary[f"streams_{n_streams}"] = {
            "points_per_s": pts / dt,
            "mean_pieces": float(jnp.mean(out["n_pieces"])),
        }

    # sharded runtime variant: same pipeline through shard_map + chunked
    # streaming ingestion (on this container the mesh is 1 CPU device; on the
    # pod target the same call spans the full ``data`` axis)
    mesh = fleet_data_mesh()
    for n_streams, chunk in ((64, None), (64, 128), (256, 128)):
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        (out, tele), dt = timed(
            lambda f=fleet, c=chunk: run_fleet(
                f, cfg, jax.random.key(0), mesh, chunk_len=c,
                reconstruct=False,
            ),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        mode = f"chunk{chunk}" if chunk else "whole"
        rows.append((f"fleet_sharded_{n_streams}x512_{mode}", 1e6 * dt, pts / dt))
        summary[f"sharded_{n_streams}_{mode}"] = {
            "points_per_s": pts / dt,
            "devices": int(mesh.devices.size),
            "fleet_wire_bytes": float(tele["wire_bytes"]),
            "fleet_compression_rate": float(tele["wire_bytes"])
            / float(tele["raw_bytes"]),
        }
    return rows, summary
