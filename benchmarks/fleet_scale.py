"""Fleet-throughput benchmark (the TPU adaptation's headline table):
streams/second for the batched SymED pipeline as the slab grows, plus the
sharded ``repro.launch.fleet`` runtime on whatever devices exist -- flat
``data`` sharding, the streaming receiver at several digitize cadences, and
the 2-D ``(pod, data)`` layout with hierarchical telemetry reduction (on the
16x16 dry-run pod the same rows span 256 chips; here the mesh degenerates to
the local device count).  The resident stream service is metered per arrival
tick in three shapes: raw-in (masked compressor scan), compressed-in (the
transport's pieces mode: scatter + cadenced digitize), and the slab-rerun
anti-pattern.

CLI (the CI ``bench-artifacts`` job runs exactly this):

    PYTHONPATH=src python -m benchmarks.fleet_scale --quick --out BENCH_fleet.json

``BENCH_fleet.json`` schema (version ``bench_fleet/v1``):

    {
      "schema": "bench_fleet/v1",
      "env": {"devices": int, "backend": str, "quick": bool},
      "rows": [                      # one entry per benchmark row
        {"name": str,                # e.g. "fleet_sharded_64x512_chunk128"
         "us_per_call": float,       # mean wall latency per metered call
         "points_per_s": float}      # derived throughput of that row
      ],
      "summary": {...}               # per-section dicts: the same keys
    }                                # ``run()`` has always returned --
                                     # latency / compression / wire ratios

``rows`` is the stable machine-readable perf trajectory (compare across
commits by row name); ``summary`` carries the richer per-section numbers
(``fleet_compression_rate``, ``ms_per_symbol``, ``wire_in_ratio``,
``resident_speedup``, ...).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import pieces_on_wire
from repro.core.symed import SymEDConfig, symed_batch, symed_encode_chunk
from repro.data.synthetic import make_fleet
from repro.launch.fleet import fleet_data_mesh, fleet_report, run_fleet
from repro.launch.mesh import make_pod_data_mesh
from repro.launch.stream import StreamServer

from benchmarks.common import timed


def run(quick: bool = False) -> Tuple[List[tuple], dict]:
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=32, len_max=128)
    rows: List[tuple] = []
    summary = {}
    t_len = 256 if quick else 512
    for n_streams in (8, 32) if quick else (16, 64, 256):
        fleet = jnp.asarray(make_fleet(n_streams, t_len, seed=1))
        out, dt = timed(
            lambda f=fleet: symed_batch(f, cfg, jax.random.key(0),
                                        reconstruct=False),
            warmup=1, iters=2,
        )
        pts = n_streams * t_len
        rows.append((f"fleet_{n_streams}x{t_len}", 1e6 * dt, pts / dt))
        summary[f"streams_{n_streams}"] = {
            "points_per_s": pts / dt,
            "mean_pieces": float(jnp.mean(out["n_pieces"])),
        }

    # sharded runtime variant: same pipeline through shard_map + the streaming
    # receiver at several digitize cadences (on this container the mesh is 1
    # CPU device; on the pod target the same call spans the full ``data``
    # axis).  k=None digitizes once at end-of-stream; k=1/2 emit symbols
    # online -- deliberately the expensive shape (the receiver's k-means runs
    # T/(C*k) times per stream), so these rows use a smaller slab.  Stream
    # counts are rounded up to a device-count multiple so the same rows run
    # on any mesh (run_fleet requires an even shard split).
    n_dev = jax.device_count()
    round_up = lambda n: -(-n // n_dev) * n_dev
    mesh = fleet_data_mesh()
    chunk = 64 if quick else 128
    combos = ((16, chunk, None), (16, chunk, 2)) if quick else (
        (64, None, None), (64, 128, None), (256, 128, None),
        (32, 128, 1), (32, 128, 2),
    )
    for n_streams, c_len, dk in combos:
        n_streams = round_up(n_streams)
        fleet = jnp.asarray(make_fleet(n_streams, t_len, seed=1))
        (out, tele), dt = timed(
            lambda f=fleet, c=c_len, k=dk: run_fleet(
                f, cfg, jax.random.key(0), mesh, chunk_len=c,
                digitize_every_k=k, reconstruct=False,
            ),
            warmup=1, iters=2,
        )
        pts = n_streams * t_len
        mode = (f"chunk{c_len}_k{dk}" if dk else
                f"chunk{c_len}" if c_len else "whole")
        rows.append((f"fleet_sharded_{n_streams}x{t_len}_{mode}", 1e6 * dt,
                     pts / dt))
        rep = fleet_report(tele, dt)
        summary[f"sharded_{n_streams}_{mode}"] = {
            "points_per_s": pts / dt,
            "devices": int(mesh.devices.size),
            "fleet_wire_bytes": rep["wire_bytes"],
            "fleet_compression_rate": rep["compression_rate"],
            "wire_in_ratio": rep["wire_in_ratio"],
            "wire_out_ratio": rep["wire_out_ratio"],
            "ms_per_symbol": rep["ms_per_symbol"],
        }

    # multi-pod layout: shard over the flattened (pod, data) grid with the
    # hierarchical psum tree (data within a pod, then across pods).  Pod count
    # degenerates to 1 on a single local device; on the dry-run target this is
    # the 2 x 256 two-pod mesh.
    n_pods = 2 if n_dev % 2 == 0 and n_dev >= 2 else 1
    pod_mesh = make_pod_data_mesh(n_pods, n_dev // n_pods)
    n_streams = round_up(16 if quick else 32)
    fleet = jnp.asarray(make_fleet(n_streams, t_len, seed=1))
    (out, tele), dt = timed(
        lambda: run_fleet(
            fleet, cfg, jax.random.key(0), pod_mesh, chunk_len=chunk,
            digitize_every_k=2, reconstruct=False, axis=("pod", "data"),
        ),
        warmup=1, iters=2,
    )
    rep = fleet_report(tele, dt)
    rows.append((f"fleet_pods{n_pods}_{n_streams}x{t_len}_chunk{chunk}_k2",
                 1e6 * dt, n_streams * t_len / dt))
    summary["pod_data"] = {
        "points_per_s": n_streams * t_len / dt,
        "streams": n_streams,
        "layout": f"{n_pods}x{n_dev // n_pods}",
        "fleet_compression_rate": rep["compression_rate"],
        "ms_per_symbol": rep["ms_per_symbol"],
    }

    # sessions-resident service vs slab re-run: the same arrival tick (every
    # stream delivers one W-point window) costs one donated batched table
    # step when the ReceiverState stays resident (repro.launch.stream), vs a
    # full re-encode of the materialized slab when it doesn't -- the
    # batch-replay anti-pattern a naive service falls into at steady state.
    svc_streams, svc_len, svc_win = round_up(8), 128 if quick else 256, 64
    slab_np = np.asarray(make_fleet(svc_streams, svc_len, seed=3))
    server = StreamServer(cfg, max_sessions=svc_streams, window_cap=svc_win,
                          digitize_every_k=1)
    sids = [f"s{i}" for i in range(svc_streams)]
    for sid in sids:
        server.open(sid)

    def tick(c):
        server.ingest_many(
            {sid: slab_np[i, c: c + svc_win] for i, sid in enumerate(sids)})

    tick(0)  # compiles the donated step; steady state is what we meter
    n_ticks = (svc_len - svc_win) // svc_win
    t0 = time.perf_counter()
    for c in range(svc_win, svc_len, svc_win):
        tick(c)
    dt_resident = (time.perf_counter() - t0) / max(n_ticks, 1)
    for sid in sids:
        server.close(sid)

    # compressed-in service tick: the transport's pieces mode.  Senders run
    # the compressor (pre-materialized here, outside the metered region);
    # the receiver's tick is a wire-buffer scatter + cadenced digitize.
    pieces_server = StreamServer(cfg, max_sessions=svc_streams,
                                 window_cap=svc_win, digitize_every_k=1)
    for sid in sids:
        pieces_server.open(sid)
    states = {sid: None for sid in sids}
    tick_arrivals = []
    for c in range(0, svc_len, svc_win):
        arr = {}
        for i, sid in enumerate(sids):
            w = slab_np[i, c: c + svc_win]
            states[sid], ev = symed_encode_chunk(jnp.asarray(w), cfg,
                                                 states[sid])
            eps, steps = pieces_on_wire(ev, c)
            arr[sid] = {"endpoints": eps, "steps": steps,
                        "t_seen": c + len(w), "t0": float(slab_np[i, 0])}
        tick_arrivals.append(arr)
    pieces_server.ingest_pieces_many(tick_arrivals[0])  # compile
    t0 = time.perf_counter()
    for arr in tick_arrivals[1:]:
        pieces_server.ingest_pieces_many(arr)
    dt_pieces = (time.perf_counter() - t0) / max(len(tick_arrivals) - 1, 1)
    pieces_rep = pieces_server.report(1.0)
    for sid in sids:
        pieces_server.close(sid)

    slab = jnp.asarray(slab_np)
    _, dt_slab = timed(
        lambda: symed_batch(slab, cfg, jax.random.key(0), reconstruct=False),
        warmup=1, iters=2,
    )
    pts_tick = svc_streams * svc_win
    rows.append((f"service_resident_tick_{svc_streams}x{svc_len}_w{svc_win}",
                 1e6 * dt_resident, pts_tick / dt_resident))
    rows.append((f"service_pieces_in_tick_{svc_streams}x{svc_len}_w{svc_win}",
                 1e6 * dt_pieces, pts_tick / dt_pieces))
    rows.append((f"service_slab_rerun_tick_{svc_streams}x{svc_len}",
                 1e6 * dt_slab, pts_tick / dt_slab))
    summary["stream_service"] = {
        "sessions": svc_streams,
        "window": svc_win,
        "resident_tick_ms": 1e3 * dt_resident,
        "pieces_in_tick_ms": 1e3 * dt_pieces,
        "slab_rerun_tick_ms": 1e3 * dt_slab,
        "resident_speedup": dt_slab / max(dt_resident, 1e-12),
        "wire_out_bytes": server.totals["bytes_out"],
        "wire_in_ratio_pieces": pieces_rep["wire_in_ratio"],
    }

    # resident-tick scaling: the same steady-state arrival tick at larger
    # session counts (one donated table step regardless of fleet size), and
    # a digitize-cadence sweep at the base count.  Off-cadence ticks digitize
    # an *empty* span (the while-loop trip count is the span width, not
    # n_max), so averaged over a cadence period k > 1 costs about the same
    # digitize work as k=1 -- the sweep meters enough ticks to amortize the
    # wider on-cadence spans against the no-op off-cadence ones.
    def resident_tick_s(n_sessions: int, dk: int, length: int,
                        obs=None) -> float:
        n_sessions = round_up(n_sessions)
        slab = np.asarray(make_fleet(n_sessions, length, seed=3))
        srv = StreamServer(cfg, max_sessions=n_sessions, window_cap=svc_win,
                           digitize_every_k=dk, obs=obs)
        ids = [f"r{i}" for i in range(n_sessions)]
        for sid in ids:
            srv.open(sid)

        def tick(c):
            srv.ingest_many({sid: slab[i, c: c + svc_win]
                             for i, sid in enumerate(ids)})

        tick(0)  # compiles the donated step; steady state is what we meter
        t0 = time.perf_counter()
        for c in range(svc_win, length, svc_win):
            tick(c)
        dt = ((time.perf_counter() - t0)
              / max((length - svc_win) // svc_win, 1))
        for sid in ids:
            srv.close(sid)
        return dt

    scale = {}
    for n_sessions in (8, 32, 64):
        dt = resident_tick_s(n_sessions, 1, svc_len)
        pts = round_up(n_sessions) * svc_win
        rows.append((f"service_resident_tick_{round_up(n_sessions)}"
                     f"x{svc_len}_w{svc_win}_scale", 1e6 * dt, pts / dt))
        scale[f"sessions_{n_sessions}"] = {
            "tick_ms": 1e3 * dt, "points_per_s": pts / dt}
    cadence = {}
    cad_len = svc_win * 8  # 7 metered ticks: full k=4 period amortized twice
    for dk in (1, 2, 4):
        dt = resident_tick_s(svc_streams, dk, cad_len)
        pts = svc_streams * svc_win
        rows.append((f"service_resident_tick_{svc_streams}x{cad_len}"
                     f"_w{svc_win}_k{dk}", 1e6 * dt, pts / dt))
        cadence[f"k_{dk}"] = {"tick_ms": 1e3 * dt, "points_per_s": pts / dt}
    summary["stream_service"]["scale"] = scale
    summary["stream_service"]["cadence"] = cadence

    # trace-driven service rows: the workload harness replays a seeded
    # scenario trace through the resident server.  Each row's trace comes
    # from an *explicit per-row seed* (``scenario_seed(name, 0)``), never a
    # shared rng threaded across rows, so reordering, adding, or deleting
    # rows cannot perturb any other row's schedule (pinned by the
    # reorder-invariance test in tests/test_workload.py).
    from repro.workload import Workload, scenario_seed
    from repro.workload.replay import replay_trace

    wl_shape = {"sessions": 4 if quick else 8, "length": svc_len,
                "window": svc_win}
    workload_summary = {}
    for sc_name in ("bursty", "flash_crowd"):
        wl = Workload(sc_name, seed=scenario_seed(sc_name, 0), **wl_shape)
        res = replay_trace(wl.trace(), cfg=cfg, server_kw=wl.server_kw())
        drains = max(int(res.queue["drains"]), 1)
        pts = res.counters["points_in"]
        rows.append((f"workload_{sc_name}_{wl_shape['sessions']}x{svc_len}"
                     f"_w{svc_win}", 1e6 * res.wall_seconds / drains,
                     pts / max(res.wall_seconds, 1e-12)))
        workload_summary[sc_name] = {
            "seed": scenario_seed(sc_name, 0),
            "points_per_s": pts / max(res.wall_seconds, 1e-12),
            "drain_ms": 1e3 * res.wall_seconds / drains,
            "max_queue_depth": res.queue["max_depth"],
            "evicted": res.counters["evicted"],
            "p99_symbol_ms": res.latency["p99_ms"],
        }
    summary["workload"] = workload_summary

    # flight-recorder overhead: the identical steady-state tick with the
    # observability layer enabled (the default) vs disabled (obs=False,
    # shared null instruments).  Interleaved min-of-2 runs cancel most
    # scheduler noise; ``check_bench.py`` gates overhead_frac at <= 5%
    # (with a small absolute floor for sub-ms jitter).  Both measurements
    # come from this same artifact, so the gate needs no baseline.
    obs_len = svc_win * (6 if quick else 12)
    runs = {True: [], False: []}
    for _ in range(2):
        for enabled in (False, True):
            runs[enabled].append(resident_tick_s(
                svc_streams, 1, obs_len, obs=None if enabled else False))
    dt_on, dt_off = min(runs[True]), min(runs[False])
    pts = svc_streams * svc_win
    rows.append((f"service_resident_tick_obs_on_{svc_streams}x{obs_len}"
                 f"_w{svc_win}", 1e6 * dt_on, pts / dt_on))
    rows.append((f"service_resident_tick_obs_off_{svc_streams}x{obs_len}"
                 f"_w{svc_win}", 1e6 * dt_off, pts / dt_off))
    summary["stream_service"]["obs"] = {
        "tick_ms_obs_on": 1e3 * dt_on,
        "tick_ms_obs_off": 1e3 * dt_off,
        "overhead_frac": (dt_on - dt_off) / max(dt_off, 1e-12),
    }
    return rows, summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized slabs (seconds, not minutes)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the machine-readable BENCH_fleet.json here")
    args = ap.parse_args()

    rows, summary = run(quick=args.quick)
    print("name,us_per_call,points_per_s")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.1f}")
    if args.out:
        doc = {
            "schema": "bench_fleet/v1",
            "env": {
                "devices": int(jax.device_count()),
                "backend": jax.default_backend(),
                "quick": bool(args.quick),
            },
            "rows": [
                {"name": n, "us_per_call": round(us, 1),
                 "points_per_s": round(d, 1)}
                for n, us, d in rows
            ],
            "summary": summary,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
