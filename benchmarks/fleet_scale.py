"""Fleet-throughput benchmark (the TPU adaptation's headline table):
streams/second for the batched SymED pipeline as the slab grows, plus the
sharded ``repro.launch.fleet`` runtime on whatever devices exist -- flat
``data`` sharding, the streaming receiver at several digitize cadences, and
the 2-D ``(pod, data)`` layout with hierarchical telemetry reduction (on the
16x16 dry-run pod the same rows span 256 chips; here the mesh degenerates to
the local device count)."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet
from repro.launch.fleet import fleet_data_mesh, fleet_report, run_fleet
from repro.launch.mesh import make_pod_data_mesh

from benchmarks.common import timed


def run() -> Tuple[List[tuple], dict]:
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=32, len_max=128)
    rows: List[tuple] = []
    summary = {}
    for n_streams in (16, 64, 256):
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        out, dt = timed(
            lambda f=fleet: symed_batch(f, cfg, jax.random.key(0),
                                        reconstruct=False),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        rows.append((f"fleet_{n_streams}x512", 1e6 * dt, pts / dt))
        summary[f"streams_{n_streams}"] = {
            "points_per_s": pts / dt,
            "mean_pieces": float(jnp.mean(out["n_pieces"])),
        }

    # sharded runtime variant: same pipeline through shard_map + the streaming
    # receiver at several digitize cadences (on this container the mesh is 1
    # CPU device; on the pod target the same call spans the full ``data``
    # axis).  k=None digitizes once at end-of-stream; k=1/2 emit symbols
    # online -- deliberately the expensive shape (the receiver's k-means runs
    # T/(C*k) times per stream), so these rows use a smaller slab.  Stream
    # counts are rounded up to a device-count multiple so the same rows run
    # on any mesh (run_fleet requires an even shard split).
    n_dev = jax.device_count()
    round_up = lambda n: -(-n // n_dev) * n_dev
    mesh = fleet_data_mesh()
    for n_streams, chunk, dk in (
        (64, None, None), (64, 128, None), (256, 128, None),
        (32, 128, 1), (32, 128, 2),
    ):
        n_streams = round_up(n_streams)
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        (out, tele), dt = timed(
            lambda f=fleet, c=chunk, k=dk: run_fleet(
                f, cfg, jax.random.key(0), mesh, chunk_len=c,
                digitize_every_k=k, reconstruct=False,
            ),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        mode = (f"chunk{chunk}_k{dk}" if dk else
                f"chunk{chunk}" if chunk else "whole")
        rows.append((f"fleet_sharded_{n_streams}x512_{mode}", 1e6 * dt, pts / dt))
        rep = fleet_report(tele, dt)
        summary[f"sharded_{n_streams}_{mode}"] = {
            "points_per_s": pts / dt,
            "devices": int(mesh.devices.size),
            "fleet_wire_bytes": rep["wire_bytes"],
            "fleet_compression_rate": rep["compression_rate"],
            "ms_per_symbol": rep["ms_per_symbol"],
        }

    # multi-pod layout: shard over the flattened (pod, data) grid with the
    # hierarchical psum tree (data within a pod, then across pods).  Pod count
    # degenerates to 1 on a single local device; on the dry-run target this is
    # the 2 x 256 two-pod mesh.
    n_pods = 2 if n_dev % 2 == 0 and n_dev >= 2 else 1
    pod_mesh = make_pod_data_mesh(n_pods, n_dev // n_pods)
    n_streams = round_up(32)
    fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
    (out, tele), dt = timed(
        lambda: run_fleet(
            fleet, cfg, jax.random.key(0), pod_mesh, chunk_len=128,
            digitize_every_k=2, reconstruct=False, axis=("pod", "data"),
        ),
        warmup=1, iters=2,
    )
    rep = fleet_report(tele, dt)
    rows.append((f"fleet_pods{n_pods}_{n_streams}x512_chunk128_k2", 1e6 * dt,
                 n_streams * 512 / dt))
    summary["pod_data"] = {
        "points_per_s": n_streams * 512 / dt,
        "streams": n_streams,
        "layout": f"{n_pods}x{n_dev // n_pods}",
        "fleet_compression_rate": rep["compression_rate"],
        "ms_per_symbol": rep["ms_per_symbol"],
    }
    return rows, summary
