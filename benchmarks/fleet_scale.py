"""Fleet-throughput benchmark (the TPU adaptation's headline table):
streams/second for the batched SymED pipeline as the slab grows, plus the
sharded ``repro.launch.fleet`` runtime on whatever devices exist -- flat
``data`` sharding, the streaming receiver at several digitize cadences, and
the 2-D ``(pod, data)`` layout with hierarchical telemetry reduction (on the
16x16 dry-run pod the same rows span 256 chips; here the mesh degenerates to
the local device count)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet
from repro.launch.fleet import fleet_data_mesh, fleet_report, run_fleet
from repro.launch.mesh import make_pod_data_mesh
from repro.launch.stream import StreamServer

from benchmarks.common import timed


def run() -> Tuple[List[tuple], dict]:
    cfg = SymEDConfig(tol=0.5, alpha=0.01, n_max=128, k_max=32, len_max=128)
    rows: List[tuple] = []
    summary = {}
    for n_streams in (16, 64, 256):
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        out, dt = timed(
            lambda f=fleet: symed_batch(f, cfg, jax.random.key(0),
                                        reconstruct=False),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        rows.append((f"fleet_{n_streams}x512", 1e6 * dt, pts / dt))
        summary[f"streams_{n_streams}"] = {
            "points_per_s": pts / dt,
            "mean_pieces": float(jnp.mean(out["n_pieces"])),
        }

    # sharded runtime variant: same pipeline through shard_map + the streaming
    # receiver at several digitize cadences (on this container the mesh is 1
    # CPU device; on the pod target the same call spans the full ``data``
    # axis).  k=None digitizes once at end-of-stream; k=1/2 emit symbols
    # online -- deliberately the expensive shape (the receiver's k-means runs
    # T/(C*k) times per stream), so these rows use a smaller slab.  Stream
    # counts are rounded up to a device-count multiple so the same rows run
    # on any mesh (run_fleet requires an even shard split).
    n_dev = jax.device_count()
    round_up = lambda n: -(-n // n_dev) * n_dev
    mesh = fleet_data_mesh()
    for n_streams, chunk, dk in (
        (64, None, None), (64, 128, None), (256, 128, None),
        (32, 128, 1), (32, 128, 2),
    ):
        n_streams = round_up(n_streams)
        fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
        (out, tele), dt = timed(
            lambda f=fleet, c=chunk, k=dk: run_fleet(
                f, cfg, jax.random.key(0), mesh, chunk_len=c,
                digitize_every_k=k, reconstruct=False,
            ),
            warmup=1, iters=2,
        )
        pts = n_streams * 512
        mode = (f"chunk{chunk}_k{dk}" if dk else
                f"chunk{chunk}" if chunk else "whole")
        rows.append((f"fleet_sharded_{n_streams}x512_{mode}", 1e6 * dt, pts / dt))
        rep = fleet_report(tele, dt)
        summary[f"sharded_{n_streams}_{mode}"] = {
            "points_per_s": pts / dt,
            "devices": int(mesh.devices.size),
            "fleet_wire_bytes": rep["wire_bytes"],
            "fleet_compression_rate": rep["compression_rate"],
            "ms_per_symbol": rep["ms_per_symbol"],
        }

    # multi-pod layout: shard over the flattened (pod, data) grid with the
    # hierarchical psum tree (data within a pod, then across pods).  Pod count
    # degenerates to 1 on a single local device; on the dry-run target this is
    # the 2 x 256 two-pod mesh.
    n_pods = 2 if n_dev % 2 == 0 and n_dev >= 2 else 1
    pod_mesh = make_pod_data_mesh(n_pods, n_dev // n_pods)
    n_streams = round_up(32)
    fleet = jnp.asarray(make_fleet(n_streams, 512, seed=1))
    (out, tele), dt = timed(
        lambda: run_fleet(
            fleet, cfg, jax.random.key(0), pod_mesh, chunk_len=128,
            digitize_every_k=2, reconstruct=False, axis=("pod", "data"),
        ),
        warmup=1, iters=2,
    )
    rep = fleet_report(tele, dt)
    rows.append((f"fleet_pods{n_pods}_{n_streams}x512_chunk128_k2", 1e6 * dt,
                 n_streams * 512 / dt))
    summary["pod_data"] = {
        "points_per_s": n_streams * 512 / dt,
        "streams": n_streams,
        "layout": f"{n_pods}x{n_dev // n_pods}",
        "fleet_compression_rate": rep["compression_rate"],
        "ms_per_symbol": rep["ms_per_symbol"],
    }

    # sessions-resident service vs slab re-run: the same arrival tick (every
    # stream delivers one W-point window) costs one donated batched table
    # step when the ReceiverState stays resident (repro.launch.stream), vs a
    # full re-encode of the materialized slab when it doesn't -- the
    # batch-replay anti-pattern a naive service falls into at steady state.
    svc_streams, svc_len, svc_win = round_up(8), 256, 64
    slab_np = np.asarray(make_fleet(svc_streams, svc_len, seed=3))
    server = StreamServer(cfg, max_sessions=svc_streams, window_cap=svc_win,
                          digitize_every_k=1)
    sids = [f"s{i}" for i in range(svc_streams)]
    for sid in sids:
        server.open(sid)

    def tick(c):
        server.ingest_many(
            {sid: slab_np[i, c: c + svc_win] for i, sid in enumerate(sids)})

    tick(0)  # compiles the donated step; steady state is what we meter
    n_ticks = (svc_len - svc_win) // svc_win
    t0 = time.perf_counter()
    for c in range(svc_win, svc_len, svc_win):
        tick(c)
    dt_resident = (time.perf_counter() - t0) / max(n_ticks, 1)
    for sid in sids:
        server.close(sid)

    slab = jnp.asarray(slab_np)
    _, dt_slab = timed(
        lambda: symed_batch(slab, cfg, jax.random.key(0), reconstruct=False),
        warmup=1, iters=2,
    )
    pts_tick = svc_streams * svc_win
    rows.append((f"service_resident_tick_{svc_streams}x{svc_len}_w{svc_win}",
                 1e6 * dt_resident, pts_tick / dt_resident))
    rows.append((f"service_slab_rerun_tick_{svc_streams}x{svc_len}",
                 1e6 * dt_slab, pts_tick / dt_slab))
    summary["stream_service"] = {
        "sessions": svc_streams,
        "window": svc_win,
        "resident_tick_ms": 1e3 * dt_resident,
        "slab_rerun_tick_ms": 1e3 * dt_slab,
        "resident_speedup": dt_slab / max(dt_resident, 1e-12),
        "wire_out_bytes": server.totals["bytes_out"],
    }
    return rows, summary
