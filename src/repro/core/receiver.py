"""SymED wire format + receiver-side piece construction (paper Alg. 2).

The sender transmits one raw float per finished piece (the segment endpoint)
plus a one-off 4-byte "hello" carrying t0.  The receiver reconstructs each
piece locally:

  * ``inc_i = e_i - e_{i-1}``  (with ``e_{-1} = t0``),
  * ``len_i`` from *arrival times*: in the fleet simulator the ingest clock is
    the stream step index, so ``len_i = step_i - step_{i-1}`` (with the
    convention ``step_{-1} = 1`` -- the first piece starts at t0, and a piece
    emitted while processing step j ends at point j-1).

``compact_events`` turns the sender's per-step event arrays into padded
per-piece buffers -- this is the scatter that model the sender->receiver wire.
``compact_chunk`` / ``append_tail`` are the resumable pieces of the same
scatter: the streaming receiver (``repro.core.symed.symed_receive_chunk``)
applies ``compact_chunk`` per arriving window, carrying only the padded
buffers + counters across chunk boundaries, and ``append_tail`` folds the
sender's trailing flush in at end-of-stream.  ``compact_events`` is written
*in terms of* those two helpers so the whole-stream and streaming paths stay
bitwise-identical by construction.
"""
from __future__ import annotations

import functools
import struct
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DELTA_FRAME_HEADER_BYTES", "DELTA_SYMBOL_BYTES", "PIECE_TUPLE_BYTES",
    "append_tail", "compact_chunk", "compact_events", "delta_frame_bytes",
    "pack_delta_frame", "pack_piece_tuples", "pieces_from_wire",
    "unpack_delta_frame", "unpack_piece_tuples",
]

# Symbol-delta frame layout (the service's outbound counterpart of the
# 4-byte-per-piece wire *in*): a count header plus, per newly digitized
# piece, a 1-byte symbol label and the 4-byte raw endpoint -- so downstream
# consumers can resync the piece chain without replaying the stream.  Host
# bookkeeping (repro.launch.stream) uses the constants directly to avoid
# device scalars in its steady-state loop.  ``pack_delta_frame`` /
# ``unpack_delta_frame`` are the byte-level realization of exactly this
# layout: ``len(pack_delta_frame(l, e)) == delta_frame_bytes(len(l))``, and
# ``repro.launch.transport`` puts these bytes on a real socket.
DELTA_FRAME_HEADER_BYTES = 4.0
DELTA_SYMBOL_BYTES = 5.0  # 1B label + 4B endpoint

# Inbound compressed-piece tuple (``repro.launch.transport`` pieces mode):
# the paper's sender transmits one raw f32 endpoint per piece; a batched
# transport must also carry the arrival step explicitly (u32), since framing
# detaches pieces from the ingest clock.
PIECE_TUPLE_BYTES = 8.0  # 4B endpoint + 4B arrival step

# numpy record layouts of the two wire payloads (big-endian, packed)
_DELTA_REC = np.dtype([("label", "u1"), ("endpoint", ">f4")])
_PIECE_REC = np.dtype([("endpoint", ">f4"), ("step", ">u4")])


def delta_frame_bytes(n_new: jax.Array) -> jax.Array:
    """Wire-out bytes of one symbol-delta frame carrying ``n_new`` symbols."""
    return (DELTA_FRAME_HEADER_BYTES
            + DELTA_SYMBOL_BYTES * jnp.asarray(n_new, jnp.float32))


def pack_delta_frame(labels, endpoints) -> bytes:
    """Serialize one symbol-delta frame: ``!I`` count + per-symbol record.

    Per symbol: u1 label + big-endian f32 raw endpoint (the documented
    4 B header + 5 B/symbol layout; labels wrap at 256 like
    ``symbols_to_string``'s alphabet fold).
    """
    labels = np.asarray(labels)
    rec = np.empty(labels.shape[0], _DELTA_REC)
    rec["label"] = labels.astype(np.int64) % 256
    rec["endpoint"] = np.asarray(endpoints, np.float32)
    return struct.pack("!I", labels.shape[0]) + rec.tobytes()


def unpack_delta_frame(buf: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``pack_delta_frame``: ``(labels i32, endpoints f32)``."""
    (n,) = struct.unpack_from("!I", buf)
    rec = np.frombuffer(buf, _DELTA_REC, count=n, offset=4)
    return rec["label"].astype(np.int32), rec["endpoint"].astype(np.float32)


def pack_piece_tuples(endpoints, steps) -> bytes:
    """Serialize inbound piece tuples: per piece ``>f4`` endpoint + ``>u4``
    arrival step (``PIECE_TUPLE_BYTES`` each, no header -- the transport's
    DATA frame carries the count)."""
    endpoints = np.asarray(endpoints, np.float32)
    rec = np.empty(endpoints.shape[0], _PIECE_REC)
    rec["endpoint"] = endpoints
    rec["step"] = np.asarray(steps, np.int64)
    return rec.tobytes()


def unpack_piece_tuples(buf: bytes, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``pack_piece_tuples``: ``(endpoints f32, steps i32)``."""
    rec = np.frombuffer(buf, _PIECE_REC, count=n)
    return rec["endpoint"].astype(np.float32), rec["step"].astype(np.int32)


def compact_chunk(
    endpoints: jax.Array,
    steps: jax.Array,
    n_pieces: jax.Array,
    emit: jax.Array,
    chunk_endpoints: jax.Array,
    step_idx: jax.Array,
):
    """Scatter one window's emissions into the receiver's padded wire buffers.

    Args:
      endpoints/steps: (n_max,) wire buffers accumulated so far.
      n_pieces: () i32 pieces already compacted (next free slot).
      emit: (C,) bool per-step emission flags of the window.
      chunk_endpoints: (C,) f32 transmitted endpoints (0 where emit=False).
      step_idx: (C,) i32 *global* stream step of each window slot.

    Returns ``(endpoints, steps, n_pieces)`` updated; pieces beyond the
    ``n_max`` capacity are dropped, exactly like ``compact_events``.
    """
    n_max = endpoints.shape[0]
    pos = n_pieces + jnp.cumsum(emit.astype(jnp.int32)) - 1  # slot per step
    slot = jnp.where(emit, pos, n_max)                       # OOB rows dropped
    endpoints = endpoints.at[slot].set(chunk_endpoints, mode="drop")
    steps = steps.at[slot].set(step_idx, mode="drop")
    n_new = jnp.minimum(n_pieces + jnp.sum(emit.astype(jnp.int32)), n_max)
    return endpoints, steps, n_new


def append_tail(
    endpoints: jax.Array,
    steps: jax.Array,
    n_pieces: jax.Array,
    tail,
    t_len: jax.Array,
):
    """Fold the sender's trailing flush into the wire buffers.

    The open segment [seg_start .. t_{T-1}] arrives as a final piece,
    conceptually emitted "at step T" (``t_len``).  No-op when ``tail.emit``
    is False or the buffer is full.
    """
    n_max = endpoints.shape[0]
    endpoints = jnp.where(
        jnp.arange(n_max) == n_pieces,
        jnp.where(tail.emit, tail.endpoint, endpoints[jnp.minimum(n_pieces, n_max - 1)]),
        endpoints,
    )
    steps = jnp.where(
        jnp.arange(n_max) == n_pieces,
        jnp.where(tail.emit, t_len, steps[jnp.minimum(n_pieces, n_max - 1)]),
        steps,
    )
    n_final = jnp.minimum(n_pieces + tail.emit.astype(jnp.int32), n_max)
    return endpoints, steps, n_final


@functools.partial(jax.jit, static_argnames=("n_max",))
def compact_events(events: dict, *, n_max: int, t0: jax.Array) -> dict:
    """Compact per-step emission events into padded per-piece arrays.

    Args:
      events: output of ``compress_stream`` for a single stream: ``emit``
        (T,) bool, ``endpoint`` (T,) f32, ``tail`` PieceEvent, ...
      n_max: static per-piece buffer capacity.
      t0: first raw stream point (the "hello" payload).

    Returns dict: ``endpoints`` (n_max,) f32, ``steps`` (n_max,) i32 emission
    step of each piece, ``lengths`` (n_max,) i32, ``incs`` (n_max,) f32,
    ``n_pieces`` () i32, ``t0``.

    Lengths/incs are the *receiver's* reconstruction (arrival-gap based); they
    equal the sender-side ground truth exactly (tested).
    """
    emit = events["emit"]
    t_len = emit.shape[-1]
    endpoints, steps, n_emitted = compact_chunk(
        jnp.zeros((n_max,), jnp.float32),
        jnp.zeros((n_max,), jnp.int32),
        jnp.zeros((), jnp.int32),
        emit,
        events["endpoint"],
        jnp.arange(t_len, dtype=jnp.int32),
    )
    endpoints, steps, n_pieces = append_tail(
        endpoints, steps, n_emitted, events["tail"], t_len
    )

    lens, incs = pieces_from_wire(endpoints, steps, n_pieces, t0)
    return {
        "endpoints": endpoints,
        "steps": steps,
        "lengths": lens,
        "incs": incs,
        "n_pieces": n_pieces,
        "t0": t0,
    }


def pieces_from_wire(
    endpoints: jax.Array, steps: jax.Array, n_pieces: jax.Array, t0: jax.Array
):
    """Alg. 2 lines 5-7: build (len, inc) from consecutive arrivals."""
    n_max = endpoints.shape[0]
    live = jnp.arange(n_max) < n_pieces
    prev_e = jnp.concatenate([jnp.asarray(t0, jnp.float32)[None], endpoints[:-1]])
    prev_s = jnp.concatenate([jnp.ones((1,), jnp.int32), steps[:-1]])
    lens = jnp.where(live, steps - prev_s, 0).astype(jnp.int32)
    incs = jnp.where(live, endpoints - prev_e, 0.0)
    return lens, incs
