"""Reconstruction: symbols/pieces -> time series (paper Sec. 3.2).

Three steps, each vectorizable with static shapes:

  * inverse digitization -- replace each symbol by its center (len~, inc~),
  * quantization         -- cumulative-error rounding of lengths back to ints
                            (carries the rounding remainder so the total
                            length is preserved, as in ABBA),
  * inverse compression  -- polygonal interpolation of the piece chain.

SymED's *online* reconstruction skips the first two steps and interpolates the
receiver's raw pieces directly (paper: ~half the DTW error of symbols).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "inverse_digitization",
    "quantize_lengths",
    "inverse_compression",
    "reconstruct_from_pieces",
    "reconstruct_from_symbols",
]


def inverse_digitization(labels: jax.Array, centers: jax.Array) -> jax.Array:
    """symbols -> representative pieces: (n_max,) int32 -> (n_max, 2) f32."""
    return centers[labels]


def quantize_lengths(lengths: jax.Array, mask: jax.Array) -> jax.Array:
    """Round fractional lengths to ints >= 1, carrying the rounding error.

    ABBA's quantization: round(cumsum) - round(previous cumsum) keeps the total
    reconstructed length equal to round(sum of fractional lengths).  The >= 1
    floor is folded *into* the carry: a piece forced up to 1 borrows from the
    running total, so subsequent pieces absorb the excess and the invariant
    ``sum(q) == round(sum(lengths))`` survives sub-unit fractional lengths
    (it degrades to ``sum(q) == n_live`` only when there are more live pieces
    than total rounded points -- each piece must still occupy >= 1 point).

    Recurrence ``alloc_i = max(alloc_{i-1} + live_i, round(csum_i))`` in
    closed form: ``alloc_i = cnt_i + max(0, running_max(round(csum_j) -
    cnt_j))`` with ``cnt`` the live-piece count, so it stays a parallel scan.
    """
    lengths = jnp.where(mask, lengths, 0.0)
    r = jnp.round(jnp.cumsum(lengths))
    cnt = jnp.cumsum(mask.astype(r.dtype))
    runmax = jax.lax.associative_scan(jnp.maximum, r - cnt)
    alloc = cnt + jnp.maximum(runmax, 0.0)
    prev = jnp.concatenate([jnp.zeros((1,), alloc.dtype), alloc[:-1]])
    q = (alloc - prev).astype(jnp.int32)
    return jnp.where(mask, q, 0)


@functools.partial(jax.jit, static_argnames=("total_len",))
def inverse_compression(
    lengths: jax.Array,
    incs: jax.Array,
    n_pieces: jax.Array,
    t0: jax.Array,
    total_len: int,
) -> jax.Array:
    """Interpolate the polygonal chain into a series of ``total_len`` points.

    Args:
      lengths: (n_max,) int32 piece lengths (padded with 0).
      incs:    (n_max,) f32 piece increments.
      n_pieces: () int32 valid count.
      t0: () f32 anchor value (first stream point).
      total_len: static output length N+1.

    Output index x lands in piece j with start_j <= x < start_{j+1}; value is
    ``base_j + (x - start_j) * inc_j / len_j``.  Indices beyond the chain hold
    the final endpoint.
    """
    n_max = lengths.shape[0]
    live = jnp.arange(n_max) < n_pieces
    lens = jnp.where(live, lengths, 0).astype(jnp.float32)
    incs = jnp.where(live, incs, 0.0)

    starts = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(lens)])
    bases = t0 + jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(incs)])

    x = jnp.arange(total_len, dtype=jnp.float32)
    # piece index for each output position (rightmost start <= x)
    j = jnp.clip(jnp.searchsorted(starts, x, side="right") - 1, 0, n_max - 1)
    frac = (x - starts[j]) / jnp.maximum(lens[j], 1.0)
    val = bases[j] + jnp.clip(frac, 0.0, 1.0) * incs[j]
    # past the end of the chain: hold the final endpoint (padded incs are 0,
    # so bases[-1] == t0 + sum of live increments)
    end = starts[-1]
    return jnp.where(x >= end, bases[-1], val)


def reconstruct_from_pieces(
    lengths: jax.Array, incs: jax.Array, n_pieces: jax.Array, t0: jax.Array, total_len: int
) -> jax.Array:
    """SymED online reconstruction: interpolate raw receiver pieces directly."""
    return inverse_compression(
        lengths.astype(jnp.int32), incs, n_pieces, t0, total_len
    )


def reconstruct_from_symbols(
    labels: jax.Array,
    centers: jax.Array,
    n_pieces: jax.Array,
    t0: jax.Array,
    total_len: int,
) -> jax.Array:
    """Offline reconstruction from the symbol string + center table (ABBA path)."""
    n_max = labels.shape[0]
    live = jnp.arange(n_max) < n_pieces
    rep = inverse_digitization(labels, centers)           # (n_max, 2)
    qlens = quantize_lengths(rep[:, 0], live)
    return inverse_compression(qlens, jnp.where(live, rep[:, 1], 0.0), n_pieces, t0, total_len)
