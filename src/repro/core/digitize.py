"""SymED receiver: online digitization via warm-started k-means (paper Alg. 3).

Pieces arrive one at a time.  All state lives in fixed-capacity masked buffers
(XLA-friendly):

  * ``pieces``  (n_max, 2)  raw-space (len, inc) tuples, ``n`` of them valid,
  * ``labels``  (n_max,)    current cluster id per piece (labels of *old*
                            pieces may change -- paper Sec. 4.2),
  * ``centers`` (k_max, 2)  raw-space cluster centers, ``k`` of them active.

Faithful semantics:
  * identity labeling while fewer than ``k_min`` pieces exist (Alg. 3 line 2),
  * clustering happens in standardized+scaled space: coords are
    ``(scl * len/std(len), inc/std(inc))`` (ABBA's scl convention; scl=0
    degenerates to 1D clustering on increments),
  * warm start from previous centers with k = k_old; if the max within-cluster
    variance still exceeds ``tol_s^2`` grow k, seeding the new center with the
    newest piece first and random re-init only after that (Alg. 3 lines 10-17),
  * ``GetTolS``: we use tol_s = tol in standardized space (documented heuristic;
    the paper defers to ABBA's variance test).

The inner distance/assign/update step is exactly what the Pallas
``kmeans_assign`` kernel accelerates; ``repro.kernels.ops`` dispatches between
this jnp reference and the kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "DigitizerState",
    "digitizer_delta",
    "digitizer_init",
    "digitizer_step",
    "digitizer_table_step",
    "digitize_pieces",
    "digitize_span",
    "digitize_span_table",
    "masked_kmeans",
    "masked_kmeans_table",
    "max_cluster_variance",
    "scale_coords",
]

_BIG = jnp.float32(1e30)


class DigitizerState(NamedTuple):
    pieces: jax.Array   # (n_max, 2) raw (len, inc); len stored as f32
    n: jax.Array        # () int32 -- number of valid pieces
    labels: jax.Array   # (n_max,) int32
    centers: jax.Array  # (k_max, 2) raw space
    k: jax.Array        # () int32 -- number of active centers
    key: jax.Array      # PRNG key for the (rare) random re-init path


def digitizer_init(n_max: int, k_max: int, key: jax.Array) -> DigitizerState:
    return DigitizerState(
        pieces=jnp.zeros((n_max, 2), jnp.float32),
        n=jnp.zeros((), jnp.int32),
        labels=jnp.zeros((n_max,), jnp.int32),
        centers=jnp.zeros((k_max, 2), jnp.float32),
        k=jnp.zeros((), jnp.int32),
        key=key,
    )


def scale_coords(
    pieces: jax.Array, mask: jax.Array, scl: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """ABBA standardization of piece space.

    Returns (scales, coords): ``coords = pieces * scales`` with
    ``scales = (scl/std(len), 1/std(inc))`` over the active pieces.
    No mean removal (increments keep sign semantics, as in ABBA).
    """
    cnt = jnp.maximum(jnp.sum(mask), 1).astype(jnp.float32)
    m = mask[:, None].astype(jnp.float32)
    mean = jnp.sum(pieces * m, axis=0) / cnt
    var = jnp.sum((pieces - mean) ** 2 * m, axis=0) / cnt
    std = jnp.sqrt(var)
    std = jnp.where(std < 1e-12, 1.0, std)
    scales = jnp.stack([scl / std[0], 1.0 / std[1]])
    return scales, pieces * scales


def masked_kmeans(
    coords: jax.Array,
    mask: jax.Array,
    c_init: jax.Array,
    k: jax.Array,
    iters: int = 10,
) -> Tuple[jax.Array, jax.Array]:
    """Lloyd iterations over masked pieces/centers.

    Args:
      coords: (n_max, 2) scaled piece coordinates.
      mask:   (n_max,) bool -- valid pieces.
      c_init: (k_max, 2) initial centers (rows >= k are ignored).
      k:      () int32 active center count.

    Returns (centers, labels): empty clusters keep their previous position.
    """
    k_max = c_init.shape[0]
    center_active = jnp.arange(k_max) < k

    def lloyd(_, carry):
        centers, _ = carry
        labels, sums, counts = _lloyd_half_step(coords, mask, centers,
                                                center_active)
        new_centers = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centers
        )
        return new_centers, labels

    centers, labels = jax.lax.fori_loop(
        0, iters, lloyd, (c_init, jnp.zeros(coords.shape[0], jnp.int32))
    )
    return centers, labels


def _lloyd_half_step(
    coords: jax.Array,
    mask: jax.Array,
    centers: jax.Array,
    center_active: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The assign half of one Lloyd iteration, single clustering problem.

    Exactly the op sequence ``kernels.kmeans.kmeans_assign_pallas`` fuses:
    masked pairwise distances (MXU expansion), argmin, and the per-cluster
    (sum, count) statistics.  ``masked_kmeans`` consumes it per lane;
    ``masked_kmeans_table`` either vmaps it (bitwise-identical reference) or
    swaps in the Pallas kernel.

    Returns ``(labels (n,), sums (k_max, 2), counts (k_max,))``.
    """
    k_max = centers.shape[0]
    d = _pairwise_sq_dists(coords, centers)
    d = jnp.where(center_active[None, :], d, _BIG)
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
    onehot = onehot * mask[:, None].astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)                      # (k_max,)
    sums = onehot.T @ coords                              # (k_max, 2)
    return labels, sums, counts


def masked_kmeans_table(
    coords: jax.Array,
    mask: jax.Array,
    c_init: jax.Array,
    k: jax.Array,
    iters: int = 10,
    *,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Slot-table batch of independent ``masked_kmeans`` problems.

    Args:
      coords: (S, n_max, 2) scaled piece coordinates per slot.
      mask:   (S, n_max) valid pieces per slot.
      c_init: (S, k_max, 2) initial centers.
      k:      (S,) active center counts.
      use_kernel: route the assign half-step through the fused Pallas
        kernel (``kernels.ops.kmeans_assign``, one ``pallas_call`` over the
        whole table) instead of ``jax.vmap(_lloyd_half_step)``.  The vmapped
        path is bitwise-identical to per-slot ``masked_kmeans``; the kernel
        path matches to float tolerance and zeroes labels of masked pieces
        (parity tested in ``tests/test_kernels.py``), so CPU deployments
        keep ``use_kernel=False``.

    Returns ``(centers (S, k_max, 2), labels (S, n_max))``.
    """
    n_streams, n = coords.shape[0], coords.shape[1]
    k_max = c_init.shape[1]
    center_active = jnp.arange(k_max)[None, :] < k[:, None]   # (S, k_max)

    if use_kernel:
        from repro.kernels import ops as _kops  # deferred: avoids an import
        # cycle (kernels.ref pulls in core modules at import time)

        def half(centers):
            return _kops.kmeans_assign(coords, mask, centers, center_active)
    else:
        def half(centers):
            return jax.vmap(_lloyd_half_step)(coords, mask, centers,
                                              center_active)

    def lloyd(_, carry):
        centers, _ = carry
        labels, sums, counts = half(centers)
        new_centers = jnp.where(
            counts[..., None] > 0,
            sums / jnp.maximum(counts[..., None], 1.0), centers
        )
        return new_centers, labels

    return jax.lax.fori_loop(
        0, iters, lloyd, (c_init, jnp.zeros((n_streams, n), jnp.int32))
    )


def _pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """||x_i - c_j||^2 via the MXU-friendly expansion (matches the kernel)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)         # (n, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]               # (1, k)
    cross = x @ c.T                                    # (n, k) -- MXU food
    return jnp.maximum(x2 - 2.0 * cross + c2, 0.0)


def max_cluster_variance(
    coords: jax.Array,
    mask: jax.Array,
    centers: jax.Array,
    labels: jax.Array,
    k: jax.Array,
) -> jax.Array:
    """max_c  sum_{p in c} ||p - center_c||^2 / max(|c| - 1, 1).

    Sample variance per cluster (singletons score 0), maximized over active
    clusters -- the paper's MAXCLUSTERVARIANCE tolerance test.
    """
    k_max = centers.shape[0]
    onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
    onehot = onehot * mask[:, None].astype(jnp.float32)
    sq = jnp.sum((coords[:, None, :] - centers[None, :, :]) ** 2, axis=-1)  # (n,k)
    per_cluster = jnp.sum(sq * onehot, axis=0)  # (k_max,)
    counts = jnp.sum(onehot, axis=0)
    var = per_cluster / jnp.maximum(counts - 1.0, 1.0)
    active = (jnp.arange(k_max) < k) & (counts > 0)
    return jnp.max(jnp.where(active, var, 0.0))


def _raw_centers(
    pieces: jax.Array, mask: jax.Array, labels: jax.Array, k_max: int
) -> Tuple[jax.Array, jax.Array]:
    """Per-cluster means of the *raw* pieces (de-standardization; also the
    right answer for scl=0 where the scaled len coordinate is degenerate)."""
    onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
    onehot = onehot * mask[:, None].astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ pieces
    return sums / jnp.maximum(counts[:, None], 1.0), counts


def digitizer_step(
    state: DigitizerState,
    piece: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
) -> Tuple[DigitizerState, jax.Array]:
    """Ingest one (len, inc) piece; return updated state + newest symbol id."""
    n_max, k_cap = state.pieces.shape[0], state.centers.shape[0]
    piece = jnp.asarray(piece, jnp.float32)

    pieces = jax.lax.dynamic_update_slice(
        state.pieces, piece[None, :], (state.n, jnp.int32(0))
    )
    n = state.n + 1
    mask = jnp.arange(n_max) < n

    # --- trivial phase (Alg. 3 line 2): every piece its own cluster --------
    def trivial(key):
        labels = jnp.where(mask, jnp.arange(n_max), 0).astype(jnp.int32)
        m = min(k_cap, n_max)  # static
        centers = jnp.zeros((k_cap, 2), jnp.float32)
        centers = centers.at[:m].set(jnp.where(mask[:m, None], pieces[:m], 0.0))
        return DigitizerState(pieces, n, labels, centers, n, key)

    # --- clustering phase ---------------------------------------------------
    def cluster(key):
        scl_arr = jnp.asarray(scl, jnp.float32)
        scales, coords = scale_coords(pieces, mask, scl_arr)
        c_scaled = state.centers * scales[None, :]
        bound = jnp.asarray(tol, jnp.float32) ** 2
        k_hi = jnp.minimum(jnp.asarray(k_max_active, jnp.int32), n)
        k_o = jnp.maximum(state.k, 1)

        def run(c_init, k):
            c, lab = masked_kmeans(coords, mask, c_init, k, lloyd_iters)
            err = max_cluster_variance(coords, mask, c, lab, k)
            return c, lab, err

        c0, lab0, err0 = run(c_scaled, k_o)

        def cond(carry):
            k, _, _, err, _ = carry
            return (k < k_hi) & (err > bound)

        def body(carry):
            k, c, lab, err, key = carry
            k_new = k + 1
            key, sub = jax.random.split(key)

            # k_old + 1: seed the extra center with the newest piece
            newest = coords[n - 1]
            seeded = jax.lax.dynamic_update_slice(c, newest[None, :], (k, 0))

            # beyond that: random re-init from active pieces
            probs = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
            idx = jax.random.choice(sub, n_max, shape=(k_cap,), replace=False, p=probs)
            randomed = coords[idx]

            c_init = jnp.where(k_new == k_o + 1, seeded, randomed)
            c2, lab2, err2 = run(c_init, k_new)
            return k_new, c2, lab2, err2, key

        k_fin, c_fin, lab_fin, _, key = jax.lax.while_loop(
            cond, body, (k_o, c0, lab0, err0, key)
        )
        centers_raw, _ = _raw_centers(pieces, mask, lab_fin, k_cap)
        # keep previous raw position for (rare) empty active clusters
        return DigitizerState(pieces, n, lab_fin, centers_raw, k_fin, key)

    new_state = jax.lax.cond(n <= k_min, trivial, cluster, state.key)
    symbol = new_state.labels[n - 1]
    return new_state, symbol


def digitizer_delta(
    prev_n: jax.Array,
    state: DigitizerState,
    symbols_online: jax.Array,
    endpoints: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Symbol delta since ``prev_n`` pieces had been digitized.

    This is the receiver's *wire-out* payload (ABBA-VSM-style downstream
    consumers ingest the symbol stream incrementally): after a digitize pass
    advanced ``state.n`` past ``prev_n``, slot ``i < n_new`` of the returned
    arrays holds the symbol emitted when piece ``prev_n + i`` was first
    digitized and the raw endpoint that piece transmitted on the wire in.

    Returns ``(labels, endpoints, n_new)`` with the arrays padded to
    ``n_max`` (zeros beyond ``n_new``), so concatenating the first ``n_new``
    entries of every delta reproduces ``symbols_online[:n]`` /
    ``endpoints[:n]`` exactly.
    """
    n_max = symbols_online.shape[0]
    idx = jnp.arange(n_max)
    n_new = (state.n - prev_n).astype(jnp.int32)
    src = jnp.minimum(prev_n + idx, n_max - 1)
    live = idx < n_new
    return (
        jnp.where(live, symbols_online[src], 0).astype(jnp.int32),
        jnp.where(live, endpoints[src], 0.0).astype(jnp.float32),
        n_new,
    )


def digitize_span(  # symlint: entry(pair=span/slot, shapes=pair-span-slot)
    state: DigitizerState,
    lengths: jax.Array,
    incs: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
) -> Tuple[DigitizerState, jax.Array]:
    """Ingest buffer slots ``lo <= idx < hi`` into a resumable digitizer.

    This is the online-receiver primitive: pieces live in the padded wire
    buffers ``lengths``/``incs`` (n_max,), ``state.n`` pieces have already
    been digitized (callers pass ``lo = state.n``), and the span up to ``hi``
    (the pieces that arrived since the last digitize) is scanned through
    ``digitizer_step`` one piece at a time.  ``digitize_pieces`` is the
    ``lo=0`` instantiation, so resuming in any number of spans is
    bitwise-identical to one whole-buffer pass by construction.

    Returns ``(state, symbols)`` -- ``symbols`` (n_max,) holds the symbol
    emitted when each span slot arrived (0 outside the span).

    The loop is a ``lax.while_loop`` over a cursor ``j in [lo, hi)``: the
    trip count is the number of pieces actually in the span, not ``n_max``.
    The previous formulation scanned all ``n_max`` positions with a
    ``lax.cond`` gate -- under ``jax.vmap`` (slot tables, fleet slabs) that
    cond lowers to a select which *runs* the full k-means at every position
    and discards the dead results, making every digitize pass cost
    O(n_max * lloyd) regardless of how few pieces arrived (the
    ``resident_speedup`` < 1 regression).  Per lane the executed
    ``digitizer_step`` sequence is identical, so results stay bitwise-equal;
    under vmap the batched while body is select-masked per lane by jax's
    batching rule, preserving that contract.
    """
    n_max = lengths.shape[0]
    pieces = jnp.stack(
        [lengths.astype(jnp.float32), incs.astype(jnp.float32)], axis=-1
    )

    def cond(carry):
        _, _, j = carry
        return j < hi

    def body(carry):
        st, syms, j = carry
        # dead lanes of a batched loop ride along past hi: clamp their read
        jc = jnp.minimum(j, n_max - 1)
        st2, sym = digitizer_step(
            st, pieces[jc], tol=tol, scl=scl, k_min=k_min,
            k_max_active=k_max_active, lloyd_iters=lloyd_iters,
        )
        return st2, syms.at[jc].set(sym), j + 1

    final, symbols, _ = jax.lax.while_loop(
        cond, body,
        (state, jnp.zeros((n_max,), jnp.int32), jnp.asarray(lo, jnp.int32)),
    )
    return final, symbols


def _select_lanes(pred, new, old):
    """Per-lane select over pytrees with an ``(S,)`` leading axis.

    Mirrors what jax's control-flow batching rules do to a vmapped
    ``cond``/``while_loop`` carry: every leaf keeps ``new`` where ``pred``
    and ``old`` elsewhere (select, not arithmetic -- NaNs in dead lanes
    cannot leak through).
    """
    def sel(a, b):
        return jnp.where(pred.reshape(pred.shape + (1,) * (a.ndim - 1)), a, b)

    return jax.tree.map(sel, new, old)


def digitizer_table_step(
    state: DigitizerState,
    piece: jax.Array,
    live: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
    use_kernel: bool = False,
) -> Tuple[DigitizerState, jax.Array]:
    """Slot-table batch of ``digitizer_step``: every lane ingests one piece.

    Semantically ``jax.vmap(digitizer_step)`` with a per-lane ``live`` gate,
    but the k-means inner loop runs as *one* table-level problem
    (``masked_kmeans_table``) so ``use_kernel=True`` can fuse the Lloyd
    assign half-step of every slot into a single ``pallas_call``.  The
    ``use_kernel=False`` path lowers to the same batched ops ``jax.vmap``
    produces (control flow is hand-lowered exactly the way jax's batching
    rules do it: both cond branches computed + per-lane select, while-loop
    with an any() predicate and select-masked carries), keeping end-of-
    stream results bitwise-equal to the per-slot path.

    Args:
      state: DigitizerState with an (S,) leading axis on every leaf.
      piece: (S, 2) one raw (len, inc) piece per lane.
      live:  (S,) bool -- lanes with ``live=False`` pass through unchanged.

    Returns ``(state, symbols (S,))`` -- symbol 0 for dead lanes.
    """
    n_streams, n_max = state.pieces.shape[0], state.pieces.shape[1]
    k_cap = state.centers.shape[1]
    piece = jnp.asarray(piece, jnp.float32)

    pieces = jax.vmap(
        lambda p, pc, m: jax.lax.dynamic_update_slice(
            p, pc[None, :], (m, jnp.int32(0)))
    )(state.pieces, piece, state.n)
    n = state.n + 1                                           # (S,)
    mask = jnp.arange(n_max)[None, :] < n[:, None]            # (S, n_max)

    # --- trivial phase (batched): every piece its own cluster --------------
    def trivial():
        labels = jnp.where(mask, jnp.arange(n_max)[None, :], 0).astype(jnp.int32)
        m = min(k_cap, n_max)  # static
        centers = jnp.zeros((n_streams, k_cap, 2), jnp.float32)
        centers = centers.at[:, :m].set(
            jnp.where(mask[:, :m, None], pieces[:, :m], 0.0))
        return DigitizerState(pieces, n, labels, centers, n, state.key)

    # --- clustering phase (batched; the k-means runs table-level) ----------
    def cluster():
        scl_arr = jnp.asarray(scl, jnp.float32)
        scales, coords = jax.vmap(
            lambda p, m: scale_coords(p, m, scl_arr))(pieces, mask)
        c_scaled = state.centers * scales[:, None, :]
        bound = jnp.asarray(tol, jnp.float32) ** 2
        k_hi = jnp.minimum(jnp.asarray(k_max_active, jnp.int32), n)   # (S,)
        k_o = jnp.maximum(state.k, 1)

        def run(c_init, k):
            c, lab = masked_kmeans_table(coords, mask, c_init, k, lloyd_iters,
                                         use_kernel=use_kernel)
            err = jax.vmap(max_cluster_variance)(coords, mask, c, lab, k)
            return c, lab, err

        c0, lab0, err0 = run(c_scaled, k_o)

        def growing(k, err):
            return (k < k_hi) & (err > bound)

        def cond(carry):
            k, _, _, err, _ = carry
            return jnp.any(growing(k, err))

        def body(carry):
            k, c, lab, err, key = carry
            grow = growing(k, err)                            # (S,)
            k_new = k + 1
            splits = jax.vmap(jax.random.split)(key)
            key_new, sub = splits[:, 0], splits[:, 1]

            # k_old + 1: seed the extra center with the newest piece
            newest = jnp.take_along_axis(
                coords, (n - 1)[:, None, None], axis=1)[:, 0]  # (S, 2)
            seeded = jax.vmap(
                lambda cc, nw, kk: jax.lax.dynamic_update_slice(
                    cc, nw[None, :], (kk, jnp.int32(0)))
            )(c, newest, k)

            # beyond that: random re-init from active pieces
            probs = mask.astype(jnp.float32) / jnp.maximum(
                jnp.sum(mask, axis=1, keepdims=True), 1)
            idx = jax.vmap(
                lambda s, p: jax.random.choice(
                    s, n_max, shape=(k_cap,), replace=False, p=p)
            )(sub, probs)
            randomed = jnp.take_along_axis(coords, idx[:, :, None], axis=1)

            c_init = jnp.where((k_new == k_o + 1)[:, None, None],
                               seeded, randomed)
            c2, lab2, err2 = run(c_init, k_new)
            return _select_lanes(
                grow, (k_new, c2, lab2, err2, key_new), (k, c, lab, err, key))

        k_fin, c_fin, lab_fin, _, key = jax.lax.while_loop(
            cond, body, (k_o, c0, lab0, err0, state.key)
        )
        del c_fin  # raw-space centers are recomputed from the labeling
        centers_raw = jax.vmap(
            lambda p, m, l: _raw_centers(p, m, l, k_cap)[0]
        )(pieces, mask, lab_fin)
        return DigitizerState(pieces, n, lab_fin, centers_raw, k_fin, key)

    stepped = _select_lanes(n <= k_min, trivial(), cluster())
    symbol = jnp.take_along_axis(stepped.labels, (n - 1)[:, None], axis=1)[:, 0]
    new_state = _select_lanes(live, stepped, state)
    return new_state, jnp.where(live, symbol, 0)


def digitize_span_table(  # symlint: entry(pair=span/table, shapes=pair-span-table)
    state: DigitizerState,
    lengths: jax.Array,
    incs: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
    use_kernel: bool = False,
) -> Tuple[DigitizerState, jax.Array]:
    """Slot-table batch of ``digitize_span``: per-lane spans, shared loop.

    Every lane owns a cursor walking its ``[lo_s, hi_s)`` span; the loop
    runs until every lane drains (trip count = the *widest* span in the
    table, not ``n_max``), each iteration one table-level
    ``digitizer_table_step``.  Lanes whose cursor is done are select-masked
    exactly like a vmapped per-lane while loop, so results are bitwise-equal
    to ``jax.vmap(digitize_span)`` on the reference path while
    ``use_kernel=True`` fuses each iteration's Lloyd half-steps across the
    whole table into single ``pallas_call``s.

    Args:
      state: batched DigitizerState ((S,) leading axis).
      lengths/incs: (S, n_max) padded piece buffers.
      lo/hi: (S,) span bounds per lane (``lo == hi`` lanes are no-ops).

    Returns ``(state, symbols (S, n_max))`` -- symbols 0 outside each span.
    """
    n_streams, n_max = lengths.shape
    pieces = jnp.stack(
        [lengths.astype(jnp.float32), incs.astype(jnp.float32)], axis=-1
    )

    def cond(carry):
        _, _, j = carry
        return jnp.any(j < hi)

    def body(carry):
        st, syms, j = carry
        live = j < hi                                         # (S,)
        jc = jnp.minimum(j, n_max - 1)
        piece = jnp.take_along_axis(pieces, jc[:, None, None], axis=1)[:, 0]
        st2, sym = digitizer_table_step(
            st, piece, live, tol=tol, scl=scl, k_min=k_min,
            k_max_active=k_max_active, lloyd_iters=lloyd_iters,
            use_kernel=use_kernel,
        )
        # write each live lane's symbol at its own cursor; dead lanes
        # rewrite their current value (a no-op)
        cur = jnp.take_along_axis(syms, jc[:, None], axis=1)[:, 0]
        syms2 = syms.at[jnp.arange(n_streams), jc].set(
            jnp.where(live, sym, cur))
        return st2, syms2, jnp.where(live, j + 1, j)

    final, symbols, _ = jax.lax.while_loop(
        cond, body,
        (state, jnp.zeros((n_streams, n_max), jnp.int32),
         jnp.asarray(lo, jnp.int32)),
    )
    return final, symbols


@functools.partial(
    jax.jit,
    static_argnames=("k_cap", "k_min", "k_max_active", "lloyd_iters", "use_kernel"),
)
def digitize_pieces(  # symlint: entry(drive=digitize, budget=0, shapes=digitize-pieces)
    lengths: jax.Array,
    incs: jax.Array,
    n_pieces: jax.Array,
    key: jax.Array,
    *,
    k_cap: int = 100,
    tol: float = 0.5,
    scl: float = 1.0,
    k_min: int = 3,
    k_max_active: int = 100,
    lloyd_iters: int = 10,
    use_kernel: bool = False,  # reserved: kernels.ops dispatch happens above us
) -> dict:
    """Run the receiver over a padded piece sequence (single stream).

    Args:
      lengths/incs: (n_max,) padded piece arrays (receiver-reconstructed).
      n_pieces: () int32 number of valid pieces.

    Returns dict with final ``labels``/``centers``/``k`` plus the per-step
    symbol emission ``symbols`` (n_max,) (symbol assigned when each piece
    arrived; later steps may relabel earlier pieces -- final labeling is
    ``labels``).
    """
    n_max = lengths.shape[0]
    k_cap = int(k_cap)
    state = digitizer_init(n_max, k_cap, key)
    final, symbols = digitize_span(
        state, lengths, incs, jnp.zeros((), jnp.int32), n_pieces,
        tol=tol, scl=scl, k_min=k_min, k_max_active=k_max_active,
        lloyd_iters=lloyd_iters,
    )
    return {
        "labels": final.labels,
        "centers": final.centers,
        "k": final.k,
        "symbols": symbols,
        "state": final,
    }
