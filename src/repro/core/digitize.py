"""SymED receiver: online digitization via warm-started k-means (paper Alg. 3).

Pieces arrive one at a time.  All state lives in fixed-capacity masked buffers
(XLA-friendly):

  * ``pieces``  (n_max, 2)  raw-space (len, inc) tuples, ``n`` of them valid,
  * ``labels``  (n_max,)    current cluster id per piece (labels of *old*
                            pieces may change -- paper Sec. 4.2),
  * ``centers`` (k_max, 2)  raw-space cluster centers, ``k`` of them active.

Faithful semantics:
  * identity labeling while fewer than ``k_min`` pieces exist (Alg. 3 line 2),
  * clustering happens in standardized+scaled space: coords are
    ``(scl * len/std(len), inc/std(inc))`` (ABBA's scl convention; scl=0
    degenerates to 1D clustering on increments),
  * warm start from previous centers with k = k_old; if the max within-cluster
    variance still exceeds ``tol_s^2`` grow k, seeding the new center with the
    newest piece first and random re-init only after that (Alg. 3 lines 10-17),
  * ``GetTolS``: we use tol_s = tol in standardized space (documented heuristic;
    the paper defers to ABBA's variance test).

The inner distance/assign/update step is exactly what the Pallas
``kmeans_assign`` kernel accelerates; ``repro.kernels.ops`` dispatches between
this jnp reference and the kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "DigitizerState",
    "digitizer_delta",
    "digitizer_init",
    "digitizer_step",
    "digitize_pieces",
    "digitize_span",
    "masked_kmeans",
    "max_cluster_variance",
    "scale_coords",
]

_BIG = jnp.float32(1e30)


class DigitizerState(NamedTuple):
    pieces: jax.Array   # (n_max, 2) raw (len, inc); len stored as f32
    n: jax.Array        # () int32 -- number of valid pieces
    labels: jax.Array   # (n_max,) int32
    centers: jax.Array  # (k_max, 2) raw space
    k: jax.Array        # () int32 -- number of active centers
    key: jax.Array      # PRNG key for the (rare) random re-init path


def digitizer_init(n_max: int, k_max: int, key: jax.Array) -> DigitizerState:
    return DigitizerState(
        pieces=jnp.zeros((n_max, 2), jnp.float32),
        n=jnp.zeros((), jnp.int32),
        labels=jnp.zeros((n_max,), jnp.int32),
        centers=jnp.zeros((k_max, 2), jnp.float32),
        k=jnp.zeros((), jnp.int32),
        key=key,
    )


def scale_coords(
    pieces: jax.Array, mask: jax.Array, scl: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """ABBA standardization of piece space.

    Returns (scales, coords): ``coords = pieces * scales`` with
    ``scales = (scl/std(len), 1/std(inc))`` over the active pieces.
    No mean removal (increments keep sign semantics, as in ABBA).
    """
    cnt = jnp.maximum(jnp.sum(mask), 1).astype(jnp.float32)
    m = mask[:, None].astype(jnp.float32)
    mean = jnp.sum(pieces * m, axis=0) / cnt
    var = jnp.sum((pieces - mean) ** 2 * m, axis=0) / cnt
    std = jnp.sqrt(var)
    std = jnp.where(std < 1e-12, 1.0, std)
    scales = jnp.stack([scl / std[0], 1.0 / std[1]])
    return scales, pieces * scales


def masked_kmeans(
    coords: jax.Array,
    mask: jax.Array,
    c_init: jax.Array,
    k: jax.Array,
    iters: int = 10,
) -> Tuple[jax.Array, jax.Array]:
    """Lloyd iterations over masked pieces/centers.

    Args:
      coords: (n_max, 2) scaled piece coordinates.
      mask:   (n_max,) bool -- valid pieces.
      c_init: (k_max, 2) initial centers (rows >= k are ignored).
      k:      () int32 active center count.

    Returns (centers, labels): empty clusters keep their previous position.
    """
    k_max = c_init.shape[0]
    center_active = jnp.arange(k_max) < k

    def lloyd(_, carry):
        centers, _ = carry
        d = _pairwise_sq_dists(coords, centers)
        d = jnp.where(center_active[None, :], d, _BIG)
        labels = jnp.argmin(d, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
        onehot = onehot * mask[:, None].astype(jnp.float32)
        counts = jnp.sum(onehot, axis=0)                      # (k_max,)
        sums = onehot.T @ coords                              # (k_max, 2)
        new_centers = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centers
        )
        return new_centers, labels

    centers, labels = jax.lax.fori_loop(
        0, iters, lloyd, (c_init, jnp.zeros(coords.shape[0], jnp.int32))
    )
    return centers, labels


def _pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """||x_i - c_j||^2 via the MXU-friendly expansion (matches the kernel)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)         # (n, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]               # (1, k)
    cross = x @ c.T                                    # (n, k) -- MXU food
    return jnp.maximum(x2 - 2.0 * cross + c2, 0.0)


def max_cluster_variance(
    coords: jax.Array,
    mask: jax.Array,
    centers: jax.Array,
    labels: jax.Array,
    k: jax.Array,
) -> jax.Array:
    """max_c  sum_{p in c} ||p - center_c||^2 / max(|c| - 1, 1).

    Sample variance per cluster (singletons score 0), maximized over active
    clusters -- the paper's MAXCLUSTERVARIANCE tolerance test.
    """
    k_max = centers.shape[0]
    onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
    onehot = onehot * mask[:, None].astype(jnp.float32)
    sq = jnp.sum((coords[:, None, :] - centers[None, :, :]) ** 2, axis=-1)  # (n,k)
    per_cluster = jnp.sum(sq * onehot, axis=0)  # (k_max,)
    counts = jnp.sum(onehot, axis=0)
    var = per_cluster / jnp.maximum(counts - 1.0, 1.0)
    active = (jnp.arange(k_max) < k) & (counts > 0)
    return jnp.max(jnp.where(active, var, 0.0))


def _raw_centers(
    pieces: jax.Array, mask: jax.Array, labels: jax.Array, k_max: int
) -> Tuple[jax.Array, jax.Array]:
    """Per-cluster means of the *raw* pieces (de-standardization; also the
    right answer for scl=0 where the scaled len coordinate is degenerate)."""
    onehot = jax.nn.one_hot(labels, k_max, dtype=jnp.float32)
    onehot = onehot * mask[:, None].astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ pieces
    return sums / jnp.maximum(counts[:, None], 1.0), counts


def digitizer_step(
    state: DigitizerState,
    piece: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
) -> Tuple[DigitizerState, jax.Array]:
    """Ingest one (len, inc) piece; return updated state + newest symbol id."""
    n_max, k_cap = state.pieces.shape[0], state.centers.shape[0]
    piece = jnp.asarray(piece, jnp.float32)

    pieces = jax.lax.dynamic_update_slice(state.pieces, piece[None, :], (state.n, 0))
    n = state.n + 1
    mask = jnp.arange(n_max) < n

    # --- trivial phase (Alg. 3 line 2): every piece its own cluster --------
    def trivial(key):
        labels = jnp.where(mask, jnp.arange(n_max), 0).astype(jnp.int32)
        m = min(k_cap, n_max)  # static
        centers = jnp.zeros((k_cap, 2), jnp.float32)
        centers = centers.at[:m].set(jnp.where(mask[:m, None], pieces[:m], 0.0))
        return DigitizerState(pieces, n, labels, centers, n, key)

    # --- clustering phase ---------------------------------------------------
    def cluster(key):
        scl_arr = jnp.asarray(scl, jnp.float32)
        scales, coords = scale_coords(pieces, mask, scl_arr)
        c_scaled = state.centers * scales[None, :]
        bound = jnp.asarray(tol, jnp.float32) ** 2
        k_hi = jnp.minimum(jnp.asarray(k_max_active, jnp.int32), n)
        k_o = jnp.maximum(state.k, 1)

        def run(c_init, k):
            c, lab = masked_kmeans(coords, mask, c_init, k, lloyd_iters)
            err = max_cluster_variance(coords, mask, c, lab, k)
            return c, lab, err

        c0, lab0, err0 = run(c_scaled, k_o)

        def cond(carry):
            k, _, _, err, _ = carry
            return (k < k_hi) & (err > bound)

        def body(carry):
            k, c, lab, err, key = carry
            k_new = k + 1
            key, sub = jax.random.split(key)

            # k_old + 1: seed the extra center with the newest piece
            newest = coords[n - 1]
            seeded = jax.lax.dynamic_update_slice(c, newest[None, :], (k, 0))

            # beyond that: random re-init from active pieces
            probs = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
            idx = jax.random.choice(sub, n_max, shape=(k_cap,), replace=False, p=probs)
            randomed = coords[idx]

            c_init = jnp.where(k_new == k_o + 1, seeded, randomed)
            c2, lab2, err2 = run(c_init, k_new)
            return k_new, c2, lab2, err2, key

        k_fin, c_fin, lab_fin, _, key = jax.lax.while_loop(
            cond, body, (k_o, c0, lab0, err0, key)
        )
        centers_raw, _ = _raw_centers(pieces, mask, lab_fin, k_cap)
        # keep previous raw position for (rare) empty active clusters
        return DigitizerState(pieces, n, lab_fin, centers_raw, k_fin, key)

    new_state = jax.lax.cond(n <= k_min, trivial, cluster, state.key)
    symbol = new_state.labels[n - 1]
    return new_state, symbol


def digitizer_delta(
    prev_n: jax.Array,
    state: DigitizerState,
    symbols_online: jax.Array,
    endpoints: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Symbol delta since ``prev_n`` pieces had been digitized.

    This is the receiver's *wire-out* payload (ABBA-VSM-style downstream
    consumers ingest the symbol stream incrementally): after a digitize pass
    advanced ``state.n`` past ``prev_n``, slot ``i < n_new`` of the returned
    arrays holds the symbol emitted when piece ``prev_n + i`` was first
    digitized and the raw endpoint that piece transmitted on the wire in.

    Returns ``(labels, endpoints, n_new)`` with the arrays padded to
    ``n_max`` (zeros beyond ``n_new``), so concatenating the first ``n_new``
    entries of every delta reproduces ``symbols_online[:n]`` /
    ``endpoints[:n]`` exactly.
    """
    n_max = symbols_online.shape[0]
    idx = jnp.arange(n_max)
    n_new = (state.n - prev_n).astype(jnp.int32)
    src = jnp.minimum(prev_n + idx, n_max - 1)
    live = idx < n_new
    return (
        jnp.where(live, symbols_online[src], 0).astype(jnp.int32),
        jnp.where(live, endpoints[src], 0.0).astype(jnp.float32),
        n_new,
    )


def digitize_span(
    state: DigitizerState,
    lengths: jax.Array,
    incs: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    tol: float,
    scl: float,
    k_min: int,
    k_max_active: int,
    lloyd_iters: int = 10,
) -> Tuple[DigitizerState, jax.Array]:
    """Ingest buffer slots ``lo <= idx < hi`` into a resumable digitizer.

    This is the online-receiver primitive: pieces live in the padded wire
    buffers ``lengths``/``incs`` (n_max,), ``state.n`` pieces have already
    been digitized (callers pass ``lo = state.n``), and the span up to ``hi``
    (the pieces that arrived since the last digitize) is scanned through
    ``digitizer_step`` one piece at a time.  ``digitize_pieces`` is the
    ``lo=0`` instantiation, so resuming in any number of spans is
    bitwise-identical to one whole-buffer pass by construction.

    Returns ``(state, symbols)`` -- ``symbols`` (n_max,) holds the symbol
    emitted when each span slot arrived (0 outside the span).
    """
    n_max = lengths.shape[0]
    pieces = jnp.stack(
        [lengths.astype(jnp.float32), incs.astype(jnp.float32)], axis=-1
    )

    def step(s, xs):
        piece, idx = xs
        live = (idx >= lo) & (idx < hi)

        def do(st):
            return digitizer_step(
                st, piece, tol=tol, scl=scl, k_min=k_min,
                k_max_active=k_max_active, lloyd_iters=lloyd_iters,
            )

        def skip(st):
            return st, jnp.zeros((), jnp.int32)

        return jax.lax.cond(live, do, skip, s)

    return jax.lax.scan(step, state, (pieces, jnp.arange(n_max)))


@functools.partial(
    jax.jit,
    static_argnames=("k_cap", "k_min", "k_max_active", "lloyd_iters", "use_kernel"),
)
def digitize_pieces(
    lengths: jax.Array,
    incs: jax.Array,
    n_pieces: jax.Array,
    key: jax.Array,
    *,
    k_cap: int = 100,
    tol: float = 0.5,
    scl: float = 1.0,
    k_min: int = 3,
    k_max_active: int = 100,
    lloyd_iters: int = 10,
    use_kernel: bool = False,  # reserved: kernels.ops dispatch happens above us
) -> dict:
    """Run the receiver over a padded piece sequence (single stream).

    Args:
      lengths/incs: (n_max,) padded piece arrays (receiver-reconstructed).
      n_pieces: () int32 number of valid pieces.

    Returns dict with final ``labels``/``centers``/``k`` plus the per-step
    symbol emission ``symbols`` (n_max,) (symbol assigned when each piece
    arrived; later steps may relabel earlier pieces -- final labeling is
    ``labels``).
    """
    n_max = lengths.shape[0]
    k_cap = int(k_cap)
    state = digitizer_init(n_max, k_cap, key)
    final, symbols = digitize_span(
        state, lengths, incs, jnp.zeros((), jnp.int32), n_pieces,
        tol=tol, scl=scl, k_min=k_min, k_max_active=k_max_active,
        lloyd_iters=lloyd_iters,
    )
    return {
        "labels": final.labels,
        "centers": final.centers,
        "k": final.k,
        "symbols": symbols,
        "state": final,
    }
