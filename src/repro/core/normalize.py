"""Online normalization (SymED Eq. 1-2): damped-window EWMA / EWMV z-scoring.

The paper standardizes every in-memory point each iteration with the *current*
EWMA/EWMV.  Because the Brownian-bridge residual used by the compressor is
affine-invariant (the mean cancels, the scale divides out), downstream code
never needs the re-standardized segment itself -- only the current (mean, var)
pair.  This module provides:

  * ``ewm_step``       -- one O(1) update of (EWMA, EWMV),
  * ``ewm_scan``       -- full-stream scan, batched over leading axes,
  * ``standardize``    -- z-score with a given (mean, var).

``ewm_scan`` has a Pallas fast path (``repro.kernels.ops.ewma_scan``) used by
the fleet runtime; this pure-jnp version is the reference oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["EwmState", "ewm_init", "ewm_step", "ewm_scan", "standardize"]


class EwmState(NamedTuple):
    """Damped-window normalization state (paper Eq. 1-2)."""

    mean: jax.Array  # EWMA_j
    var: jax.Array   # EWMV_j


def ewm_init(t0: jax.Array) -> EwmState:
    """Paper initialization: EWMA_0 = t_0, EWMV_0 = 1.0."""
    t0 = jnp.asarray(t0, jnp.float32)
    return EwmState(mean=t0, var=jnp.ones_like(t0))


def ewm_step(state: EwmState, t: jax.Array, alpha: float | jax.Array) -> EwmState:
    """One damped-window update.

    EWMA_j = a*t_j + (1-a)*EWMA_{j-1}
    EWMV_j = a*(t_j - EWMA_j)^2 + (1-a)*EWMV_{j-1}

    Note the variance uses the *updated* mean (MacGregor & Harris '93 form used
    by the paper -- Eq. 2 references EWMA_j, not EWMA_{j-1}).
    """
    mean = alpha * t + (1.0 - alpha) * state.mean
    var = alpha * (t - mean) ** 2 + (1.0 - alpha) * state.var
    return EwmState(mean=mean, var=var)


def ewm_scan(
    ts: jax.Array, alpha: float | jax.Array, time_axis: int = -1
) -> Tuple[jax.Array, jax.Array]:
    """EWMA/EWMV over a (batched) stream.

    Args:
      ts: float array ``(..., T)`` (time on ``time_axis``).
      alpha: damping weight in (0, 1].

    Returns:
      (means, vars), same shape as ``ts``: the normalization parameters *after*
      ingesting each point (i.e. the params the sender uses at step j).
    """
    ts = jnp.asarray(ts, jnp.float32)
    ts_t = jnp.moveaxis(ts, time_axis, 0)

    init = ewm_init(ts_t[0])

    def step(state: EwmState, t):
        new = ewm_step(state, t, alpha)
        return new, new

    # Step 0 keeps the paper's init (mean=t0, var=1) -- no update on the first
    # point; updates start with t_1.
    _, tail = jax.lax.scan(step, init, ts_t[1:])
    means = jnp.concatenate([init.mean[None], tail.mean], axis=0)
    vars_ = jnp.concatenate([init.var[None], tail.var], axis=0)
    return jnp.moveaxis(means, 0, time_axis), jnp.moveaxis(vars_, 0, time_axis)


def standardize(x: jax.Array, mean: jax.Array, var: jax.Array, eps: float = 1e-12) -> jax.Array:
    """z-score ``x`` with the damped-window params: (x - EWMA)/sqrt(EWMV)."""
    return (x - mean) * jax.lax.rsqrt(var + eps)
