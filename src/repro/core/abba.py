"""Offline ABBA baseline (Elsworth & Guettel 2020) -- the paper's comparator.

ABBA = (global z-normalization) -> (greedy piecewise-linear compression)
     -> (k-means digitization with tolerance-driven k search) -> symbols.

We reuse the SymED sender machinery for segmentation: running it with
``alpha=0`` on globally pre-normalized data freezes EWMV at 1.0, which makes
the online error test *identical* to ABBA's offline criterion
``SSE <= (len_ts - 2) * tol^2``.  Digitization is a deterministic offline
k-search (quantile init + farthest-point growth), warm-started Lloyd.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import digitize as dg
from repro.core.compress import compress_stream
from repro.core.receiver import compact_events

__all__ = ["AbbaResult", "abba_encode"]


class AbbaResult(NamedTuple):
    labels: jax.Array    # (n_max,) int32
    centers: jax.Array   # (k_max, 2) in normalized piece space
    k: jax.Array         # () int32
    lengths: jax.Array   # (n_max,) int32 true piece lengths
    incs: jax.Array      # (n_max,) f32 true (normalized-space) increments
    n_pieces: jax.Array  # () int32
    mean: jax.Array      # () f32 global normalization params
    std: jax.Array       # () f32


def _kmeans_growth(coords, mask, n, *, k_min, k_max, tol, lloyd_iters):
    """Deterministic offline k-search: quantile seed, farthest-point growth."""
    n_max, k_cap = coords.shape[0], k_max
    bound = jnp.float32(tol) ** 2

    # seed k_min centers at inc-quantiles of the active pieces
    order = jnp.argsort(jnp.where(mask, coords[:, 1], _big()))
    k0 = jnp.minimum(jnp.int32(k_min), n)

    def seed(k):
        # positions ~ evenly spaced over the first n sorted entries
        pos = (jnp.arange(k_cap).astype(jnp.float32) + 0.5) * (
            n.astype(jnp.float32) / jnp.maximum(k.astype(jnp.float32), 1.0)
        )
        idx = order[jnp.clip(pos.astype(jnp.int32), 0, n_max - 1)]
        return coords[idx]

    def run(c_init, k):
        c, lab = dg.masked_kmeans(coords, mask, c_init, k, lloyd_iters)
        err = dg.max_cluster_variance(coords, mask, c, lab, k)
        return c, lab, err

    c, lab, err = run(seed(k0), k0)
    k_hi = jnp.minimum(jnp.minimum(jnp.int32(k_max), n), jnp.int32(coords.shape[0]))

    def cond(carry):
        k, _, _, err = carry
        return (k < k_hi) & (err > bound)

    def body(carry):
        k, c, lab, _ = carry
        # farthest-point growth: new center = active piece farthest from its center
        d = jnp.sum((coords - c[lab]) ** 2, axis=1)
        far = jnp.argmax(jnp.where(mask, d, -1.0))
        c_new = jax.lax.dynamic_update_slice(c, coords[far][None, :], (k, 0))
        k = k + 1
        c2, lab2, err2 = run(c_new, k)
        return k, c2, lab2, err2

    k, c, lab, err = jax.lax.while_loop(cond, body, (k0, c, lab, err))
    return c, lab, k


def _big():
    return jnp.float32(1e30)


@functools.partial(
    jax.jit, static_argnames=("n_max", "len_max", "k_min", "k_max", "lloyd_iters")
)
def abba_encode(
    ts: jax.Array,
    *,
    n_max: int = 512,
    tol: float = 0.5,
    scl: float = 1.0,
    len_max: int = 512,
    k_min: int = 3,
    k_max: int = 100,
    lloyd_iters: int = 20,
) -> AbbaResult:
    """Offline ABBA on a single stream ``(T,)`` (vmap for batches)."""
    ts = jnp.asarray(ts, jnp.float32)
    mean = jnp.mean(ts)
    std = jnp.maximum(jnp.std(ts), 1e-12)
    tn = (ts - mean) / std

    # alpha=0 freezes EWMV at 1.0 -> exact offline ABBA segmentation criterion
    events = compress_stream(tn, tol=tol, len_max=len_max, alpha=0.0)
    wire = compact_events(events, n_max=n_max, t0=tn[0])

    pieces = jnp.stack(
        [wire["lengths"].astype(jnp.float32), wire["incs"]], axis=-1
    )
    mask = jnp.arange(n_max) < wire["n_pieces"]
    _, coords = dg.scale_coords(pieces, mask, jnp.float32(scl))
    c, lab, k = _kmeans_growth(
        coords, mask, wire["n_pieces"],
        k_min=k_min, k_max=k_max, tol=tol, lloyd_iters=lloyd_iters,
    )
    centers_raw, _ = dg._raw_centers(pieces, mask, lab, c.shape[0])
    return AbbaResult(
        labels=jnp.where(mask, lab, 0),
        centers=centers_raw,
        k=k,
        lengths=wire["lengths"],
        incs=wire["incs"],
        n_pieces=wire["n_pieces"],
        mean=mean,
        std=std,
    )
