"""SymED sender: online adaptive piecewise-linear compression (paper Alg. 1).

Semantics reproduced faithfully from the paper:

  * the current segment ``T_s`` grows one point at a time;
  * after appending point ``t_j`` the EWMA/EWMV params are updated, the whole
    segment is (conceptually) re-standardized with the *current* params, and
    the Brownian-bridge error of the standardized segment is compared against
    ``bound = (len_ts - 2) * tol^2`` (ABBA's squared-tolerance criterion; the
    paper writes ``tol`` but inherits ABBA's squared form -- see DESIGN.md);
  * on violation (or ``len_ts > len_max``) the segment *excluding* ``t_j``
    becomes a finished piece, its raw endpoint is "transmitted", and the next
    segment is seeded with the last two points ``[t_{m-1}, t_j]``.

Beyond-paper optimization (recorded in DESIGN.md / EXPERIMENTS.md): the paper
recomputes the bridge error over the stored segment in O(m) per appended point
(O(m^2) per piece).  We maintain centered sufficient statistics

    S0 = sum v_h,   S1 = sum h*v_h,   S2 = sum v_h^2,   v_h = t_h - t_start

so the raw-space bridge error is O(1) per point:

    err_raw = S2 - 2*(D/L)*S1 + (D/L)^2 * L(L+1)(2L+1)/6,   D = v_L, L = len

and, because z-scoring is affine and linear interpolation commutes with affine
maps, the error of the *re-standardized* segment is exactly

    err_norm = err_raw / EWMV_j.

This is exact (not an approximation) and removes the paper's need to keep the
segment in sender memory at all: sender state is O(1) per stream, which is what
makes the vectorized fleet sender a `lax.scan` with tiny carry.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.normalize import EwmState, ewm_init, ewm_step

__all__ = [
    "CompressorState",
    "PieceEvent",
    "compressor_init",
    "compressor_step",
    "compressor_finalize",
    "compress_stream",
    "bridge_error_direct",
    "pieces_on_wire",
]


class CompressorState(NamedTuple):
    """O(1) per-stream sender state."""

    norm: EwmState      # online normalization params (EWMA_j, EWMV_j)
    seg_start: jax.Array  # raw value t_start of the open segment
    last: jax.Array       # raw value of the newest point in the segment
    npts: jax.Array       # number of points currently in the segment (int32)
    s0: jax.Array         # sum of centered values   sum_h (t_h - seg_start)
    s1: jax.Array         # sum of h * centered      sum_h h*(t_h - seg_start)
    s2: jax.Array         # sum of squared centered  sum_h (t_h - seg_start)^2


class PieceEvent(NamedTuple):
    """Per-step sender output.

    ``emit`` flags steps at which a piece was finished.  On emission the wire
    payload is a single raw float (``endpoint``) -- ``len``/``inc`` are what the
    *receiver* reconstructs and are carried here for the simulator/tests.
    """

    emit: jax.Array      # bool
    endpoint: jax.Array  # transmitted raw value t_{m-1} (0 where emit=False)
    length: jax.Array    # piece length in steps (int32; receiver-side view)
    inc: jax.Array       # piece increment in raw space (receiver-side view)


def compressor_init(t0: jax.Array) -> CompressorState:
    """Open the first segment at the first stream point ``t0``."""
    t0 = jnp.asarray(t0, jnp.float32)
    z = jnp.zeros_like(t0)
    return CompressorState(
        norm=ewm_init(t0),
        seg_start=t0,
        last=t0,
        npts=jnp.ones(t0.shape, jnp.int32),
        s0=z,
        s1=z,
        s2=z,
    )


def _bridge_error_raw(state_s0, state_s1, state_s2, delta, length_f):
    """Brownian-bridge SSE of the open segment in raw space, O(1).

    ``delta`` = v_L = (t_end - t_start); ``length_f`` = L (float, #steps >= 1).
    """
    l = length_f
    # sum_h h^2 for h=0..L  ==  L(L+1)(2L+1)/6
    sum_h2 = l * (l + 1.0) * (2.0 * l + 1.0) / 6.0
    r = delta / l
    err = state_s2 - 2.0 * r * state_s1 + r * r * sum_h2
    # guard tiny negatives from cancellation
    return jnp.maximum(err, 0.0)


def bridge_error_direct(seg: jax.Array) -> jax.Array:
    """O(m) oracle: SSE between ``seg`` and the straight line joining its ends.

    Used by tests to validate the O(1) incremental path, and mirrors the
    paper's GetError (on an already-standardized segment, pass the z-scored
    values).
    """
    seg = jnp.asarray(seg, jnp.float32)
    n = seg.shape[-1]
    if n < 3:
        return jnp.zeros(seg.shape[:-1], jnp.float32)
    h = jnp.arange(n, dtype=jnp.float32)
    line = seg[..., :1] + (seg[..., -1:] - seg[..., :1]) * (h / (n - 1.0))
    return jnp.sum((seg - line) ** 2, axis=-1)


def compressor_step(
    state: CompressorState,
    t: jax.Array,
    *,
    tol: float | jax.Array,
    len_max: int | jax.Array,
    alpha: float | jax.Array,
) -> Tuple[CompressorState, PieceEvent]:
    """Ingest one raw point; possibly emit a finished piece (paper Alg. 1).

    Fully vectorized: all fields may carry leading batch dims.
    """
    t = jnp.asarray(t, jnp.float32)

    # --- Alg.1 line 7: update online-normalization params with t_j ---------
    norm = ewm_step(state.norm, t, alpha)

    # --- tentatively append t to the segment (lines 6, 8-11) ---------------
    v = t - state.seg_start                     # centered value of t
    h = state.npts.astype(jnp.float32)          # index of t within segment
    s0 = state.s0 + v
    s1 = state.s1 + h * v
    s2 = state.s2 + v * v
    npts_new = state.npts + 1                   # len_ts after append
    len_f = npts_new.astype(jnp.float32) - 1.0  # L = #steps of the segment

    err_raw = _bridge_error_raw(s0, s1, s2, v, jnp.maximum(len_f, 1.0))
    # exact error of the re-standardized segment (affine invariance)
    err = err_raw / jnp.maximum(norm.var, 1e-12)

    tol = jnp.asarray(tol, jnp.float32)
    bound = (npts_new.astype(jnp.float32) - 2.0) * tol * tol
    violated = (err > bound) | (npts_new > jnp.asarray(len_max, jnp.int32))

    # --- on violation: close the piece [seg_start .. last], reseed ---------
    piece_len = state.npts - 1                  # steps in the closed piece
    piece_inc = state.last - state.seg_start
    endpoint = state.last

    # segment reseeded with [last, t]:  v0 = 0, v1 = t - last
    v1 = t - state.last
    seeded = CompressorState(
        norm=norm,
        seg_start=state.last,
        last=t,
        npts=jnp.full_like(state.npts, 2),
        s0=v1,
        s1=v1,
        s2=v1 * v1,
    )
    grown = CompressorState(
        norm=norm, seg_start=state.seg_start, last=t, npts=npts_new, s0=s0, s1=s1, s2=s2
    )

    new_state = jax.tree.map(
        lambda a, b: jnp.where(_bcast(violated, a), a, b), seeded, grown
    )
    event = PieceEvent(
        emit=violated,
        endpoint=jnp.where(violated, endpoint, 0.0),
        length=jnp.where(violated, piece_len, 0),
        inc=jnp.where(violated, piece_inc, 0.0),
    )
    return new_state, event


def _bcast(flag: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast a bool flag against a state leaf (handles int/float leaves)."""
    return jnp.reshape(flag, flag.shape + (1,) * (like.ndim - flag.ndim))


def compressor_finalize(state: CompressorState) -> PieceEvent:
    """Flush the trailing open segment as a final piece (offline parity).

    ABBA converts the *entire* series; the online sender would otherwise hold
    its last partial segment forever.  Emits iff the segment has >= 2 points.
    """
    has_piece = state.npts >= 2
    return PieceEvent(
        emit=has_piece,
        endpoint=jnp.where(has_piece, state.last, 0.0),
        length=jnp.where(has_piece, state.npts - 1, 0),
        inc=jnp.where(has_piece, state.last - state.seg_start, 0.0),
    )


def pieces_on_wire(events: dict, step_offset: int):
    """Sender-side wire encode: the (endpoint, arrival-step) tuples one
    chunk's events put on the wire.

    ``events`` are the per-step arrays of one ``symed_encode_chunk`` window;
    ``step_offset`` is the global stream index of the window's first point.
    Returns host arrays ``(endpoints f32[n], steps i32[n])`` -- exactly what
    the receiver's ``compact_chunk`` scatter records for the same window, so
    a ``repro.launch.transport`` pieces-mode sender reproduces the raw-mode
    receiver state bitwise.
    """
    import numpy as np

    emit = np.asarray(events["emit"]).reshape(-1)
    endpoints = np.asarray(events["endpoint"]).reshape(-1)
    idx = np.nonzero(emit)[0]
    return (endpoints[idx].astype(np.float32),
            (idx + step_offset).astype(np.int32))


@functools.partial(jax.jit, static_argnames=("len_max",))
def compress_stream(
    ts: jax.Array,
    *,
    tol: float | jax.Array = 0.5,
    len_max: int = 512,
    alpha: float | jax.Array = 0.01,
) -> dict:
    """Run the online sender over a whole stream (batched on leading axes).

    Args:
      ts: ``(..., T)`` raw stream(s).

    Returns dict with per-step arrays shaped ``(..., T)``:
      ``emit`` bool, ``endpoint``/``inc`` f32, ``length`` i32, plus
      ``n_pieces`` ``(...,)`` i32 (including the finalize flush, which is
      reported at the last step slot iff it did not already emit there),
      and ``final_state``.

    The wire traffic of the paper's sender is exactly
    ``endpoint[emit]`` -- one float per emitted piece.
    """
    ts = jnp.asarray(ts, jnp.float32)
    ts_t = jnp.moveaxis(ts, -1, 0)
    init = compressor_init(ts_t[0])

    def step(state, t):
        return compressor_step(state, t, tol=tol, len_max=len_max, alpha=alpha)

    final_state, events = jax.lax.scan(step, init, ts_t[1:])

    # Prepend a no-emit slot for t_0 so events align 1:1 with stream steps.
    def pad0(x):
        return jnp.concatenate([jnp.zeros_like(x[:1]), x], axis=0)

    events = PieceEvent(*(pad0(x) for x in events))

    # Fold the trailing flush into the last step slot (it never collides:
    # an emission at step T-1 reseeds a 2-point segment -> flush would emit a
    # length-1 piece; both matter, so keep a dedicated tail event).
    tail = compressor_finalize(final_state)

    to_batch_last = lambda x: jnp.moveaxis(x, 0, -1)
    emit = to_batch_last(events.emit)
    n_pieces = jnp.sum(emit, axis=-1).astype(jnp.int32) + tail.emit.astype(jnp.int32)

    return {
        "emit": emit,
        "endpoint": to_batch_last(events.endpoint),
        "length": to_batch_last(events.length),
        "inc": to_batch_last(events.inc),
        "tail": tail,
        "n_pieces": n_pieces,
        "final_state": final_state,
    }
