"""SymED end-to-end pipeline: the paper's contribution as one composable module.

    sender (IoT, Alg. 1)  --one float/piece-->  receiver (edge, Alg. 2+3)

``symed_encode`` runs a single stream through sender -> wire -> receiver and
returns symbols, pieces, centers plus wire-traffic accounting.
``symed_batch`` vmaps it over a fleet slab (the distributed runtime in
``repro.launch.fleet`` shards slabs over the mesh ``data`` axis with
shard_map).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.compress import (
    CompressorState, PieceEvent, compress_stream, compressor_finalize,
    compressor_init, compressor_step,
)
from repro.core.digitize import digitize_pieces
from repro.core.metrics import compression_rate_symed, drr, dtw_ref
from repro.core.receiver import compact_events
from repro.core.reconstruct import reconstruct_from_pieces, reconstruct_from_symbols

__all__ = [
    "SymEDConfig",
    "symed_encode",
    "symed_encode_chunk",
    "symed_finish",
    "symed_batch",
    "symbols_to_string",
]


@dataclasses.dataclass(frozen=True)
class SymEDConfig:
    """Hyperparameters (paper Sec. 4.1 defaults)."""

    tol: float = 0.5          # error-tolerance (compression + digitization)
    alpha: float = 0.01       # damped-window weight (paper: 0.01..0.02)
    scl: float = 1.0          # length-vs-increment weight (2D clustering)
    k_min: int = 3            # minimum alphabet size
    k_max: int = 100          # maximum alphabet size
    len_max: int = 512        # maximum points per piece
    n_max: int = 512          # per-stream piece buffer capacity
    lloyd_iters: int = 10     # Lloyd iterations per k-means warm start

    def static_fields(self) -> Dict[str, Any]:
        return dict(
            len_max=self.len_max, n_max=self.n_max, k_min=self.k_min,
            k_max_active=self.k_max, lloyd_iters=self.lloyd_iters,
        )


def _receive(
    events, key, ts, t_len, *, tol, scl, n_max, k_min, k_max, lloyd_iters, reconstruct
):
    """Wire -> receiver: compact, digitize, score.  Shared by the whole-stream
    (``_encode``) and chunked (``_finish``) paths so their outputs stay
    identical by construction.  ``events`` must carry per-step ``emit`` /
    ``endpoint`` plus the trailing-flush ``tail``; ``t_len`` is the true
    stream length (``ts`` may be just ``ts[:1]`` when not reconstructing)."""
    # --- wire ---------------------------------------------------------------
    wire = compact_events(events, n_max=n_max, t0=ts[0])
    # --- receiver (edge node) ----------------------------------------------
    dig = digitize_pieces(
        wire["lengths"], wire["incs"], wire["n_pieces"], key,
        k_cap=k_max, tol=tol, scl=scl, k_min=k_min,
        k_max_active=k_max, lloyd_iters=lloyd_iters,
    )

    out = {
        "symbols": dig["labels"],
        "symbols_online": dig["symbols"],
        "centers": dig["centers"],
        "k": dig["k"],
        "pieces_len": wire["lengths"],
        "pieces_inc": wire["incs"],
        "n_pieces": wire["n_pieces"],
        "wire_bytes": 4.0 + 4.0 * wire["n_pieces"].astype(jnp.float32),
        "cr": compression_rate_symed(wire["n_pieces"], t_len),
        "drr": drr(wire["n_pieces"], t_len),
    }
    if reconstruct:
        rec_p = reconstruct_from_pieces(
            wire["lengths"], wire["incs"], wire["n_pieces"], ts[0], t_len
        )
        rec_s = reconstruct_from_symbols(
            dig["labels"], dig["centers"], wire["n_pieces"], ts[0], t_len
        )
        out["recon_pieces"] = rec_p
        out["recon_symbols"] = rec_s
        out["re_pieces"] = dtw_ref(ts, rec_p)
        out["re_symbols"] = dtw_ref(ts, rec_s)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("len_max", "n_max", "k_min", "k_max", "lloyd_iters", "reconstruct"),
)
def _encode(
    ts, key, *, tol, alpha, scl, len_max, n_max, k_min, k_max, lloyd_iters, reconstruct
):
    ts = jnp.asarray(ts, jnp.float32)

    # --- sender (IoT node) -------------------------------------------------
    events = compress_stream(ts, tol=tol, len_max=len_max, alpha=alpha)
    return _receive(
        events, key, ts, ts.shape[-1], tol=tol, scl=scl, n_max=n_max,
        k_min=k_min, k_max=k_max, lloyd_iters=lloyd_iters, reconstruct=reconstruct,
    )


def symed_encode(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Encode one stream ``(T,)``; optionally reconstruct + score both modes."""
    return _encode(
        ts, key, tol=cfg.tol, alpha=cfg.alpha, scl=cfg.scl,
        len_max=cfg.len_max, n_max=cfg.n_max, k_min=cfg.k_min, k_max=cfg.k_max,
        lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
    )


@functools.partial(jax.jit, static_argnames=("len_max", "first"))
def _encode_chunk(chunk, state, *, tol, alpha, len_max, first):
    chunk = jnp.asarray(chunk, jnp.float32)
    ts_t = jnp.moveaxis(chunk, -1, 0)
    if first:
        state = compressor_init(ts_t[0])
        xs = ts_t[1:]
    else:
        xs = ts_t

    def step(s, t):
        return compressor_step(s, t, tol=tol, len_max=len_max, alpha=alpha)

    state, events = jax.lax.scan(step, state, xs)
    if first:
        # no-emit slot for t_0 so events align 1:1 with chunk steps
        pad0 = lambda x: jnp.concatenate([jnp.zeros_like(x[:1]), x], axis=0)
        events = PieceEvent(*(pad0(x) for x in events))
    to_batch_last = lambda x: jnp.moveaxis(x, 0, -1)
    ev = {
        "emit": to_batch_last(events.emit),
        "endpoint": to_batch_last(events.endpoint),
        "length": to_batch_last(events.length),
        "inc": to_batch_last(events.inc),
    }
    return state, ev


def symed_encode_chunk(
    ts_chunk: jax.Array, cfg: SymEDConfig, state: CompressorState | None = None
) -> tuple[CompressorState, Dict[str, jax.Array]]:
    """Resumable sender: ingest one ``(..., C)`` window of the stream.

    ``state=None`` opens the stream (the chunk's first point seeds the
    compressor, exactly like ``compress_stream``); pass the returned state to
    ingest the next window.  Step-for-step identical to running
    ``compress_stream`` over the concatenated windows -- this is what makes
    the fleet runtime (``repro.launch.fleet``) *online*: a slab is processed
    in ``chunk_len`` windows with O(1)-per-stream carry instead of one giant
    batch.

    Returns ``(state, events)`` where ``events`` holds per-step ``emit`` /
    ``endpoint`` / ``length`` / ``inc`` arrays shaped like the chunk.
    """
    return _encode_chunk(
        ts_chunk, state, tol=cfg.tol, alpha=cfg.alpha, len_max=cfg.len_max,
        first=state is None,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "k_min", "k_max", "lloyd_iters", "reconstruct"),
)
def _finish(
    events, state, key, ts, *, tol, scl, n_max, k_min, k_max, lloyd_iters, reconstruct
):
    tail = compressor_finalize(state)
    return _receive(
        {**events, "tail": tail}, key, ts, events["emit"].shape[-1],
        tol=tol, scl=scl, n_max=n_max, k_min=k_min, k_max=k_max,
        lloyd_iters=lloyd_iters, reconstruct=reconstruct,
    )


def symed_finish(
    events: Dict[str, jax.Array],
    state: CompressorState,
    cfg: SymEDConfig,
    key: jax.Array,
    ts: jax.Array,
    reconstruct: bool = True,
) -> Dict[str, jax.Array]:
    """Close a chunked stream: flush the open segment, wire-compact, digitize.

    ``events`` are the per-step arrays from ``symed_encode_chunk`` calls,
    concatenated along the step axis (single stream, ``(T,)``); ``ts`` is the
    full raw stream (the reconstruction error is scored against it; only
    ``ts[0]`` enters the wire).  Output dict matches ``symed_encode``.
    """
    return _finish(
        events, state, key, jnp.asarray(ts, jnp.float32),
        tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max, k_min=cfg.k_min,
        k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
    )


def symed_batch(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Vectorized fleet slab: ``ts`` is (B, T); one PRNG key per stream."""
    keys = jax.random.split(key, ts.shape[0])
    return jax.vmap(lambda t, k: symed_encode(t, cfg, k, reconstruct))(ts, keys)


def symbols_to_string(labels, n_pieces) -> str:
    """Host-side helper: int labels -> 'abc...' string (I/O boundary only)."""
    import numpy as np

    labels = np.asarray(labels)[: int(n_pieces)]
    alphabet = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"
    return "".join(alphabet[l % len(alphabet)] for l in labels)
