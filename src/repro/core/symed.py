"""SymED end-to-end pipeline: the paper's contribution as one composable module.

    sender (IoT, Alg. 1)  --one float/piece-->  receiver (edge, Alg. 2+3)

``symed_encode`` runs a single stream through sender -> wire -> receiver and
returns symbols, pieces, centers plus wire-traffic accounting.
``symed_batch`` vmaps it over a fleet slab (the distributed runtime in
``repro.launch.fleet`` shards slabs over the mesh ``data`` axis with
shard_map).

Three ingestion shapes, all bitwise-equal at end-of-stream (tested):

  * **whole-stream** -- ``symed_encode(ts)``: one shot;
  * **chunked sender** -- ``symed_encode_chunk`` windows + ``symed_finish``:
    the sender is online (O(1) carry) but per-step events accumulate until a
    single digitize at the end;
  * **streaming receiver** -- ``symed_step_chunk``/``symed_receive_chunk``
    windows + ``symed_receive_finish``: *both* sides are online.  A
    ``ReceiverState`` carries the compressor, the padded wire buffers, and a
    resumable ``DigitizerState`` across windows; with
    ``digitize_every_k = k`` the digitizer runs over the newly arrived pieces
    every ``k`` windows, so symbols stream out of the receiver while the
    stream is still arriving (the paper's 42ms/symbol deployment shape).
    Total receiver memory is O(n_max), independent of stream length.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compress import (
    CompressorState, PieceEvent, compress_stream, compressor_finalize,
    compressor_init, compressor_step,
)
from repro.core.digitize import (
    DigitizerState, digitize_pieces, digitize_span, digitize_span_table,
    digitizer_delta, digitizer_init,
)
from repro.core.metrics import compression_rate_symed, drr, dtw_ref
from repro.core.receiver import (
    append_tail, compact_chunk, compact_events, delta_frame_bytes,
    pieces_from_wire,
)
from repro.core.reconstruct import reconstruct_from_pieces, reconstruct_from_symbols

__all__ = [
    "ReceiverState",
    "SymEDConfig",
    "receiver_init",
    "symed_encode",
    "symed_encode_chunk",
    "symed_finish",
    "symed_step_chunk",
    "symed_receive_chunk",
    "symed_receive_finish",
    "symed_receive_masked_chunk",
    "symed_receive_masked_chunk_table",
    "symed_receive_masked_pieces",
    "symed_receive_masked_pieces_table",
    "symed_batch",
    "symbols_to_string",
]


@dataclasses.dataclass(frozen=True)
class SymEDConfig:
    """Hyperparameters (paper Sec. 4.1 defaults)."""

    tol: float = 0.5          # error-tolerance (compression + digitization)
    alpha: float = 0.01       # damped-window weight (paper: 0.01..0.02)
    scl: float = 1.0          # length-vs-increment weight (2D clustering)
    k_min: int = 3            # minimum alphabet size
    k_max: int = 100          # maximum alphabet size
    len_max: int = 512        # maximum points per piece
    n_max: int = 512          # per-stream piece buffer capacity
    lloyd_iters: int = 10     # Lloyd iterations per k-means warm start

    def static_fields(self) -> Dict[str, Any]:
        return dict(
            len_max=self.len_max, n_max=self.n_max, k_min=self.k_min,
            k_max_active=self.k_max, lloyd_iters=self.lloyd_iters,
        )


def _receive(
    events, key, ts, t_len, n_points, *, tol, scl, n_max, k_min, k_max,
    lloyd_iters, reconstruct
):
    """Wire -> receiver: compact, digitize, score.  Shared by the whole-stream
    (``_encode``) and chunked (``_finish``) paths so their outputs stay
    identical by construction.  ``events`` must carry per-step ``emit`` /
    ``endpoint`` plus the trailing-flush ``tail``; ``t_len`` is the static
    stream length (``ts`` may be just ``ts[:1]`` when not reconstructing).
    ``n_points`` is the same length as a *runtime* scalar: the cr/drr
    divisions must see a runtime divisor, or XLA strength-reduces them to
    reciprocal multiplies and the results drift one ulp from the streaming
    receiver (which divides by the ``t_seen`` carried in its state)."""
    # --- wire ---------------------------------------------------------------
    wire = compact_events(events, n_max=n_max, t0=ts[0])
    # --- receiver (edge node) ----------------------------------------------
    dig = digitize_pieces(
        wire["lengths"], wire["incs"], wire["n_pieces"], key,
        k_cap=k_max, tol=tol, scl=scl, k_min=k_min,
        k_max_active=k_max, lloyd_iters=lloyd_iters,
    )

    out = {
        "symbols": dig["labels"],
        "symbols_online": dig["symbols"],
        "centers": dig["centers"],
        "k": dig["k"],
        "pieces_len": wire["lengths"],
        "pieces_inc": wire["incs"],
        "n_pieces": wire["n_pieces"],
        "wire_bytes": 4.0 + 4.0 * wire["n_pieces"].astype(jnp.float32),
        "cr": compression_rate_symed(wire["n_pieces"], n_points),
        "drr": drr(wire["n_pieces"], n_points),
    }
    if reconstruct:
        rec_p = reconstruct_from_pieces(
            wire["lengths"], wire["incs"], wire["n_pieces"], ts[0], t_len
        )
        rec_s = reconstruct_from_symbols(
            dig["labels"], dig["centers"], wire["n_pieces"], ts[0], t_len
        )
        out["recon_pieces"] = rec_p
        out["recon_symbols"] = rec_s
        out["re_pieces"] = dtw_ref(ts, rec_p)
        out["re_symbols"] = dtw_ref(ts, rec_s)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("len_max", "n_max", "k_min", "k_max", "lloyd_iters", "reconstruct"),
)
def _encode(
    ts, key, n_points, *, tol, alpha, scl, len_max, n_max, k_min, k_max,
    lloyd_iters, reconstruct
):
    ts = jnp.asarray(ts, jnp.float32)

    # --- sender (IoT node) -------------------------------------------------
    events = compress_stream(ts, tol=tol, len_max=len_max, alpha=alpha)
    return _receive(
        events, key, ts, ts.shape[-1], n_points, tol=tol, scl=scl, n_max=n_max,
        k_min=k_min, k_max=k_max, lloyd_iters=lloyd_iters, reconstruct=reconstruct,
    )


def symed_encode(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Encode one stream ``(T,)``; optionally reconstruct + score both modes."""
    ts = jnp.asarray(ts, jnp.float32)
    return _encode(
        ts, key, jnp.asarray(ts.shape[-1], jnp.int32),
        tol=cfg.tol, alpha=cfg.alpha, scl=cfg.scl,
        len_max=cfg.len_max, n_max=cfg.n_max, k_min=cfg.k_min, k_max=cfg.k_max,
        lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
    )


@functools.partial(jax.jit, static_argnames=("len_max", "first"))
def _encode_chunk(chunk, state, *, tol, alpha, len_max, first):  # symlint: entry(drive=chunked, budget=0, shapes=encode-chunk)
    chunk = jnp.asarray(chunk, jnp.float32)
    ts_t = jnp.moveaxis(chunk, -1, 0)
    if first:
        state = compressor_init(ts_t[0])
        xs = ts_t[1:]
    else:
        xs = ts_t

    def step(s, t):
        return compressor_step(s, t, tol=tol, len_max=len_max, alpha=alpha)

    state, events = jax.lax.scan(step, state, xs)
    if first:
        # no-emit slot for t_0 so events align 1:1 with chunk steps
        pad0 = lambda x: jnp.concatenate([jnp.zeros_like(x[:1]), x], axis=0)
        events = PieceEvent(*(pad0(x) for x in events))
    to_batch_last = lambda x: jnp.moveaxis(x, 0, -1)
    ev = {
        "emit": to_batch_last(events.emit),
        "endpoint": to_batch_last(events.endpoint),
        "length": to_batch_last(events.length),
        "inc": to_batch_last(events.inc),
    }
    return state, ev


def symed_encode_chunk(
    ts_chunk: jax.Array, cfg: SymEDConfig, state: CompressorState | None = None
) -> tuple[CompressorState, Dict[str, jax.Array]]:
    """Resumable sender: ingest one ``(..., C)`` window of the stream.

    ``state=None`` opens the stream (the chunk's first point seeds the
    compressor, exactly like ``compress_stream``); pass the returned state to
    ingest the next window.  Step-for-step identical to running
    ``compress_stream`` over the concatenated windows -- this is what makes
    the fleet runtime (``repro.launch.fleet``) *online*: a slab is processed
    in ``chunk_len`` windows with O(1)-per-stream carry instead of one giant
    batch.

    Returns ``(state, events)`` where ``events`` holds per-step ``emit`` /
    ``endpoint`` / ``length`` / ``inc`` arrays shaped like the chunk.
    """
    return _encode_chunk(
        ts_chunk, state, tol=cfg.tol, alpha=cfg.alpha, len_max=cfg.len_max,
        first=state is None,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "k_min", "k_max", "lloyd_iters", "reconstruct"),
)
def _finish(
    events, state, key, ts, n_points, *, tol, scl, n_max, k_min, k_max,
    lloyd_iters, reconstruct
):
    tail = compressor_finalize(state)
    return _receive(
        {**events, "tail": tail}, key, ts, events["emit"].shape[-1], n_points,
        tol=tol, scl=scl, n_max=n_max, k_min=k_min, k_max=k_max,
        lloyd_iters=lloyd_iters, reconstruct=reconstruct,
    )


def symed_finish(
    events: Dict[str, jax.Array],
    state: CompressorState,
    cfg: SymEDConfig,
    key: jax.Array,
    ts: jax.Array,
    reconstruct: bool = True,
) -> Dict[str, jax.Array]:
    """Close a chunked stream: flush the open segment, wire-compact, digitize.

    ``events`` are the per-step arrays from ``symed_encode_chunk`` calls,
    concatenated along the step axis (single stream, ``(T,)``); ``ts`` is the
    full raw stream (the reconstruction error is scored against it; only
    ``ts[0]`` enters the wire).  Output dict matches ``symed_encode``.
    """
    return _finish(
        events, state, key, jnp.asarray(ts, jnp.float32),
        jnp.asarray(events["emit"].shape[-1], jnp.int32),
        tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max, k_min=cfg.k_min,
        k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
    )


class ReceiverState(NamedTuple):
    """Full online SymED state for one stream: sender + wire + receiver.

    ``comp`` is the O(1) sender carry; ``endpoints``/``steps``/``n_pieces``
    are the receiver's padded wire-compaction buffers (what arrived, and
    when); ``dig`` is the resumable digitizer (``dig.n`` pieces of the buffer
    have been digitized so far); ``symbols_online`` accumulates the symbol
    emitted when each piece was first digitized.  ``t0``/``t_seen``/``chunks``
    anchor the wire ("hello" payload, global step clock, cadence counter).
    """

    comp: CompressorState
    dig: DigitizerState
    endpoints: jax.Array       # (n_max,) f32 transmitted endpoints
    steps: jax.Array           # (n_max,) i32 arrival step per piece
    n_pieces: jax.Array        # () i32 pieces compacted so far
    symbols_online: jax.Array  # (n_max,) i32 symbol at first digitization
    t0: jax.Array              # () f32 first raw point (the "hello")
    t_seen: jax.Array          # () i32 stream points ingested so far
    chunks: jax.Array          # () i32 windows ingested so far


def receiver_init(cfg: SymEDConfig, key: jax.Array) -> ReceiverState:
    """Blank (unseeded) receiver slot for session tables.

    ``t_seen == 0`` marks the slot as not yet opened by a stream point: the
    first valid point of the first ``symed_receive_masked_chunk`` window
    seeds the compressor exactly like ``symed_receive_chunk(state=None)``
    does with ``chunk[0]``.  ``repro.launch.stream`` vmaps this over the
    slot axis to build its resident session table.
    """
    return ReceiverState(
        comp=compressor_init(jnp.zeros((), jnp.float32)),
        dig=digitizer_init(cfg.n_max, cfg.k_max, key),
        endpoints=jnp.zeros((cfg.n_max,), jnp.float32),
        steps=jnp.zeros((cfg.n_max,), jnp.int32),
        n_pieces=jnp.zeros((), jnp.int32),
        symbols_online=jnp.zeros((cfg.n_max,), jnp.int32),
        t0=jnp.zeros((), jnp.float32),
        t_seen=jnp.zeros((), jnp.int32),
        chunks=jnp.zeros((), jnp.int32),
    )


def _digitize_new_pieces(
    dig, symbols_online, endpoints, steps, n_pieces, t0, *, tol, scl, n_max,
    k_min, k_max, lloyd_iters
):
    """Digitize buffer slots ``[dig.n, n_pieces)``; record first-time symbols."""
    lens, incs = pieces_from_wire(endpoints, steps, n_pieces, t0)
    dig_new, span_syms = digitize_span(
        dig, lens, incs, dig.n, n_pieces, tol=tol, scl=scl,
        k_min=k_min, k_max_active=k_max, lloyd_iters=lloyd_iters,
    )
    idx = jnp.arange(n_max)
    in_span = (idx >= dig.n) & (idx < n_pieces)
    return dig_new, jnp.where(in_span, span_syms, symbols_online)


def _digitize_new_pieces_table(
    dig, symbols_online, endpoints, steps, n_pieces, t0, emitted, *, tol, scl,
    n_max, k_min, k_max, lloyd_iters, use_kernel
):
    """Table-level ``_digitize_new_pieces``: one fused pass over all slots.

    ``emitted`` (S,) gates the digitize per lane *by span*, not by branch:
    off-cadence lanes get an empty ``[dig.n, dig.n)`` span, which the
    ``digitize_span_table`` cursor loop never visits -- bitwise-identical to
    the per-slot ``lax.cond(emitted, digitize, skip)`` (whose vmapped select
    would run the full clustering for every lane and discard it).
    """
    lens, incs = jax.vmap(pieces_from_wire)(endpoints, steps, n_pieces, t0)
    hi = jnp.where(emitted, n_pieces, dig.n)
    dig_new, span_syms = digitize_span_table(
        dig, lens, incs, dig.n, hi, tol=tol, scl=scl,
        k_min=k_min, k_max_active=k_max, lloyd_iters=lloyd_iters,
        use_kernel=use_kernel,
    )
    idx = jnp.arange(n_max)[None, :]
    in_span = (idx >= dig.n[:, None]) & (idx < hi[:, None])
    return dig_new, jnp.where(in_span, span_syms, symbols_online)


def _symbol_delta_info(n_dig_prev, dig, symbols_online, endpoints, emitted):
    """The per-chunk wire-out payload: what this call's digitize pass added.

    ``emitted`` flags whether a delta frame goes on the wire at all (off-
    cadence windows emit nothing); ``frame_bytes`` is the outbound traffic
    of the frame (0 when no frame is emitted).
    """
    labels_d, endpoints_d, n_new = digitizer_delta(
        n_dig_prev, dig, symbols_online, endpoints
    )
    emitted = jnp.asarray(emitted, bool)
    return {
        "labels": labels_d,
        "endpoints": endpoints_d,
        "n_new": n_new,
        "emitted": emitted,
        "frame_bytes": jnp.where(emitted, delta_frame_bytes(n_new), 0.0),
    }


@functools.partial(
    jax.jit,
    static_argnames=(
        "len_max", "n_max", "k_min", "k_max", "lloyd_iters",
        "digitize_every_k", "first",
    ),
)
def _receive_chunk(  # symlint: entry(drive=chunked, budget=0, shapes=receive-chunk)
    chunk, state, key, *, tol, alpha, scl, len_max, n_max, k_min, k_max,
    lloyd_iters, digitize_every_k, first,
):
    chunk = jnp.asarray(chunk, jnp.float32)
    if first:
        state = ReceiverState(
            comp=compressor_init(chunk[0]),
            dig=digitizer_init(n_max, k_max, key),
            endpoints=jnp.zeros((n_max,), jnp.float32),
            steps=jnp.zeros((n_max,), jnp.int32),
            n_pieces=jnp.zeros((), jnp.int32),
            symbols_online=jnp.zeros((n_max,), jnp.int32),
            t0=chunk[0],
            t_seen=jnp.ones((), jnp.int32),
            chunks=jnp.zeros((), jnp.int32),
        )
        xs = chunk[1:]
    else:
        xs = chunk

    # --- sender: same scan step as compress_stream / symed_encode_chunk ----
    def step(s, t):
        return compressor_step(s, t, tol=tol, len_max=len_max, alpha=alpha)

    comp, events = jax.lax.scan(step, state.comp, xs)

    # --- wire: scatter this window's emissions into the padded buffers -----
    step_idx = state.t_seen + jnp.arange(xs.shape[0], dtype=jnp.int32)
    endpoints, steps, n_pieces = compact_chunk(
        state.endpoints, state.steps, state.n_pieces,
        events.emit, events.endpoint, step_idx,
    )
    t_seen = state.t_seen + xs.shape[0]
    chunks = state.chunks + 1

    # --- receiver: digitize the newly arrived pieces every k windows -------
    n_dig_prev = state.dig.n
    if digitize_every_k:
        def digitize(dig, symbols_online):
            return _digitize_new_pieces(
                dig, symbols_online, endpoints, steps, n_pieces, state.t0,
                tol=tol, scl=scl, n_max=n_max, k_min=k_min, k_max=k_max,
                lloyd_iters=lloyd_iters,
            )

        def skip(dig, symbols_online):
            return dig, symbols_online

        emitted = chunks % digitize_every_k == 0
        dig, symbols_online = jax.lax.cond(
            emitted, digitize, skip, state.dig, state.symbols_online,
        )
    else:
        emitted = jnp.zeros((), bool)
        dig, symbols_online = state.dig, state.symbols_online

    new_state = ReceiverState(
        comp=comp, dig=dig, endpoints=endpoints, steps=steps,
        n_pieces=n_pieces, symbols_online=symbols_online,
        t0=state.t0, t_seen=t_seen, chunks=chunks,
    )
    info = {
        "n_pieces": n_pieces,
        "n_digitized": dig.n,
        "symbols_online": symbols_online,
        "symbol_delta": _symbol_delta_info(
            n_dig_prev, dig, symbols_online, endpoints, emitted
        ),
    }
    return new_state, info


def symed_receive_chunk(
    ts_chunk: jax.Array,
    cfg: SymEDConfig,
    state: Optional[ReceiverState] = None,
    key: Optional[jax.Array] = None,
    *,
    digitize_every_k: int = 1,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Fully-online step: ingest one ``(C,)`` window, sender *and* receiver.

    ``state=None`` opens the stream (``key`` is then required -- it seeds the
    digitizer exactly like the ``symed_finish`` path).  Every call compresses
    the window and wire-compacts the emitted pieces; every
    ``digitize_every_k``-th call additionally digitizes the pieces that
    arrived since the last digitization, so symbols stream out while the
    stream is still arriving.  ``digitize_every_k=0`` defers all digitization
    to ``symed_receive_finish`` (the pure ``symed_step_chunk`` behavior).

    End-of-stream outputs (via ``symed_receive_finish``) are bitwise-equal to
    ``symed_encode`` / ``symed_finish`` on the same stream for *any* window
    split and cadence -- the digitizer state evolution depends only on the
    piece arrival order, never on when it runs (tested in
    ``tests/test_streaming_receiver.py``).

    Returns ``(state, info)``: ``info["n_pieces"]`` pieces arrived so far, of
    which ``info["n_digitized"]`` have symbols in ``info["symbols_online"]``.
    ``info["symbol_delta"]`` is the per-chunk wire-out payload -- the
    ``(labels, endpoints, n_new)`` symbols this call's digitize pass added
    (``emitted``/``frame_bytes`` describe the outbound frame; concatenating
    the deltas of every call plus the finish reproduces ``symbols_online``
    exactly -- see ``repro.launch.stream``).

    Single-stream semantics ((C,) windows); ``jax.vmap`` over the leading
    axis for slabs (``repro.launch.fleet`` does exactly that).
    """
    if state is None and key is None:
        raise ValueError("opening a stream (state=None) requires a PRNG key")
    if digitize_every_k < 0:
        raise ValueError(f"digitize_every_k must be >= 0, got {digitize_every_k}")
    if key is None:
        key = jax.random.key(0)  # ignored when state is not None
    return _receive_chunk(
        ts_chunk, state, key, tol=cfg.tol, alpha=cfg.alpha, scl=cfg.scl,
        len_max=cfg.len_max, n_max=cfg.n_max, k_min=cfg.k_min, k_max=cfg.k_max,
        lloyd_iters=cfg.lloyd_iters, digitize_every_k=int(digitize_every_k),
        first=state is None,
    )


def _masked_sender_wire(chunk, n_valid, state, *, tol, alpha, len_max):
    """Per-slot sender scan + wire compaction of one masked window.

    The non-digitize half of ``_masked_receive_chunk``, factored out so the
    table-level path (``symed_receive_masked_chunk_table``) can vmap it
    while hoisting the digitize pass out of the per-slot program.  Returns
    ``(comp, t0, t_seen, endpoints, steps, n_pieces, chunks)``.

    Three runtime branches per scan slot (vs the static ``first`` split of
    ``_receive_chunk``): padding passes the carry through, the stream's
    very first valid point seeds the compressor (compressor_init, exactly
    like ``chunk[0]`` in the unmasked path), everything else runs
    ``compressor_step``.  Per-lane arithmetic is identical to the unmasked
    path, so end-of-stream outputs stay bitwise-equal.
    """
    chunk = jnp.asarray(chunk, jnp.float32)
    c_len = chunk.shape[0]

    def no_event():
        return (
            jnp.zeros((), bool), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32),
        )

    def step(carry, inp):
        comp, t0, t_seen = carry
        x, valid = inp

        def skip(comp, t0, t_seen):
            return (comp, t0, t_seen), no_event()

        def seed(comp, t0, t_seen):
            return (compressor_init(x), x, jnp.ones((), jnp.int32)), no_event()

        def ingest(comp, t0, t_seen):
            comp2, ev = compressor_step(
                comp, x, tol=tol, len_max=len_max, alpha=alpha
            )
            # t_seen is the 0-based stream index of x: the receiver's
            # arrival clock, same convention as the unmasked ``step_idx``
            return (comp2, t0, t_seen + 1), (ev.emit, ev.endpoint, t_seen)

        branch = jnp.where(valid, jnp.where(t_seen == 0, 1, 2), 0)
        return jax.lax.switch(branch, [skip, seed, ingest], comp, t0, t_seen)

    valid = jnp.arange(c_len) < n_valid
    (comp, t0, t_seen), (emit, chunk_endpoints, step_idx) = jax.lax.scan(
        step, (state.comp, state.t0, state.t_seen), (chunk, valid)
    )

    endpoints, steps, n_pieces = compact_chunk(
        state.endpoints, state.steps, state.n_pieces,
        emit, chunk_endpoints, step_idx,
    )
    chunks = state.chunks + (n_valid > 0).astype(jnp.int32)
    return comp, t0, t_seen, endpoints, steps, n_pieces, chunks


@functools.partial(
    jax.jit,
    static_argnames=(
        "len_max", "n_max", "k_min", "k_max", "lloyd_iters", "digitize_every_k",
    ),
)
def _masked_receive_chunk(
    chunk, n_valid, state, *, tol, alpha, scl, len_max, n_max, k_min, k_max,
    lloyd_iters, digitize_every_k,
):
    # --- sender + wire: scan every padded slot; only the first n_valid act -
    comp, t0, t_seen, endpoints, steps, n_pieces, chunks = _masked_sender_wire(
        chunk, n_valid, state, tol=tol, alpha=alpha, len_max=len_max
    )

    n_dig_prev = state.dig.n
    if digitize_every_k:
        def digitize(dig, symbols_online):
            return _digitize_new_pieces(
                dig, symbols_online, endpoints, steps, n_pieces, t0,
                tol=tol, scl=scl, n_max=n_max, k_min=k_min, k_max=k_max,
                lloyd_iters=lloyd_iters,
            )

        def skip_dig(dig, symbols_online):
            return dig, symbols_online

        emitted = (n_valid > 0) & (chunks % digitize_every_k == 0)
        dig, symbols_online = jax.lax.cond(
            emitted, digitize, skip_dig, state.dig, state.symbols_online,
        )
    else:
        emitted = jnp.zeros((), bool)
        dig, symbols_online = state.dig, state.symbols_online

    new_state = ReceiverState(
        comp=comp, dig=dig, endpoints=endpoints, steps=steps,
        n_pieces=n_pieces, symbols_online=symbols_online,
        t0=t0, t_seen=t_seen, chunks=chunks,
    )
    info = {
        "n_pieces": n_pieces,
        "n_digitized": dig.n,
        "t_seen": t_seen,
        "symbols_online": symbols_online,
        "symbol_delta": _symbol_delta_info(
            n_dig_prev, dig, symbols_online, endpoints, emitted
        ),
    }
    return new_state, info


def symed_receive_masked_chunk(  # symlint: entry(pair=chunk/slot, shapes=pair-chunk-slot)
    ts_chunk: jax.Array,
    n_valid: jax.Array,
    cfg: SymEDConfig,
    state: ReceiverState,
    *,
    digitize_every_k: int = 1,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Session-table variant of ``symed_receive_chunk``: padded ragged ingest.

    Ingests the first ``n_valid`` points of the ``(C,)`` window ``ts_chunk``
    (a *runtime* scalar -- network arrivals are ragged) into a state that
    must already exist (``receiver_init`` for a fresh slot; seeding happens
    at runtime when the first valid point arrives, so fresh and resumed
    slots batch through one program).  ``n_valid = 0`` is a no-op carrying
    the state through unchanged -- idle slots of a session table cost one
    masked scan, no state change.

    Bitwise contract: for any padding arrangement, the resulting state
    equals what ``symed_receive_chunk`` produces on the same valid points,
    so end-of-stream outputs stay bitwise-equal to ``symed_encode`` /
    ``symed_finish`` (tested in ``tests/test_stream_service.py``).

    Single-slot semantics; ``jax.vmap`` over the leading axis for slot
    tables (``repro.launch.stream`` does exactly that, under a donated jit).
    """
    if digitize_every_k < 0:
        raise ValueError(f"digitize_every_k must be >= 0, got {digitize_every_k}")
    return _masked_receive_chunk(
        ts_chunk, jnp.asarray(n_valid, jnp.int32), state,
        tol=cfg.tol, alpha=cfg.alpha, scl=cfg.scl, len_max=cfg.len_max,
        n_max=cfg.n_max, k_min=cfg.k_min, k_max=cfg.k_max,
        lloyd_iters=cfg.lloyd_iters, digitize_every_k=int(digitize_every_k),
    )


def symed_receive_masked_chunk_table(  # symlint: entry(pair=chunk/table, shapes=pair-chunk-table)
    windows: jax.Array,
    n_valid: jax.Array,
    cfg: SymEDConfig,
    table: ReceiverState,
    *,
    digitize_every_k: int = 1,
    use_kernel: bool = False,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Slot-table batch of ``symed_receive_masked_chunk`` with fused digitize.

    The sender scan + wire compaction run per slot under ``jax.vmap``
    (identical lowering to vmapping the per-slot function); the digitize
    pass is hoisted to *table level* -- one ``digitize_span_table`` cursor
    loop whose trip count is the widest span of newly arrived pieces in the
    table (the per-slot path pays O(n_max) per lane under vmap's
    cond-to-select lowering), and whose Lloyd assign half-steps fuse across
    all slots into single ``pallas_call``s when ``use_kernel=True``
    (``kernels.ops.kmeans_assign``; CPU deployments keep the bitwise
    vmapped reference path).

    Args:
      windows: (S, C) padded arrival windows.
      n_valid: (S,) valid point counts (0 = idle slot, masked no-op).
      table: batched ReceiverState ((S,) leading axis on every leaf).

    Returns ``(table, info)`` shaped like a vmapped
    ``symed_receive_masked_chunk`` -- and, on the reference path, bitwise-
    equal to it (property battery in ``tests/test_stream_service.py``).
    Callers jit this (``repro.launch.stream._table_step`` donates the table
    through it); it is not jitted here.
    """
    if digitize_every_k < 0:
        raise ValueError(f"digitize_every_k must be >= 0, got {digitize_every_k}")
    n_valid = jnp.asarray(n_valid, jnp.int32)
    comp, t0, t_seen, endpoints, steps, n_pieces, chunks = jax.vmap(
        lambda w, n, s: _masked_sender_wire(
            w, n, s, tol=cfg.tol, alpha=cfg.alpha, len_max=cfg.len_max)
    )(windows, n_valid, table)

    n_dig_prev = table.dig.n
    if digitize_every_k:
        emitted = (n_valid > 0) & (chunks % int(digitize_every_k) == 0)
        dig, symbols_online = _digitize_new_pieces_table(
            table.dig, table.symbols_online, endpoints, steps, n_pieces, t0,
            emitted, tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max,
            k_min=cfg.k_min, k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters,
            use_kernel=use_kernel,
        )
    else:
        emitted = jnp.zeros(n_valid.shape, bool)
        dig, symbols_online = table.dig, table.symbols_online

    new_table = ReceiverState(
        comp=comp, dig=dig, endpoints=endpoints, steps=steps,
        n_pieces=n_pieces, symbols_online=symbols_online,
        t0=t0, t_seen=t_seen, chunks=chunks,
    )
    info = {
        "n_pieces": n_pieces,
        "n_digitized": dig.n,
        "t_seen": t_seen,
        "symbols_online": symbols_online,
        "symbol_delta": jax.vmap(_symbol_delta_info)(
            n_dig_prev, dig, symbols_online, endpoints, emitted
        ),
    }
    return new_table, info


def symed_receive_masked_pieces_table(  # symlint: entry(pair=pieces/table, shapes=pair-pieces-table)
    piece_endpoints: jax.Array,
    piece_steps: jax.Array,
    n_valid: jax.Array,
    hello: jax.Array,
    t_seen: jax.Array,
    cfg: SymEDConfig,
    table: ReceiverState,
    *,
    digitize_every_k: int = 1,
    use_kernel: bool = False,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Compressed-in counterpart of ``symed_receive_masked_chunk_table``.

    Scatters each slot's padded piece tuples into its wire buffers (vmapped
    ``compact_chunk``; the sender already ran the compressor) and digitizes
    at table level.  See ``symed_receive_masked_pieces`` for the wire
    semantics and ``symed_receive_masked_chunk_table`` for the fusion /
    bitwise contract.  Arguments carry an (S,) slot axis.
    """
    if digitize_every_k < 0:
        raise ValueError(f"digitize_every_k must be >= 0, got {digitize_every_k}")
    n_valid = jnp.asarray(n_valid, jnp.int32)
    p_cap = piece_endpoints.shape[1]
    t0 = jnp.where(table.t_seen == 0, jnp.asarray(hello, jnp.float32), table.t0)
    valid = jnp.arange(p_cap)[None, :] < n_valid[:, None]
    endpoints, steps, n_pieces = jax.vmap(compact_chunk)(
        table.endpoints, table.steps, table.n_pieces,
        valid, jnp.asarray(piece_endpoints, jnp.float32),
        jnp.asarray(piece_steps, jnp.int32),
    )
    t_seen = jnp.maximum(table.t_seen, jnp.asarray(t_seen, jnp.int32))
    chunks = table.chunks + (n_valid > 0).astype(jnp.int32)

    n_dig_prev = table.dig.n
    if digitize_every_k:
        emitted = (n_valid > 0) & (chunks % int(digitize_every_k) == 0)
        dig, symbols_online = _digitize_new_pieces_table(
            table.dig, table.symbols_online, endpoints, steps, n_pieces, t0,
            emitted, tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max,
            k_min=cfg.k_min, k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters,
            use_kernel=use_kernel,
        )
    else:
        emitted = jnp.zeros(n_valid.shape, bool)
        dig, symbols_online = table.dig, table.symbols_online

    new_table = ReceiverState(
        comp=table.comp, dig=dig, endpoints=endpoints, steps=steps,
        n_pieces=n_pieces, symbols_online=symbols_online,
        t0=t0, t_seen=t_seen, chunks=chunks,
    )
    info = {
        "n_pieces": n_pieces,
        "n_digitized": dig.n,
        "t_seen": t_seen,
        "symbols_online": symbols_online,
        "symbol_delta": jax.vmap(_symbol_delta_info)(
            n_dig_prev, dig, symbols_online, endpoints, emitted
        ),
    }
    return new_table, info


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_max", "k_min", "k_max", "lloyd_iters", "digitize_every_k",
    ),
)
def _masked_receive_pieces(
    piece_endpoints, piece_steps, n_valid, hello, t_seen_new, state, *, tol,
    scl, n_max, k_min, k_max, lloyd_iters, digitize_every_k,
):
    p_cap = piece_endpoints.shape[0]

    # --- wire: the sender already ran the compressor; just scatter ---------
    # ``compact_chunk`` with a prefix mask places the arriving tuples at
    # slots [n_pieces, n_pieces + n_valid) -- the identical buffer content a
    # raw-mode ingest of the same stream would have produced, which is what
    # keeps the end-of-stream outputs bitwise-equal across transport modes.
    t0 = jnp.where(state.t_seen == 0, hello, state.t0)
    valid = jnp.arange(p_cap) < n_valid
    endpoints, steps, n_pieces = compact_chunk(
        state.endpoints, state.steps, state.n_pieces,
        valid, jnp.asarray(piece_endpoints, jnp.float32),
        jnp.asarray(piece_steps, jnp.int32),
    )
    t_seen = jnp.maximum(state.t_seen, t_seen_new)
    chunks = state.chunks + (n_valid > 0).astype(jnp.int32)

    # --- receiver: digitize cadence identical to the masked raw path ------
    n_dig_prev = state.dig.n
    if digitize_every_k:
        def digitize(dig, symbols_online):
            return _digitize_new_pieces(
                dig, symbols_online, endpoints, steps, n_pieces, t0,
                tol=tol, scl=scl, n_max=n_max, k_min=k_min, k_max=k_max,
                lloyd_iters=lloyd_iters,
            )

        def skip_dig(dig, symbols_online):
            return dig, symbols_online

        emitted = (n_valid > 0) & (chunks % digitize_every_k == 0)
        dig, symbols_online = jax.lax.cond(
            emitted, digitize, skip_dig, state.dig, state.symbols_online,
        )
    else:
        emitted = jnp.zeros((), bool)
        dig, symbols_online = state.dig, state.symbols_online

    new_state = ReceiverState(
        comp=state.comp, dig=dig, endpoints=endpoints, steps=steps,
        n_pieces=n_pieces, symbols_online=symbols_online,
        t0=t0, t_seen=t_seen, chunks=chunks,
    )
    info = {
        "n_pieces": n_pieces,
        "n_digitized": dig.n,
        "t_seen": t_seen,
        "symbols_online": symbols_online,
        "symbol_delta": _symbol_delta_info(
            n_dig_prev, dig, symbols_online, endpoints, emitted
        ),
    }
    return new_state, info


def symed_receive_masked_pieces(  # symlint: entry(pair=pieces/slot, shapes=pair-pieces-slot)
    piece_endpoints: jax.Array,
    piece_steps: jax.Array,
    n_valid: jax.Array,
    hello: jax.Array,
    t_seen: jax.Array,
    cfg: SymEDConfig,
    state: ReceiverState,
    *,
    digitize_every_k: int = 1,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Compressed-in variant of ``symed_receive_masked_chunk``.

    The sender ran ``CompressorState`` locally (``repro.launch.transport``
    pieces mode) and ships finished pieces instead of raw points: the first
    ``n_valid`` of the padded ``(P,)`` tuples ``(piece_endpoints[i],
    piece_steps[i])`` are scattered straight into the wire buffers -- the
    per-slot compressor never runs.  ``hello`` is the sender's 4-byte t0
    payload (consumed only while ``state.t_seen == 0``); ``t_seen`` is the
    sender's cumulative point clock after this frame (runtime scalar; the
    receiver needs it for cr/drr and as the close-time arrival clock).
    ``n_valid = 0`` with ``t_seen > 0`` still advances the clock (a frame
    whose window finished no piece).

    Bitwise contract: scattering the tuples a sender-side
    ``symed_encode_chunk`` emitted (via ``compress_stream``'s arithmetic --
    the same per-point program the raw-mode receiver runs) yields the exact
    wire-buffer content of raw-mode ingest, and the digitizer evolution
    depends only on piece arrival order, so ``symed_receive_finish`` outputs
    and concatenated symbol deltas stay bitwise-equal to ``symed_encode``
    across transport modes (tested in ``tests/test_transport.py``).

    The sender's trailing flush arrives as an ordinary piece tuple with
    ``step = t_seen`` (the CLOSE frame's payload); the blank slot compressor
    then has nothing to flush at ``symed_receive_finish``.

    Single-slot semantics; ``jax.vmap`` over the leading axis for slot
    tables (``repro.launch.stream.ingest_pieces_many`` does exactly that).
    """
    if digitize_every_k < 0:
        raise ValueError(f"digitize_every_k must be >= 0, got {digitize_every_k}")
    return _masked_receive_pieces(
        piece_endpoints, piece_steps, jnp.asarray(n_valid, jnp.int32),
        jnp.asarray(hello, jnp.float32), jnp.asarray(t_seen, jnp.int32),
        state, tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max, k_min=cfg.k_min,
        k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters,
        digitize_every_k=int(digitize_every_k),
    )


def symed_step_chunk(
    ts_chunk: jax.Array,
    cfg: SymEDConfig,
    state: Optional[ReceiverState] = None,
    key: Optional[jax.Array] = None,
) -> Tuple[ReceiverState, Dict[str, jax.Array]]:
    """Sender+wire only: ingest a window without running the digitizer.

    Equivalent to ``symed_receive_chunk(..., digitize_every_k=0)``; the
    digitizer catches up wholesale in ``symed_receive_finish``.
    """
    return symed_receive_chunk(ts_chunk, cfg, state, key, digitize_every_k=0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_max", "k_min", "k_max", "lloyd_iters", "reconstruct", "with_delta",
    ),
)
def _receive_finish(  # symlint: entry(drive=chunked, budget=0, shapes=receive-finish)
    state, ts, *, tol, scl, n_max, k_min, k_max, lloyd_iters, reconstruct,
    with_delta=False,
):
    tail = compressor_finalize(state.comp)
    endpoints, steps, n_pieces = append_tail(
        state.endpoints, state.steps, state.n_pieces, tail, state.t_seen
    )
    lens, incs = pieces_from_wire(endpoints, steps, n_pieces, state.t0)
    dig, span_syms = digitize_span(
        state.dig, lens, incs, state.dig.n, n_pieces, tol=tol, scl=scl,
        k_min=k_min, k_max_active=k_max, lloyd_iters=lloyd_iters,
    )
    idx = jnp.arange(n_max)
    in_span = (idx >= state.dig.n) & (idx < n_pieces)
    symbols_online = jnp.where(in_span, span_syms, state.symbols_online)

    out = {
        "symbols": dig.labels,
        "symbols_online": symbols_online,
        "centers": dig.centers,
        "k": dig.k,
        "pieces_len": lens,
        "pieces_inc": incs,
        "n_pieces": n_pieces,
        "wire_bytes": 4.0 + 4.0 * n_pieces.astype(jnp.float32),
        "cr": compression_rate_symed(n_pieces, state.t_seen),
        "drr": drr(n_pieces, state.t_seen),
    }
    if with_delta:
        # the closing delta frame: every piece digitized by this flush
        out["symbol_delta"] = _symbol_delta_info(
            state.dig.n, dig, symbols_online, endpoints,
            jnp.ones((), bool),
        )
    if reconstruct:
        t_len = ts.shape[-1]
        rec_p = reconstruct_from_pieces(lens, incs, n_pieces, state.t0, t_len)
        rec_s = reconstruct_from_symbols(
            dig.labels, dig.centers, n_pieces, state.t0, t_len
        )
        out["recon_pieces"] = rec_p
        out["recon_symbols"] = rec_s
        out["re_pieces"] = dtw_ref(ts, rec_p)
        out["re_symbols"] = dtw_ref(ts, rec_s)
    return out


def symed_receive_finish(
    state: ReceiverState,
    cfg: SymEDConfig,
    ts: Optional[jax.Array] = None,
    reconstruct: bool = False,
    *,
    with_delta: bool = False,
) -> Dict[str, jax.Array]:
    """Close a streaming-receiver stream: flush the tail, digitize the rest.

    Output dict matches ``symed_encode`` / ``symed_finish`` bitwise.  ``ts``
    (the full raw stream) is only required when ``reconstruct=True`` -- unlike
    ``symed_finish``, the receiver carries everything else (``t0``, the
    stream length ``t_seen``) in its state.  ``with_delta=True`` additionally
    returns ``out["symbol_delta"]`` -- the closing wire-out frame carrying
    the symbols this final digitize pass added (the last piece of the
    delta-concatenation contract; see ``repro.launch.stream``).
    """
    if reconstruct and ts is None:
        raise ValueError("reconstruct=True requires the raw stream ts")
    ts = jnp.zeros((1,), jnp.float32) if ts is None else jnp.asarray(ts, jnp.float32)
    return _receive_finish(
        state, ts, tol=cfg.tol, scl=cfg.scl, n_max=cfg.n_max, k_min=cfg.k_min,
        k_max=cfg.k_max, lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
        with_delta=with_delta,
    )


def symed_batch(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Vectorized fleet slab: ``ts`` is (B, T); one PRNG key per stream."""
    keys = jax.random.split(key, ts.shape[0])
    return jax.vmap(lambda t, k: symed_encode(t, cfg, k, reconstruct))(ts, keys)


def symbols_to_string(labels, n_pieces) -> str:
    """Host-side helper: int labels -> 'abc...' string (I/O boundary only)."""
    import numpy as np

    labels = np.asarray(labels)[: int(n_pieces)]
    alphabet = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"
    return "".join(alphabet[l % len(alphabet)] for l in labels)
