"""SymED end-to-end pipeline: the paper's contribution as one composable module.

    sender (IoT, Alg. 1)  --one float/piece-->  receiver (edge, Alg. 2+3)

``symed_encode`` runs a single stream through sender -> wire -> receiver and
returns symbols, pieces, centers plus wire-traffic accounting.
``symed_batch`` vmaps it over a fleet slab (the distributed runtime in
``repro.launch.fleet`` shards slabs over the mesh ``data`` axis with
shard_map).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.compress import compress_stream
from repro.core.digitize import digitize_pieces
from repro.core.metrics import compression_rate_symed, drr, dtw_ref
from repro.core.receiver import compact_events
from repro.core.reconstruct import reconstruct_from_pieces, reconstruct_from_symbols

__all__ = ["SymEDConfig", "symed_encode", "symed_batch", "symbols_to_string"]


@dataclasses.dataclass(frozen=True)
class SymEDConfig:
    """Hyperparameters (paper Sec. 4.1 defaults)."""

    tol: float = 0.5          # error-tolerance (compression + digitization)
    alpha: float = 0.01       # damped-window weight (paper: 0.01..0.02)
    scl: float = 1.0          # length-vs-increment weight (2D clustering)
    k_min: int = 3            # minimum alphabet size
    k_max: int = 100          # maximum alphabet size
    len_max: int = 512        # maximum points per piece
    n_max: int = 512          # per-stream piece buffer capacity
    lloyd_iters: int = 10     # Lloyd iterations per k-means warm start

    def static_fields(self) -> Dict[str, Any]:
        return dict(
            len_max=self.len_max, n_max=self.n_max, k_min=self.k_min,
            k_max_active=self.k_max, lloyd_iters=self.lloyd_iters,
        )


@functools.partial(
    jax.jit,
    static_argnames=("len_max", "n_max", "k_min", "k_max", "lloyd_iters", "reconstruct"),
)
def _encode(
    ts, key, *, tol, alpha, scl, len_max, n_max, k_min, k_max, lloyd_iters, reconstruct
):
    ts = jnp.asarray(ts, jnp.float32)
    t_len = ts.shape[-1]

    # --- sender (IoT node) -------------------------------------------------
    events = compress_stream(ts, tol=tol, len_max=len_max, alpha=alpha)
    # --- wire ---------------------------------------------------------------
    wire = compact_events(events, n_max=n_max, t0=ts[0])
    # --- receiver (edge node) ----------------------------------------------
    dig = digitize_pieces(
        wire["lengths"], wire["incs"], wire["n_pieces"], key,
        k_cap=k_max, tol=tol, scl=scl, k_min=k_min,
        k_max_active=k_max, lloyd_iters=lloyd_iters,
    )

    out = {
        "symbols": dig["labels"],
        "symbols_online": dig["symbols"],
        "centers": dig["centers"],
        "k": dig["k"],
        "pieces_len": wire["lengths"],
        "pieces_inc": wire["incs"],
        "n_pieces": wire["n_pieces"],
        "wire_bytes": 4.0 + 4.0 * wire["n_pieces"].astype(jnp.float32),
        "cr": compression_rate_symed(wire["n_pieces"], t_len),
        "drr": drr(wire["n_pieces"], t_len),
    }
    if reconstruct:
        rec_p = reconstruct_from_pieces(
            wire["lengths"], wire["incs"], wire["n_pieces"], ts[0], t_len
        )
        rec_s = reconstruct_from_symbols(
            dig["labels"], dig["centers"], wire["n_pieces"], ts[0], t_len
        )
        out["recon_pieces"] = rec_p
        out["recon_symbols"] = rec_s
        out["re_pieces"] = dtw_ref(ts, rec_p)
        out["re_symbols"] = dtw_ref(ts, rec_s)
    return out


def symed_encode(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Encode one stream ``(T,)``; optionally reconstruct + score both modes."""
    return _encode(
        ts, key, tol=cfg.tol, alpha=cfg.alpha, scl=cfg.scl,
        len_max=cfg.len_max, n_max=cfg.n_max, k_min=cfg.k_min, k_max=cfg.k_max,
        lloyd_iters=cfg.lloyd_iters, reconstruct=reconstruct,
    )


def symed_batch(
    ts: jax.Array, cfg: SymEDConfig, key: jax.Array, reconstruct: bool = True
) -> Dict[str, jax.Array]:
    """Vectorized fleet slab: ``ts`` is (B, T); one PRNG key per stream."""
    keys = jax.random.split(key, ts.shape[0])
    return jax.vmap(lambda t, k: symed_encode(t, cfg, k, reconstruct))(ts, keys)


def symbols_to_string(labels, n_pieces) -> str:
    """Host-side helper: int labels -> 'abc...' string (I/O boundary only)."""
    import numpy as np

    labels = np.asarray(labels)[: int(n_pieces)]
    alphabet = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"
    return "".join(alphabet[l % len(alphabet)] for l in labels)
