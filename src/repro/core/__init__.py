"""SymED core: the paper's contribution as composable JAX modules.

Sender (Alg. 1): ``normalize`` (EWMA/EWMV) + ``compress`` (O(1) bridge error).
Receiver (Alg. 2/3): ``receiver`` (wire -> pieces) + ``digitize`` (online
k-means).  ``reconstruct``/``metrics`` close the loop; ``abba`` is the paper's
offline baseline; ``symed`` wires everything end to end.
"""
from repro.core.abba import AbbaResult, abba_encode
from repro.core.compress import (
    CompressorState,
    PieceEvent,
    bridge_error_direct,
    compress_stream,
    compressor_finalize,
    compressor_init,
    compressor_step,
)
from repro.core.digitize import (
    DigitizerState,
    digitize_pieces,
    digitize_span,
    digitizer_init,
    digitizer_step,
    masked_kmeans,
    max_cluster_variance,
    scale_coords,
)
from repro.core.metrics import (
    compression_rate_abba,
    compression_rate_symed,
    drr,
    dtw_ref,
)
from repro.core.normalize import EwmState, ewm_init, ewm_scan, ewm_step, standardize
from repro.core.receiver import (
    append_tail,
    compact_chunk,
    compact_events,
    pieces_from_wire,
)
from repro.core.reconstruct import (
    inverse_compression,
    inverse_digitization,
    quantize_lengths,
    reconstruct_from_pieces,
    reconstruct_from_symbols,
)
from repro.core.symed import (
    ReceiverState,
    SymEDConfig,
    symbols_to_string,
    symed_batch,
    symed_encode,
    symed_encode_chunk,
    symed_finish,
    symed_receive_chunk,
    symed_receive_finish,
    symed_step_chunk,
)

__all__ = [k for k in dir() if not k.startswith("_")]
