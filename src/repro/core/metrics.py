"""Evaluation metrics (paper Sec. 4.1): DTW reconstruction error, compression
rate, dimension-reduction rate.

DTW here is the pure-jnp reference (anti-diagonal wavefront, optionally
Sakoe-Chiba banded).  The Pallas kernel in ``repro.kernels.dtw`` implements the
same recurrence with VMEM-resident diagonals; ``repro.kernels.ops.dtw``
dispatches between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["dtw_ref", "compression_rate_symed", "compression_rate_abba", "drr"]

_INF = jnp.float32(1e30)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_ref(x: jax.Array, y: jax.Array, band: int | None = None) -> jax.Array:
    """DTW distance between 1-D series (batched on leading axes).

    Local cost (x_i - y_j)^2, accumulated along the optimal warping path;
    returns sqrt of the accumulated cost (as used by ABBA's evaluation).

    Anti-diagonal formulation: diagonal d holds cells (i, d-i).  Recurrence
      D[i,j] = c[i,j] + min(D[i-1,j], D[i,j-1], D[i-1,j-1])
    maps to
      cur[i] = c[i, d-i] + min(prev[i-1], prev[i], prev2[i-1]).

    Args:
      x: (..., N), y: (..., M).
      band: Sakoe-Chiba radius (|i-j| <= band); None = full DTW.  The
        effective radius is clamped to ``max(band, |N - M|)``: any warping
        path from (0, 0) to (N-1, M-1) must leave the diagonal by at least
        the length difference, so a narrower band would make the terminal
        cell unreachable and return the _INF sentinel as if it were a
        distance.
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n, m = x.shape[-1], y.shape[-1]
    r = max(band, abs(n - m)) if band is not None else max(n, m)

    ii = jnp.arange(n)

    def diag_step(carry, d):
        prev2, prev = carry  # diagonals d-2 and d-1, indexed by i
        jj = d - ii
        valid = (jj >= 0) & (jj < m) & (jnp.abs(ii - jj) <= r)
        yv = jnp.take_along_axis(
            jnp.broadcast_to(y, x.shape[:-1] + (m,)),
            jnp.broadcast_to(jnp.clip(jj, 0, m - 1), x.shape[:-1] + (n,)),
            axis=-1,
        )
        cost = (x - yv) ** 2

        shift = lambda a: jnp.concatenate([jnp.full_like(a[..., :1], _INF), a[..., :-1]], -1)
        best = jnp.minimum(jnp.minimum(shift(prev), prev), shift(prev2))
        # origin cell (0,0) has no predecessor
        best = jnp.where((ii == 0) & (jj == 0), 0.0, best)
        cur = cost + best
        cur = jnp.where(valid, cur, _INF)
        return (prev, cur), None

    prev2 = jnp.full(x.shape, _INF)
    prev = jnp.full(x.shape, _INF)
    (prev, cur), _ = jax.lax.scan(
        diag_step, (prev2, prev), jnp.arange(n + m - 1)
    )
    # after the last diagonal (d = n+m-2), cell (n-1, m-1) lives in ``cur``
    total = cur[..., n - 1]
    return jnp.sqrt(total)


def compression_rate_symed(n_pieces: jax.Array, n_points) -> jax.Array:
    """CR_SymED = (bytes(P)/2) / bytes(T)  [paper Eq. 3].

    One 4-byte float is transmitted per piece (the endpoint); raw points are
    4-byte floats, so CR = n/N.  (The one-off 4-byte t0 "hello" is excluded,
    matching the paper's formula; see benchmarks for the +4B variant.)
    ``n_points`` may be a static int or a traced scalar (the streaming
    receiver carries the observed stream length in its state).
    """
    return n_pieces.astype(jnp.float32) / jnp.asarray(n_points, jnp.float32)


def compression_rate_abba(
    n_pieces: jax.Array, k_clusters: jax.Array, n_points: int
) -> jax.Array:
    """CR_ABBA = (bytes(C) + bytes(S)) / bytes(T)  [paper Eq. 3].

    Symbols are 1 byte, centers are two 4-byte floats: (8k + n) / 4N.
    """
    num = 8.0 * k_clusters.astype(jnp.float32) + n_pieces.astype(jnp.float32)
    return num / (4.0 * jnp.float32(n_points))


def drr(n_symbols: jax.Array, n_points) -> jax.Array:
    """Dimension-reduction rate len(S)/len(T) (``n_points`` may be traced)."""
    return n_symbols.astype(jnp.float32) / jnp.asarray(n_points, jnp.float32)
