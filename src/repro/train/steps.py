"""Jittable train/serve steps + state construction and sharding specs.

``make_train_step`` closes over the model config and optimizer config and
returns the pure step function the launcher jits with explicit in/out
shardings.  ``make_compressed_train_step`` is the beyond-paper variant: the
whole step runs under ``shard_map`` manual on the ``pod`` axis (data/model
stay GSPMD-auto), so the cross-pod gradient exchange becomes an *explicit*
int8 quantized psum with error feedback -- 4x fewer wire bytes on the
pod-to-pod hop, visible in the dry-run HLO (EXPERIMENTS.md Sec. Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import decode_step as _decode_step
from repro.models import init_params, loss_fn
from repro.train.optimizer import OptConfig, clip_by_global_norm, opt_init, opt_update

__all__ = [
    "init_train_state", "make_train_step", "make_compressed_train_step",
    "make_serve_step", "quantized_psum_mean",
]


def init_train_state(key, cfg, oc: OptConfig) -> Dict[str, Any]:
    params = init_params(key, cfg)
    return {
        "params": params,
        "opt": opt_init(params, oc),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg, oc: OptConfig, *, remat: bool = True,
                    accum_steps: int = 1):
    """``accum_steps`` > 1 scans over microbatches accumulating f32 grads --
    the standard memory lever for the 100B+ configs (activation temps scale
    with the microbatch, the accumulator costs one param-sized f32 tree)."""

    def grad_fn(params, batch):
        def lf(p):
            return loss_fn(p, cfg, batch, remat=remat)

        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / accum_steps,
                    acc, g,
                )
                return acc, (l, m)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, (losses, ms) = jax.lax.scan(body, acc0, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
        new_params, new_opt = opt_update(
            grads, state["opt"], params, state["step"], oc
        )
        new_state = {
            "params": new_params, "opt": new_opt, "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, "grad_norm": gnorm, **metrics}

    return train_step


# ---------------------------------------------------------------------------
# int8 cross-pod gradient exchange (beyond-paper; SymED's tolerance idea
# generalized to the collective layer: bounded-error lossy wire format)
# ---------------------------------------------------------------------------

def quantized_psum_mean(tree, axis_name: str, n: int, error_fb=None):
    """Mean-psum over ``axis_name`` in int8 with a shared per-leaf scale.

    Two collectives per leaf: a scalar max-psum (scale agreement) and the int8
    sum.  Returns (mean_tree, new_error_fb): ``error_fb`` carries the local
    quantization residual into the next step (error feedback).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + (0.0 if e is None else e.astype(jnp.float32))
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        resid = gf - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = (total.astype(jnp.float32) * scale / n).astype(g.dtype)
        return mean, resid.astype(jnp.bfloat16)

    flat_g, td = jax.tree.flatten(tree)
    flat_e = td.flatten_up_to(error_fb) if error_fb is not None else [None] * len(flat_g)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(td, [o[0] for o in out]),
        jax.tree.unflatten(td, [o[1] for o in out]),
    )


def make_compressed_train_step(cfg, oc: OptConfig, mesh, *, remat: bool = True):
    """Train step with explicit int8 cross-pod gradient all-reduce.

    Requires a mesh with a ``pod`` axis.  Inside the shard_map body each pod
    computes gradients over its own batch shard (data/model axes remain
    GSPMD-auto); the pod axis is manual so the gradient exchange is ours.
    """
    assert "pod" in mesh.axis_names, "compressed step needs the multi-pod mesh"
    npods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def body(state, batch):
        def lf(p):
            return loss_fn(p, cfg, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        grads, efb = quantized_psum_mean(
            grads, "pod", npods, error_fb=state.get("error_fb")
        )
        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
        new_params, new_opt = opt_update(
            grads, state["opt"], state["params"], state["step"], oc
        )
        new_state = {
            "params": new_params, "opt": new_opt, "step": state["step"] + 1,
            "error_fb": efb,
        }
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"),
                               {"loss": loss, "grad_norm": gnorm, **metrics})
        return new_state, metrics

    def train_step(state, batch):
        state_specs = jax.tree.map(lambda _: P(), state)
        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        out_specs = (
            jax.tree.map(lambda _: P(), state), jax.tree.map(lambda _: P(), {
                "loss": 0, "grad_norm": 0, "xent": 0, "aux": 0,
            }),
        )
        from repro.utils.jax_compat import shard_map

        return shard_map(
            body, mesh, in_specs=(state_specs, batch_specs),
            out_specs=out_specs, axis_names=frozenset({"pod"}),
        )(state, batch)

    return train_step


def init_error_fb(params):
    """Zeroed error-feedback buffers (bf16) for the compressed step."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg, *, temperature: float = 0.0):
    def serve_step(params, state, token, key=None):
        logits, new_state = _decode_step(params, cfg, state, token)
        if temperature > 0.0 and key is not None:
            next_tok = jax.random.categorical(key, logits[:, -1] / temperature)
            next_tok = next_tok[:, None].astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, new_state

    return serve_step
