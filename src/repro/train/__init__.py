"""Package."""
