"""Optimizers: AdamW (configurable moment dtype) and Adafactor (factored
second moments for the 100B+ configs), plus global-norm clipping and a
warmup+cosine schedule.  Pure functions over param pytrees; optimizer state
mirrors the param tree so the same partitioner rules shard it (ZeRO-style:
moments are FSDP-sharded exactly like their params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "opt_init", "opt_update", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"   # bfloat16 halves optimizer HBM at >=100B
    warmup_steps: int = 100
    total_steps: int = 10_000


def _schedule(step, oc: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adamw_init(params, oc: OptConfig):
    dt = jnp.dtype(oc.moments_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}


def _adamw_update(grads, opt, params, step, oc: OptConfig):
    lr = _schedule(step, oc)
    b1, b2 = oc.b1, oc.b2
    t = step.astype(jnp.float32) + 1.0
    corr = jnp.sqrt(1.0 - b2 ** t) / (1.0 - b1 ** t)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        u = corr * m_new / (jnp.sqrt(v_new) + oc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    # flatten to avoid is_leaf tricks: superblocks are structural tuples
    flat_g, td = jax.tree.flatten(grads)
    out = [
        upd(g, m, v, p)
        for g, m, v, p in zip(
            flat_g, jax.tree.leaves(opt["m"]), jax.tree.leaves(opt["v"]),
            jax.tree.leaves(params),
        )
    ]
    new_params = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; first moment omitted, as in t5x default)
# ---------------------------------------------------------------------------

def _adafactor_init(params, oc: OptConfig):
    def per_leaf(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(per_leaf, params)}


def _adafactor_update(grads, opt, params, step, oc: OptConfig):
    lr = _schedule(step, oc)
    b2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, st, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr = b2 * st["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * st["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30)
            )
            u = gf * jax.lax.rsqrt(denom + 1e-30)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = b2 * st["v"] + (1 - b2) * g2
            u = gf * jax.lax.rsqrt(v + 1e-30)
            new_st = {"v": v}
        # update clipping (Adafactor's d=1.0 RMS rule)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        if p.ndim >= 2:
            u = u + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), new_st

    # factored state nests one dict below each param leaf: flatten up-to params
    flat_g, td = jax.tree.flatten(grads)
    flat_f = td.flatten_up_to(opt["f"])
    flat_p = td.flatten_up_to(params)
    out = [upd(g, st, p) for g, st, p in zip(flat_g, flat_f, flat_p)]
    new_params = jax.tree.unflatten(td, [o[0] for o in out])
    new_f = jax.tree.unflatten(td, [o[1] for o in out])
    return new_params, {"f": new_f}


def opt_init(params, oc: OptConfig):
    if oc.name == "adamw":
        return _adamw_init(params, oc)
    if oc.name == "adafactor":
        return _adafactor_init(params, oc)
    raise ValueError(oc.name)


def opt_update(grads, opt, params, step, oc: OptConfig):
    if oc.name == "adamw":
        return _adamw_update(grads, opt, params, step, oc)
    if oc.name == "adafactor":
        return _adafactor_update(grads, opt, params, step, oc)
    raise ValueError(oc.name)
