"""SymED-compressed training telemetry + straggler watchdog.

This is the paper's sender/receiver split mapped onto the cluster: every host
is an IoT-class *sender* that runs Alg. 1 (EWMA/EWMV normalization + O(1)
bridge-error compression, numpy scalar math -- cheap enough for a per-step
host callback), transmitting one float per emitted piece to the coordinator
*receiver*, which can digitize the piece stream into symbols on demand for
monitoring dashboards / anomaly mining.

The straggler watchdog dogfoods Eq. 1-2 directly: step times are z-scored
against the damped-window mean/variance; a z-score past the threshold flags a
straggler, a wall-clock timeout flags a hang.  (This is how SymED becomes a
first-class feature of the trainer, not a side-car -- see DESIGN.md Sec. 3.)
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

__all__ = ["NumpySender", "TelemetryHub", "StepWatchdog"]


class NumpySender:
    """Host-side SymED sender (paper Alg. 1) on plain Python floats."""

    def __init__(self, tol: float = 0.5, alpha: float = 0.05, len_max: int = 256):
        self.tol = tol
        self.alpha = alpha
        self.len_max = len_max
        self._n = 0
        self.wire: List[tuple] = []   # (step_index, endpoint) transmissions
        self._state = None

    def push(self, t: float) -> Optional[float]:
        """Ingest one point; returns the transmitted endpoint if a piece closed."""
        t = float(t)
        self._n += 1
        if self._state is None:
            # EWMA_0 = t0, EWMV_0 = 1; open segment at t0
            self._state = dict(mean=t, var=1.0, start=t, last=t, npts=1,
                               s0=0.0, s1=0.0, s2=0.0)
            self.wire.append((0, t))  # t0 hello (4 bytes)
            return None
        st = self._state
        a = self.alpha
        st["mean"] = a * t + (1 - a) * st["mean"]
        st["var"] = a * (t - st["mean"]) ** 2 + (1 - a) * st["var"]

        v = t - st["start"]
        h = float(st["npts"])
        s0, s1, s2 = st["s0"] + v, st["s1"] + h * v, st["s2"] + v * v
        npts = st["npts"] + 1
        length = max(npts - 1.0, 1.0)
        sum_h2 = length * (length + 1.0) * (2.0 * length + 1.0) / 6.0
        r = v / length
        err_raw = max(s2 - 2.0 * r * s1 + r * r * sum_h2, 0.0)
        err = err_raw / max(st["var"], 1e-12)
        bound = (npts - 2.0) * self.tol * self.tol

        if err > bound or npts > self.len_max:
            endpoint = st["last"]
            self.wire.append((self._n - 1, endpoint))
            v1 = t - st["last"]
            st.update(start=st["last"], last=t, npts=2, s0=v1, s1=v1, s2=v1 * v1)
            return endpoint
        st.update(last=t, npts=npts, s0=s0, s1=s1, s2=s2)
        return None

    @property
    def raw_bytes(self) -> int:
        return 4 * self._n

    @property
    def wire_bytes(self) -> int:
        return 4 * len(self.wire)

    def compression_rate(self) -> float:
        return self.wire_bytes / max(self.raw_bytes, 1)


class TelemetryHub:
    """Coordinator-side receiver: one SymED stream per (host, metric)."""

    def __init__(self, tol: float = 0.5, alpha: float = 0.05):
        self.tol = tol
        self.alpha = alpha
        self.senders: Dict[str, NumpySender] = {}

    def record(self, name: str, value: float):
        s = self.senders.setdefault(name, NumpySender(self.tol, self.alpha))
        s.push(value)

    def record_metrics(self, host: str, metrics: Dict[str, float]):
        for k, v in metrics.items():
            self.record(f"{host}/{k}", float(v))

    def traffic_report(self) -> Dict[str, Dict[str, float]]:
        return {
            name: {
                "raw_bytes": s.raw_bytes,
                "wire_bytes": s.wire_bytes,
                "cr": s.compression_rate(),
                "pieces": len(s.wire),
            }
            for name, s in self.senders.items()
        }

    def digitize(self, name: str, k_max: int = 16):
        """Receiver-side symbolization of one stream (on demand)."""
        import jax
        import jax.numpy as jnp

        from repro.core.digitize import digitize_pieces

        s = self.senders[name]
        if len(s.wire) < 2:
            return None
        steps = [w[0] for w in s.wire]
        ends = [w[1] for w in s.wire]
        n = len(ends) - 1
        n_max = max(8, 1 << (n - 1).bit_length())
        lens = [steps[i + 1] - steps[i] for i in range(n)] + [0] * (n_max - n)
        incs = [ends[i + 1] - ends[i] for i in range(n)] + [0.0] * (n_max - n)
        return digitize_pieces(
            jnp.asarray(lens, jnp.float32), jnp.asarray(incs, jnp.float32),
            jnp.asarray(n, jnp.int32), jax.random.key(0),
            k_cap=k_max, tol=self.tol, k_max_active=k_max,
        )


class StepWatchdog:
    """Straggler/hang detection on step times via the paper's EWMA/EWMV."""

    def __init__(self, alpha: float = 0.05, z_threshold: float = 4.0,
                 hang_factor: float = 10.0, warmup: int = 5):
        self.alpha = alpha
        self.z = z_threshold
        self.hang_factor = hang_factor
        self.warmup = warmup
        self.mean = None
        self.var = 1.0
        self.count = 0
        self.events: List[dict] = []
        self._tick: Optional[float] = None

    def start_step(self):
        self._tick = time.monotonic()

    def end_step(self, step: int) -> Optional[dict]:
        dt = time.monotonic() - self._tick if self._tick else 0.0
        self._tick = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> Optional[dict]:
        """Feed one step duration directly (testing / simulation)."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return None
        a = self.alpha
        prev_mean, prev_var = self.mean, self.var
        self.mean = a * dt + (1 - a) * self.mean
        self.var = a * (dt - self.mean) ** 2 + (1 - a) * self.var
        if self.count <= self.warmup:
            return None
        zscore = (dt - prev_mean) / math.sqrt(max(prev_var, 1e-12))
        if dt > self.hang_factor * prev_mean and self.count > self.warmup:
            ev = {"step": step, "kind": "hang", "dt": dt, "z": zscore}
        elif zscore > self.z:
            ev = {"step": step, "kind": "straggler", "dt": dt, "z": zscore}
        else:
            return None
        self.events.append(ev)
        return ev

    def deadline(self) -> float:
        """Suggested per-step timeout for the runner."""
        base = self.mean if self.mean else 60.0
        return max(self.hang_factor * base, 30.0)
