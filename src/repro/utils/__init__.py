"""Package."""
