"""Optimized-HLO introspection: collective inventory + roofline terms.

``cost_analysis()`` has FLOPs and HBM bytes but no collective traffic -- and
(verified empirically, see EXPERIMENTS.md Sec. Dry-run) XLA's cost analysis
counts a while/scan body ONCE, not times its trip count.  Collectives inside
the scan-over-blocks would therefore be undercounted by n_blocks.  This parser
fixes that:

  1. split the module into computations,
  2. find every while op, resolve its trip count from the constant operand of
     the compare in its condition computation,
  3. propagate multipliers through the call graph (body=, calls=, to_apply=,
     branch_computations=),
  4. weight each collective's wire bytes by its computation's multiplier.

Wire bytes per device per op (ring algorithms, group size g):

    all-reduce       2 * R * (g-1)/g
    all-gather           R * (g-1)/g      (R = gathered result)
    reduce-scatter       R * (g-1)        (R = scattered shard)
    all-to-all           R * (g-1)/g
    collective-permute   R
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = [
    "parse_collectives", "collective_wire_bytes", "roofline_terms", "HW",
    "split_computations", "while_trip_counts",
]

# TPU v5e constants (per spec)
HW = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\][^=]*?\s(all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(",
)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(",
)
_GROUP_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# headers sit at column 0: ``%name (args...) -> type {`` (args may nest parens)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(r"while\(.*?\)\s*,\s*condition=(%?[\w\.\-]+)\s*,\s*body=(%?[\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=(%?[\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"(%?[\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\)")


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Map computation name -> its lines (headers at column 0, ``-> ... {``)."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        is_hdr = (
            line and not line[0].isspace() and "->" in line
            and line.rstrip().endswith("{")
        )
        m = _COMP_HDR_RE.match(line.strip()) if is_hdr else None
        if m:
            current = m.group(1).lstrip("%")
            comps[current] = []
        elif current is not None:
            if line.startswith("}"):
                current = None
            else:
                comps[current].append(line)
    return comps


def _entry_name(hlo_text: str) -> str:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line[len("ENTRY"):].strip())
            if m:
                return m.group(1).lstrip("%")
    return ""


def while_trip_counts(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """body-computation name -> trip count (best-effort; default 1)."""
    trips: Dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1).lstrip("%"), m.group(2).lstrip("%")
            trips[body] = max(trips.get(body, 1), _trip_from_cond(comps.get(cond, [])))
    return trips


def _trip_from_cond(cond_lines: List[str]) -> int:
    consts = dict(
        (m.group(1).lstrip("%"), int(m.group(2)))
        for line in cond_lines for m in _CONST_RE.finditer(line)
    )
    best = 1
    for line in cond_lines:
        m = _COMPARE_RE.search(line)
        if not m:
            continue
        for opn in re.findall(r"%([\w\.\-]+)", m.group(1)):
            if opn in consts:
                best = max(best, consts[opn])
    if best == 1 and consts:
        best = max(consts.values())
    return best


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    trips = while_trip_counts(comps)
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name]:
            is_while = _WHILE_RE.search(line)
            callees = [c.lstrip("%") for c in _CALL_RE.findall(line)]
            bm = _BRANCH_RE.search(line)
            if bm:
                callees += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
            for c in callees:
                if c == name:
                    continue
                factor = trips.get(c, 1) if is_while else 1
                visit(c, m * factor)

    visit(entry, 1.0)
    return mult


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUP_ITOA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _line_collective(line: str):
    if not any(op in line for op in _COLL_OPS):
        return None
    if "-done" in line:  # async pair: count the -start only
        return None
    m = _COLL_RE.search(line)
    if m:
        return {"op": m.group(3), "result_bytes": _shape_bytes(m.group(1), m.group(2)),
                "group": _group_size(line)}
    m = _TUPLE_COLL_RE.search(line)
    if m:
        rbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
        return {"op": m.group(2), "result_bytes": rbytes, "group": _group_size(line)}
    return None


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Collectives with while-trip multipliers applied (``count`` may be >1)."""
    comps = split_computations(hlo_text)
    entry = _entry_name(hlo_text)
    mult = _multipliers(comps, entry) if entry else {}
    out: List[Dict] = []
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            c = _line_collective(line)
            if c:
                c["count"] = m
                out.append(c)
    if not out:  # fallback: flat scan (shouldn't happen)
        for line in hlo_text.splitlines():
            c = _line_collective(line)
            if c:
                c["count"] = 1.0
                out.append(c)
    return out


def collective_wire_bytes(colls: List[Dict]) -> float:
    total = 0.0
    for c in colls:
        r, g = c["result_bytes"], max(c["group"], 1)
        n = c.get("count", 1.0)
        if c["op"] == "all-reduce":
            total += n * 2.0 * r * (g - 1) / g
        elif c["op"] == "all-gather":
            total += n * r * (g - 1) / g
        elif c["op"] == "reduce-scatter":
            total += n * r * (g - 1)
        elif c["op"] == "all-to-all":
            total += n * r * (g - 1) / g
        elif c["op"] == "collective-permute":
            total += n * r
    return total


def roofline_terms(
    flops_per_dev: float, hbm_bytes_per_dev: float, wire_bytes_per_dev: float,
) -> Dict[str, float]:
    """Three roofline terms in seconds (all inputs per device)."""
    compute_s = flops_per_dev / HW["peak_flops"]
    memory_s = hbm_bytes_per_dev / HW["hbm_bw"]
    collective_s = wire_bytes_per_dev / HW["ici_bw"]
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
