"""Version-compat shims for JAX APIs that were renamed across releases.

The kernels and launchers in this repo target the *current* Pallas/sharding
API surface (``pltpu.MemorySpace``, ``pltpu.CompilerParams``,
``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map``); the pinned
toolchain in this container ships jax 0.4.37, where those names are still
``pltpu.TPUMemorySpace`` / ``pltpu.TPUCompilerParams``, ``dimension_semantics``
takes the string literals ``'parallel'``/``'arbitrary'`` instead of the
``GridDimensionSemantics`` enum, ``make_mesh`` has no ``axis_types`` kwarg,
and ``shard_map`` lives in ``jax.experimental`` with a ``check_rep`` flag.

Everything is resolved by feature detection (never version string parsing),
so the same source runs on both sides of each rename:

This table is also the single source of truth for the ``SL001`` lint
(``python -m repro.analysis``): every ````-quoted name or ``kwarg=`` token
between the table rules below is banned outside this module.  Adding a shim
here (with its table row) is how the banned list grows.

======================  ==============================  ========================
concept                 version-sensitive spelling      routed through
======================  ==============================  ========================
TPU memory spaces       ``pltpu.TPUMemorySpace``        ``MemorySpace``
                        ``pltpu.MemorySpace``           ``MemorySpace``
VMEM scratch shapes     ``pltpu.VMEM``                  ``VMEM``
compiler params         ``pltpu.TPUCompilerParams``     ``CompilerParams``
                        ``pltpu.CompilerParams``        ``CompilerParams``
dimension semantics     ``dimension_semantics=``        ``tpu_compiler_params``
                        ``GridDimensionSemantics``      ``dimension_semantics``
mesh construction       ``jax.make_mesh``               ``make_mesh``
mesh axis types         ``axis_types=``                 ``make_mesh``
shard_map               ``jax.experimental.shard_map``  ``shard_map``
                        ``jax.shard_map``               ``shard_map``
replication check       ``check_rep=``                  ``shard_map``
                        ``check_vma=``                  ``shard_map``
profiler annotations    ``jax.profiler.TraceAnnotation``  ``trace_annotation``
                        ``jax.profiler.TraceContext``   ``trace_annotation``
======================  ==============================  ========================
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "MemorySpace",
    "VMEM",
    "CompilerParams",
    "dimension_semantics",
    "tpu_compiler_params",
    "make_mesh",
    "shard_map",
    "HAS_AXIS_TYPES",
    "trace_annotation",
]

# --- Pallas TPU memory spaces ------------------------------------------------
# pltpu.TPUMemorySpace (enum: ANY/SMEM/VMEM/CMEM/SEMAPHORE) was renamed to
# pltpu.MemorySpace; members are identical.
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# pltpu.VMEM (the scratch-shape constructor) predates the enum rename and may
# disappear in favor of the enum member; prefer the module constant while it
# exists, fall back to the enum.
VMEM = getattr(pltpu, "VMEM", None)
if VMEM is None:  # pragma: no cover -- future-API path
    VMEM = MemorySpace.VMEM

# --- Pallas TPU compiler params ---------------------------------------------
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# GridDimensionSemantics is an enum-like namespace on new JAX; old JAX wants
# the literal strings 'parallel' / 'arbitrary' (it also exposes module-level
# pltpu.PARALLEL / pltpu.ARBITRARY sentinels, but the dataclass is typed for
# the strings, so strings are the safe denominator there).
_GDS = getattr(pltpu, "GridDimensionSemantics", None)


def dimension_semantics(*kinds: str) -> tuple:
    """Map ``'parallel'``/``'arbitrary'`` strings onto the installed API.

    Usage::

        compiler_params=tpu_compiler_params("parallel", "arbitrary")
    """
    for k in kinds:
        if k not in ("parallel", "arbitrary"):
            raise ValueError(f"unknown dimension semantic {k!r}")
    if _GDS is not None and hasattr(_GDS, "PARALLEL"):
        table = {"parallel": _GDS.PARALLEL, "arbitrary": _GDS.ARBITRARY}
        return tuple(table[k] for k in kinds)
    return tuple(kinds)


def tpu_compiler_params(*kinds: str, **kwargs: Any):
    """``CompilerParams`` with version-appropriate ``dimension_semantics``."""
    return CompilerParams(dimension_semantics=dimension_semantics(*kinds), **kwargs)


# --- Mesh construction -------------------------------------------------------
_MAKE_MESH_PARAMS = inspect.signature(jax.make_mesh).parameters
HAS_AXIS_TYPES = "axis_types" in _MAKE_MESH_PARAMS and hasattr(
    jax.sharding, "AxisType"
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence[Any]] = None,
):
    """``jax.make_mesh`` that requests Auto axis types where supported.

    On new JAX every axis is created as ``AxisType.Auto`` (the repo never uses
    Explicit axes); on old JAX the kwarg simply does not exist and Auto is the
    only behavior anyway.
    """
    kwargs: dict = {"devices": devices}
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# --- shard_map ---------------------------------------------------------------
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # moved out of jax.experimental after 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = inspect.signature(_shard_map_impl).parameters


def shard_map(
    f,
    mesh,
    in_specs,
    out_specs,
    *,
    check_replication: bool = False,
    axis_names: Optional[frozenset] = None,
):
    """Uniform ``shard_map`` across the ``check_rep`` -> ``check_vma`` rename.

    ``check_replication=False`` (the default) disables the out-spec
    replication check under whichever flag name the installed JAX uses --
    the fleet runtime emits psum-reduced telemetry whose replication the
    old checker cannot always prove.

    ``axis_names`` (new-API spelling): the subset of mesh axes the body is
    manual over.  Old JAX expresses the same thing inverted, as
    ``auto=<the other axes>``.
    """
    kwargs: dict = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SM_PARAMS:
        kwargs["check_vma"] = check_replication
    elif "check_rep" in _SM_PARAMS:
        kwargs["check_rep"] = check_replication
    if axis_names is not None:
        if "axis_names" in _SM_PARAMS:
            kwargs["axis_names"] = frozenset(axis_names)
        else:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_impl(f, **kwargs)


# --- profiler trace annotations ----------------------------------------------
# jax.profiler.TraceAnnotation is the current spelling of the scoped
# device-profile annotation; older releases only had TraceContext (and very
# old ones neither).  The observability layer (repro.obs) routes through this
# name so serving-loop spans can also land inside XLA device profiles.
_trace_ann = getattr(jax.profiler, "TraceAnnotation", None)
if _trace_ann is None:  # pragma: no cover -- old-API path
    _trace_ann = getattr(jax.profiler, "TraceContext", None)

if _trace_ann is not None:
    trace_annotation = _trace_ann
else:  # pragma: no cover -- profiler-less build
    from contextlib import nullcontext as _nullcontext

    def trace_annotation(name: str, **kwargs: Any):
        """No-op stand-in when the installed jax has no profiler annotations."""
        return _nullcontext()
