"""Analytic FLOP / HBM-byte model per (arch x shape) cell.

Why analytic: XLA's ``cost_analysis()`` counts a while/scan body once, not
times its trip count (verified -- see EXPERIMENTS.md), so models that scan
over superblocks would be undercounted by ~n_blocks.  The matmul FLOPs below
are exact per layer; pointwise work is ignored (<2% for these shapes).  The
HBM model is a documented approximation: weight traffic (per model-axis
shard), optimizer state traffic, and major activation operand traffic at bf16,
with the standard full-remat multiplier.

Conventions:
  * fwd FLOPs = 2 * MACs; train executes fwd + bwd (2x fwd) + remat re-fwd
    => executed = 4x fwd.  MODEL_FLOPS uses the 6*N*D convention (no remat),
    so useful_ratio ~ 6/8 = 0.75 is the expected remat tax for dense archs.
  * decode counts one token against a cache of ``seq_len``.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import SHAPES, ModelConfig

__all__ = ["cell_flops", "cell_hbm_bytes", "analytic_cell"]


def _attn_flops_tok(cfg: ModelConfig, attn_type: str, ctx: float) -> float:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * (h + 2 * kv) * hd + 2 * d * h * hd
    sdpa = 2 * 2 * ctx * h * hd
    return proj + sdpa


def _mlp_flops_tok(cfg: ModelConfig) -> float:
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    return (6 if gated else 4) * cfg.d_model * cfg.d_ff


def _moe_flops_tok(cfg: ModelConfig) -> float:
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    expert = (6 if gated else 4) * cfg.d_model * cfg.d_ff
    router = 2 * cfg.d_model * cfg.n_experts
    dispatch = 4 * cfg.capacity_factor * cfg.top_k * cfg.d_model
    return router + cfg.top_k * expert + dispatch


def _mamba_flops_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    dtr = max(d // 16, 1)
    return (
        2 * d * 2 * di              # in_proj
        + 2 * cfg.ssm_conv * di     # depthwise conv
        + 2 * di * (dtr + 2 * st)   # x_proj
        + 2 * dtr * di              # dt_proj
        + 10 * di * st              # selective scan update + C.h
        + 2 * di * d                # out_proj
    )


def _mlstm_flops_tok(cfg: ModelConfig, ctx: float) -> float:
    d = cfg.d_model
    di = 2 * d
    return (
        2 * d * 2 * di              # up
        + 2 * 4 * di                # conv
        + 3 * 2 * di * di           # q, k, v
        + 2 * di * 2 * cfg.n_heads  # gates
        + 2 * 2 * ctx * di          # quadratic form (scores + weighted V)
        + 2 * di * d                # down
    )


def _slstm_flops_tok(cfg: ModelConfig) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    pf = (4 * d + 2) // 3
    return (
        2 * d * 4 * d               # wx
        + 2 * cfg.n_heads * hd * 4 * hd  # block-diag recurrence
        + 2 * d * 2 * pf + 2 * pf * d    # GeGLU FFN
    )


def _layer_flops_tok(cfg: ModelConfig, spec, ctx: float, cross_ctx: float = 0.0) -> float:
    if spec.kind == "attn":
        f = _attn_flops_tok(cfg, spec.attn_type, ctx if spec.attn_type != "local"
                            else min(ctx, cfg.window))
        if cfg.cross_attention and cross_ctx:
            f += 2 * cfg.d_model * cfg.n_heads * cfg.head_dim  # q proj
            f += 2 * 2 * cross_ctx * cfg.n_heads * cfg.head_dim
    elif spec.kind == "mamba":
        f = _mamba_flops_tok(cfg)
    elif spec.kind == "mlstm":
        f = _mlstm_flops_tok(cfg, ctx)
    elif spec.kind == "slstm":
        f = _slstm_flops_tok(cfg)
    else:
        raise ValueError(spec.kind)
    if spec.has_mlp:
        f += _moe_flops_tok(cfg) if spec.moe else _mlp_flops_tok(cfg)
    return f


def _stack_flops_tok(cfg: ModelConfig, ctx: float, cross_ctx: float) -> float:
    per_block = sum(
        _layer_flops_tok(cfg, s, ctx, cross_ctx) for s in cfg.block_pattern
    )
    tail = sum(_layer_flops_tok(cfg, s, ctx, cross_ctx) for s in cfg.tail_pattern)
    return per_block * cfg.n_blocks + tail


def cell_flops(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    """Executed + model FLOPs (totals across all chips)."""
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    unembed = 2 * cfg.d_model * cfg.vocab

    if shape.step == "decode":
        ctx = float(s)
        tokens = float(b)  # one new token per sequence
        fwd = tokens * (_stack_flops_tok(cfg, ctx, cfg.num_prefix_embeds) + unembed)
        executed = fwd
        model = 2.0 * _active_params(cfg) * tokens
    else:
        ctx = (s + 1) / 2.0  # causal average context
        tokens = float(b * s)
        fwd = tokens * (_stack_flops_tok(cfg, ctx, cfg.num_prefix_embeds) + unembed)
        if cfg.enc_blocks:
            enc_tokens = float(b * cfg.num_prefix_embeds)
            enc_fwd = enc_tokens * cfg.enc_blocks * _layer_flops_tok(
                cfg, cfg.block_pattern[0].__class__(kind="attn"),
                cfg.num_prefix_embeds,
            )
            fwd += enc_fwd
        if shape.step == "train":
            executed = 4.0 * fwd   # fwd + 2x bwd + remat re-fwd
            model = 6.0 * _active_params(cfg) * tokens
        else:  # prefill
            executed = fwd
            model = 2.0 * _active_params(cfg) * tokens
    return {"fwd": fwd, "executed": executed, "model": model}


def _active_params(cfg: ModelConfig) -> int:
    from repro.models.params import count_params

    return count_params(cfg, active_only=True)


def _total_params(cfg: ModelConfig) -> int:
    from repro.models.params import count_params

    return count_params(cfg)


def cell_hbm_bytes(cfg: ModelConfig, shape_name: str, n_chips: int,
                   model_shards: int = 16) -> float:
    """Approximate per-device HBM traffic for one step (documented model).

    weights: each device streams its 1/model_shards slice of all params for
    fwd, bwd and the remat re-fwd (FSDP gathers cross the interconnect, not
    HBM, but the gathered tiles are read from HBM once per use).
    optimizer: AdamW moments read+write (f32/bf16 per config) + param update.
    activations: ~12 * d bytes/token/layer at bf16 (block in/out, norms, qkv,
    mlp operands), divided across batch shards; x3 for train passes.
    """
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    n_params = _total_params(cfg)
    p_bytes = 2  # bf16
    w_slice = n_params * p_bytes / model_shards

    if shape.step == "decode":
        tokens_dev = max(b / (n_chips / model_shards), 1)
        act = 12 * cfg.d_model * 2 * tokens_dev * cfg.n_layers
        cache = _decode_cache_bytes(cfg, b, s) / n_chips
        return w_slice + act + cache

    tokens_dev = b * s / (n_chips / model_shards)
    passes = 3 if shape.step == "train" else 1
    weights = w_slice * (3 if shape.step == "train" else 1)
    opt = 0.0
    if shape.step == "train":
        m_bytes = 4 if n_params < 3e10 else 2
        opt = n_params / n_chips * (4 * m_bytes + 2 * 2 + 4)  # m,v rw + p rw + g
    act = 12 * cfg.d_model * 2 * tokens_dev * cfg.n_layers * passes / model_shards
    return weights + opt + act


def _decode_cache_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    total = 0.0
    for spec in list(cfg.block_pattern) * cfg.n_blocks + list(cfg.tail_pattern):
        if spec.kind == "attn":
            c = min(s, cfg.window) if spec.attn_type == "local" else s
            total += 2 * b * c * cfg.n_kv_heads * cfg.head_dim * 2
        elif spec.kind == "mamba":
            total += b * cfg.ssm_expand * cfg.d_model * cfg.ssm_state * 4
        elif spec.kind == "mlstm":
            di = 2 * cfg.d_model
            total += b * cfg.n_heads * (di // cfg.n_heads) ** 2 * 4
        elif spec.kind == "slstm":
            total += 4 * b * cfg.d_model * 4
    return total


def analytic_cell(cfg: ModelConfig, shape_name: str, n_chips: int,
                  model_shards: int = 16) -> Dict[str, float]:
    fl = cell_flops(cfg, shape_name)
    hbm = cell_hbm_bytes(cfg, shape_name, n_chips, model_shards)
    return {
        "flops_per_dev": fl["executed"] / n_chips,
        "model_flops": fl["model"],
        "fwd_flops": fl["fwd"],
        "hbm_bytes_per_dev": hbm,
    }
