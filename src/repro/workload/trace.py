"""``workload_trace/v1``: the arrival-trace schema the replay engine drives.

A trace is a totally ordered list of per-tick session events --
``(t_ms, session_id, kind, window_ref)`` -- plus a header binding them to
a deterministic synthetic source fleet (``repro.data.synthetic.make_fleet``
rows).  ``window_ref`` indexes the owning stream row's consecutive
``window``-point slices, so a trace is *self-contained*: the same
``(trace, seed)`` pair reproduces the same bytes on the wire anywhere.

Event kinds:

    ``open``   session arrives (allocates a slot / OPEN frame)
    ``data``   session delivers source window ``window_ref``
    ``close``  session ends cleanly (flush tail / CLOSE frame)

On-disk form is jsonl: a header line (schema, name, seed, fleet shape,
per-session metadata) followed by one compact line per event.  The
canonical serialization also backs :meth:`Trace.digest`, the identity the
reorder-invariance and determinism batteries compare.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterator, List, Tuple

SCHEMA = "workload_trace/v1"
KINDS = ("open", "data", "close")

#: trace clock quantum the synthesizers emit on (one service tick)
TICK_MS = 10


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One scheduled session event; ``window_ref`` is -1 for open/close."""
    t_ms: int
    sid: str
    kind: str
    window_ref: int = -1


@dataclasses.dataclass
class Trace:
    """An arrival trace plus the synthetic-source binding that replays it.

    ``sessions`` maps every sid to ``{"stream": row, "mode": "raw"|"pieces"}``:
    the ``make_fleet(n_streams, length, seed)`` row the session reads and
    the transport mode its sender uses.  Several sids may share one stream
    row (reconnect churn resumes the row under a fresh sid).
    """
    name: str
    seed: int
    n_streams: int
    length: int
    window: int
    events: List[TraceEvent]
    sessions: Dict[str, dict]
    service_every_ms: int = TICK_MS

    # ------------------------------------------------------------- views

    @property
    def n_windows(self) -> int:
        """Source windows per stream row (last one may be partial)."""
        return -(-self.length // self.window)

    def ticks(self) -> Iterator[Tuple[int, List[TraceEvent]]]:
        """Yield ``(t_ms, events)`` groups in trace order."""
        group: List[TraceEvent] = []
        t = None
        for ev in self.events:
            if t is not None and ev.t_ms != t:
                yield t, group
                group = []
            t = ev.t_ms
            group.append(ev)
        if group:
            yield t, group

    def schedule(self) -> List[List[Tuple[int, int]]]:
        """Per-tick ``(stream row, window_ref)`` data arrivals.

        The exact shape ``launch.stream``'s retired ``_arrival_schedule``
        generator yielded -- the shim-equivalence battery compares against
        a frozen copy of it.
        """
        out = []
        for _, evs in self.ticks():
            tick = [(self.sessions[ev.sid]["stream"], ev.window_ref)
                    for ev in evs if ev.kind == "data"]
            if tick:
                out.append(tick)
        return out

    def counts(self) -> Dict[str, int]:
        """Event totals (the schedule-determined half of a bench row)."""
        data = sum(1 for ev in self.events if ev.kind == "data")
        return {
            "events": len(self.events),
            "windows": data,
            "sessions": len(self.sessions),
        }

    # ------------------------------------------------------- serialization

    def header(self) -> dict:
        return {
            "schema": SCHEMA,
            "name": self.name,
            "seed": self.seed,
            "n_streams": self.n_streams,
            "length": self.length,
            "window": self.window,
            "service_every_ms": self.service_every_ms,
            "sessions": self.sessions,
        }

    def to_jsonl(self) -> str:
        lines = [json.dumps(self.header(), sort_keys=True,
                            separators=(",", ":"))]
        for ev in self.events:
            lines.append(json.dumps(
                {"t": ev.t_ms, "sid": ev.sid, "k": ev.kind,
                 "w": ev.window_ref},
                sort_keys=True, separators=(",", ":")))
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace file")
        head = json.loads(lines[0])
        if head.get("schema") != SCHEMA:
            raise ValueError(
                f"expected schema {SCHEMA!r}, got {head.get('schema')!r}")
        events = [
            TraceEvent(t_ms=int(d["t"]), sid=str(d["sid"]),
                       kind=str(d["k"]), window_ref=int(d["w"]))
            for d in map(json.loads, lines[1:])
        ]
        trace = cls(
            name=str(head["name"]), seed=int(head["seed"]),
            n_streams=int(head["n_streams"]), length=int(head["length"]),
            window=int(head["window"]), events=events,
            sessions={str(k): dict(v) for k, v in head["sessions"].items()},
            service_every_ms=int(head["service_every_ms"]),
        )
        trace.validate()
        return trace

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_jsonl(f.read())

    def digest(self) -> str:
        """sha256 over the canonical jsonl -- the trace's identity."""
        return hashlib.sha256(self.to_jsonl().encode("utf-8")).hexdigest()

    # ---------------------------------------------------------- invariants

    def validate(self) -> None:
        """Raise ``ValueError`` on any schema violation."""
        if self.n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {self.n_streams}")
        if not 1 <= self.window <= self.length:
            raise ValueError(
                f"window {self.window} outside [1, length {self.length}]")
        if self.service_every_ms < 1:
            raise ValueError(
                f"service_every_ms must be >= 1, got {self.service_every_ms}")
        n_windows = self.n_windows
        opened: set = set()
        closed: set = set()
        last_ref: Dict[str, int] = {}
        prev_t = 0
        for i, ev in enumerate(self.events):
            if ev.kind not in KINDS:
                raise ValueError(f"event {i}: unknown kind {ev.kind!r}")
            if ev.t_ms < prev_t:
                raise ValueError(
                    f"event {i}: t_ms {ev.t_ms} goes backwards from {prev_t}")
            prev_t = ev.t_ms
            meta = self.sessions.get(ev.sid)
            if meta is None:
                raise ValueError(f"event {i}: sid {ev.sid!r} not in sessions")
            if ev.sid in closed:
                raise ValueError(f"event {i}: sid {ev.sid!r} already closed")
            if ev.kind == "open":
                if ev.sid in opened:
                    raise ValueError(f"event {i}: sid {ev.sid!r} reopened")
                opened.add(ev.sid)
            elif ev.sid not in opened:
                raise ValueError(
                    f"event {i}: {ev.kind} for unopened sid {ev.sid!r}")
            if ev.kind == "data":
                if not 0 <= ev.window_ref < n_windows:
                    raise ValueError(
                        f"event {i}: window_ref {ev.window_ref} outside "
                        f"[0, {n_windows})")
                if ev.window_ref <= last_ref.get(ev.sid, -1):
                    raise ValueError(
                        f"event {i}: sid {ev.sid!r} window_ref "
                        f"{ev.window_ref} not increasing")
                last_ref[ev.sid] = ev.window_ref
            if ev.kind == "close":
                closed.add(ev.sid)
        for sid, meta in self.sessions.items():
            if not 0 <= int(meta.get("stream", -1)) < self.n_streams:
                raise ValueError(
                    f"sid {sid!r}: stream row {meta.get('stream')} outside "
                    f"[0, {self.n_streams})")
            if meta.get("mode", "raw") not in ("raw", "pieces"):
                raise ValueError(
                    f"sid {sid!r}: mode must be raw|pieces, got "
                    f"{meta.get('mode')!r}")
            if sid not in opened:
                raise ValueError(f"sid {sid!r} declared but never opened")


class TraceBuilder:
    """Append-only event builder the synthesizers share.

    Events must be appended in nondecreasing ``t_ms`` order; ``build``
    validates the full invariant set.
    """

    def __init__(self, name: str, seed: int, n_streams: int, length: int,
                 window: int, service_every_ms: int = TICK_MS):
        self.name = name
        self.seed = seed
        self.n_streams = n_streams
        self.length = length
        self.window = window
        self.service_every_ms = service_every_ms
        self.events: List[TraceEvent] = []
        self.sessions: Dict[str, dict] = {}

    def open(self, t_ms: int, sid: str, stream: int,
             mode: str = "raw") -> None:
        self.sessions[sid] = {"stream": int(stream), "mode": mode}
        self.events.append(TraceEvent(int(t_ms), sid, "open"))

    def data(self, t_ms: int, sid: str, window_ref: int) -> None:
        self.events.append(
            TraceEvent(int(t_ms), sid, "data", int(window_ref)))

    def close(self, t_ms: int, sid: str) -> None:
        self.events.append(TraceEvent(int(t_ms), sid, "close"))

    def build(self) -> Trace:
        trace = Trace(
            name=self.name, seed=self.seed, n_streams=self.n_streams,
            length=self.length, window=self.window, events=self.events,
            sessions=self.sessions, service_every_ms=self.service_every_ms,
        )
        trace.validate()
        return trace
