"""Workload harness CLI: replay scenarios, assert SLOs, emit bench rows.

    PYTHONPATH=src python -m repro.workload --scenario flash_crowd \
        --slo p99_symbol_ms=50

Replays each ``--scenario`` (or a recorded ``--trace`` jsonl) through the
in-process ``StreamServer`` -- or the loopback TCP transport with
``--transport`` -- and checks the scenario's SLOs plus any ``--slo``
overrides against the measured quantiles.  Exit status: 0 clean, 1 on any
SLO violation, 3 if ``--runs N`` replays disagree bitwise, 2 on bad flags.

``--out BENCH_transport.json`` writes the machine-readable per-scenario
artifact (schema ``bench_transport/v1``) that ``benchmarks/check_bench.py
--transport-fresh`` gates against the committed baseline.
"""
from __future__ import annotations

import sys

from repro.launch.cli import prescan_host_devices

if __name__ == "__main__":  # pragma: no cover -- CLI path only
    # before any jax-importing module below (jax locks the device count)
    prescan_host_devices()

import argparse
import json
import time

from repro.launch.cli import (
    add_devices_arg, add_symed_args, validate_shared_args)
from repro.workload import (
    SCENARIOS, Trace, Workload, check_slos, parse_slo_specs, scenario_seed)

BENCH_SCHEMA = "bench_transport/v1"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.workload",
        description=__doc__.split("\n", 1)[0])
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME",
                    help="scenario to replay (repeatable; 'all' = the "
                         f"non-legacy zoo; have: "
                         f"{', '.join(sorted(SCENARIOS))})")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="replay a recorded workload_trace/v1 jsonl instead "
                         "of synthesizing")
    ap.add_argument("--dump-trace", default=None, metavar="FILE",
                    help="write the synthesized trace jsonl and exit "
                         "(single --scenario only)")
    ap.add_argument("--sessions", type=int, default=None,
                    help="override the scenario's session count")
    ap.add_argument("--length", type=int, default=None,
                    help="override the scenario's points per stream")
    ap.add_argument("--window", type=int, default=None,
                    help="override the scenario's arrival window")
    ap.add_argument("--slo", action="append", default=[],
                    metavar="KEY=LIMIT",
                    help="SLO threshold override (repeatable), e.g. "
                         "p99_symbol_ms=50")
    ap.add_argument("--no-slos", action="store_true",
                    help="measure only; skip the scenario's default SLO gate")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="pace drains against the trace clock at this "
                         "multiple of real time (0: unpaced)")
    ap.add_argument("--transport", action="store_true",
                    help="drive the loopback TCP transport tier instead of "
                         "the in-process server")
    ap.add_argument("--runs", type=int, default=1,
                    help="replay N times and require identical fingerprints "
                         "(delta bytes + counters)")
    ap.add_argument("--verify", action="store_true",
                    help="check every session's delta concatenation bitwise "
                         "against symed_encode")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help=f"write the {BENCH_SCHEMA} artifact here")
    add_devices_arg(ap)
    add_symed_args(ap)
    return ap


def _scenario_names(args) -> list:
    if args.scenario is None:
        return ["all"]
    return list(args.scenario)


def _resolve_scenarios(ap, args) -> list:
    names = []
    for name in _scenario_names(args):
        if name == "all":
            names.extend(n for n, sc in SCENARIOS.items() if not sc.legacy)
        elif name in SCENARIOS:
            names.append(name)
        else:
            ap.error(f"unknown scenario {name!r} "
                     f"(have: {', '.join(sorted(SCENARIOS))}, all)")
    return names


def _check_mesh_fit(name: str, server_kw: dict, devices: int) -> None:
    cap = int(server_kw.get("max_sessions", 8))
    lo = server_kw.get("min_slots")
    if cap % devices or (lo is not None and int(lo) % devices):
        raise SystemExit(
            f"scenario {name!r}: slot table (max_sessions={cap}, "
            f"min_slots={lo}) must divide over --devices {devices}")


def _run_scenario(name: str, trace, server_kw: dict, slos: dict, args,
                  cfg, mesh) -> tuple:
    """Replay (possibly repeatedly); returns (bench_row, violations, ok)."""
    from repro.workload.replay import replay_trace

    if mesh is not None:
        server_kw = {**server_kw, "mesh": mesh}
    results = []
    for _ in range(max(args.runs, 1)):
        results.append(replay_trace(
            trace, cfg=cfg, server_kw=server_kw, rate=args.rate,
            transport=args.transport, verify=args.verify))
    res = results[0]
    prints = set(r.fingerprint() for r in results)
    determinism = "n/a" if len(results) == 1 else (
        "OK" if len(prints) == 1 else "MISMATCH")
    measured = res.measured()
    violations = check_slos(measured, slos)
    for v in violations:
        print(f"slo_check scenario={name} {v.key}: "
              f"measured={v.measured:.3f} limit={v.limit:.3f} -> VIOLATION")
    for key, limit in sorted(slos.items()):
        if not any(v.key == key for v in violations):
            print(f"slo_check scenario={name} {key}: "
                  f"measured={measured.get(key, 0.0):.3f} "
                  f"limit={limit:.3f} -> ok")
    c = res.counters
    extra = f"verified={res.verified} " if args.verify else ""
    print("workload_summary "
          f"scenario={name} transport={int(args.transport)} "
          f"runs={len(results)} determinism={determinism} "
          f"delta_sha256={res.delta_sha256[:16]} "
          f"opened={int(c.get('opened', 0))} "
          f"closed={int(c.get('closed', 0))} "
          f"evicted={int(c.get('evicted', 0))} "
          f"points_in={int(c.get('points_in', 0))} "
          f"symbols_out={int(c.get('symbols_out', 0))} "
          f"grows={int(c.get('grows', 0))} "
          f"shrinks={int(c.get('shrinks', 0))} "
          f"queue_max={int(res.queue['max_depth'])} "
          f"queue_mean={res.queue['mean_depth']:.2f} "
          f"p50_ms={res.latency['p50_ms']:.3f} "
          f"p99_ms={res.latency['p99_ms']:.3f} "
          f"p999_ms={res.latency['p999_ms']:.3f} "
          f"wall_s={res.wall_seconds:.2f} "
          f"{extra}"
          f"violations={len(violations)}", flush=True)
    row = {
        "scenario": name,
        "seed": trace.seed,
        "transport": int(args.transport),
        "trace_digest": trace.digest(),
        **{k: int(v) for k, v in trace.counts().items()},
        "opened": int(c.get("opened", 0)),
        "closed": int(c.get("closed", 0)),
        "evicted": int(c.get("evicted", 0)),
        "evict_rate": res.evict_rate,
        "points_in": int(c.get("points_in", 0)),
        "symbols_out": int(c.get("symbols_out", 0)),
        "drains": int(res.queue["drains"]),
        "max_queue_depth": int(res.queue["max_depth"]),
        "mean_queue_depth": round(res.queue["mean_depth"], 4),
        "p50_symbol_ms": round(res.latency["p50_ms"], 4),
        "p99_symbol_ms": round(res.latency["p99_ms"], 4),
        "p999_symbol_ms": round(res.latency["p999_ms"], 4),
        "delta_sha256": res.delta_sha256,
        "slos": {k: float(v) for k, v in sorted(slos.items())},
        "violations": [str(v) for v in violations],
    }
    return row, violations, determinism


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    validate_shared_args(ap, args)
    if args.runs < 1:
        ap.error(f"--runs must be >= 1, got {args.runs}")
    if args.rate < 0:
        ap.error(f"--rate must be >= 0, got {args.rate}")
    try:
        parse_slo_specs(args.slo)
    except ValueError as e:
        ap.error(str(e))
    if args.trace is not None and args.scenario is not None:
        ap.error("--trace and --scenario are mutually exclusive")
    overrides = {k: getattr(args, k) for k in ("sessions", "length", "window")
                 if getattr(args, k) is not None}

    # (name, trace, server_kw, slos) per replay target
    targets = []
    if args.trace is not None:
        trace = Trace.load(args.trace)
        wl = (Workload(trace.name) if trace.name in SCENARIOS else None)
        server_kw = wl.server_kw() if wl else {"max_sessions": 8,
                                               "pretrace": True}
        slos = dict(wl.slos()) if (wl and not args.no_slos) else {}
        slos.update(parse_slo_specs(args.slo))
        targets.append((trace.name, trace, server_kw, slos))
    else:
        for name in _resolve_scenarios(ap, args):
            wl = Workload(name, seed=scenario_seed(name, args.seed),
                          **overrides)
            slos = {} if args.no_slos else dict(wl.slos())
            slos.update(parse_slo_specs(args.slo))
            targets.append((name, wl.trace(), wl.server_kw(), slos))

    if args.dump_trace is not None:
        if len(targets) != 1:
            ap.error("--dump-trace needs exactly one scenario")
        _, trace, _, _ = targets[0]
        trace.save(args.dump_trace)
        print(f"trace written           : {args.dump_trace} "
              f"({trace.counts()['events']} events, digest "
              f"{trace.digest()[:16]})")
        return 0

    for name, _, server_kw, _ in targets:
        _check_mesh_fit(name, server_kw, args.devices)

    import jax  # noqa: F401  (device count pinned by the prescan above)

    from repro.core.symed import SymEDConfig
    from repro.launch.fleet import fleet_data_mesh

    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    mesh = fleet_data_mesh() if args.devices > 1 else None

    rows = []
    n_violations = 0
    mismatch = False
    t0 = time.perf_counter()
    for name, trace, server_kw, slos in targets:
        sc = SCENARIOS.get(name)
        print(f"--- scenario {name}"
              + (f": {sc.description}" if sc else " (recorded trace)"),
              flush=True)
        row, violations, determinism = _run_scenario(
            name, trace, server_kw, slos, args, cfg, mesh)
        rows.append(row)
        n_violations += len(violations)
        mismatch = mismatch or determinism == "MISMATCH"

    if args.out:
        doc = {
            "schema": BENCH_SCHEMA,
            "generated_by": "python -m repro.workload",
            "config": {
                "tol": args.tol, "alpha": args.alpha, "seed": args.seed,
                "rate": args.rate, "devices": args.devices,
                "runs": args.runs, "transport": int(args.transport),
            },
            "rows": rows,
            "summary": {
                "scenarios": len(rows),
                "violations": n_violations,
                "wall_seconds": round(time.perf_counter() - t0, 2),
            },
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"bench artifact          : {args.out} "
              f"({len(rows)} scenario rows)")

    if mismatch:
        return 3
    return 1 if n_violations else 0


if __name__ == "__main__":
    sys.exit(main())
