"""Trace-driven workload harness: scenarios, replay, tail-latency SLOs.

The load half of the benchmarking story (``repro.obs`` is the measurement
half): seeded synthesizers build ``workload_trace/v1`` arrival traces for
a scenario zoo, a replay engine drives them -- bit-reproducibly -- through
the in-process ``StreamServer`` or the loopback TCP transport, and an SLO
layer turns the scraped quantiles into a pass/fail gate
(``BENCH_transport.json`` in CI).

    PYTHONPATH=src python -m repro.workload --scenario flash_crowd \
        --slo p99_symbol_ms=50

Import layering: this module (trace schema, scenarios, SLOs) is
numpy-only, so the CLI can pin the forced host device count before jax
loads.  The replay engine pulls in jax; import it as
``repro.workload.replay`` or touch the lazily-forwarded names below.
"""
from repro.workload.scenarios import (
    SCENARIOS, Scenario, Workload, legacy_arrival_schedule, scenario_seed,
    synthesize,
)
from repro.workload.slo import (
    KNOWN_SLOS, SLOViolation, check_slos, parse_slo, parse_slo_specs,
)
from repro.workload.trace import SCHEMA, TICK_MS, Trace, TraceBuilder, TraceEvent

__all__ = [
    "SCHEMA", "TICK_MS", "Trace", "TraceBuilder", "TraceEvent",
    "SCENARIOS", "Scenario", "Workload", "legacy_arrival_schedule",
    "scenario_seed", "synthesize",
    "KNOWN_SLOS", "SLOViolation", "check_slos", "parse_slo",
    "parse_slo_specs",
    "ReplayResult", "replay_trace",
]

_LAZY = {"ReplayResult", "replay_trace"}


def __getattr__(name):
    # replay drags in jax; keep it out of the pre-device-pinning import path
    if name in _LAZY:
        from repro.workload import replay as _replay
        return getattr(_replay, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
