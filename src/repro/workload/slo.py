"""Declarative SLO thresholds checked against replay measurements.

An SLO set is a flat ``{key: limit}`` mapping; every key is an upper bound
on one measurement the replay engine reports (scraped from the
``repro.obs`` registry plus the engine's queue accounting).  ``check_slos``
returns the violations, so "gate this scenario" is::

    violations = check_slos(result.measured(), slos)
    sys.exit(1 if violations else 0)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence

__all__ = ["KNOWN_SLOS", "SLOViolation", "parse_slo", "parse_slo_specs",
           "check_slos"]

#: key -> human description; every SLO is an upper bound on the same-named
#: measurement in ``ReplayResult.measured()``
KNOWN_SLOS: Dict[str, str] = {
    "p50_symbol_ms": "median arrival->delta-frame latency per symbol (ms)",
    "p99_symbol_ms": "99th-percentile per-symbol latency (ms)",
    "p999_symbol_ms": "99.9th-percentile per-symbol latency (ms)",
    "max_queue_depth": "max windows staged at any service drain",
    "mean_queue_depth": "mean windows staged per service drain",
    "evict_rate": "LRU evictions / sessions opened",
}


@dataclasses.dataclass(frozen=True)
class SLOViolation:
    key: str
    limit: float
    measured: float

    def __str__(self) -> str:
        return (f"{self.key}: measured={self.measured:.3f} "
                f"limit={self.limit:.3f}")


def parse_slo(spec: str) -> tuple:
    """Parse one ``key=limit`` CLI spec into ``(key, float(limit))``."""
    key, sep, raw = spec.partition("=")
    key = key.strip()
    if not sep or not raw.strip():
        raise ValueError(f"SLO spec must be key=limit, got {spec!r}")
    if key not in KNOWN_SLOS:
        raise ValueError(
            f"unknown SLO {key!r} (have: {', '.join(sorted(KNOWN_SLOS))})")
    try:
        limit = float(raw)
    except ValueError:
        raise ValueError(f"SLO limit must be a number, got {spec!r}")
    return key, limit


def parse_slo_specs(specs: Sequence[str]) -> Dict[str, float]:
    """Parse repeated ``--slo key=limit`` flags (later specs win)."""
    out: Dict[str, float] = {}
    for spec in specs:
        key, limit = parse_slo(spec)
        out[key] = limit
    return out


def check_slos(measured: Mapping[str, float],
               slos: Mapping[str, float]) -> List[SLOViolation]:
    """Upper-bound every declared SLO against ``measured``.

    A declared SLO whose measurement is missing is itself a violation
    (measured as NaN): silently passing an unmeasurable threshold would
    make the gate decorative.
    """
    out: List[SLOViolation] = []
    for key, limit in sorted(slos.items()):
        got = measured.get(key)
        if got is None:
            out.append(SLOViolation(key, float(limit), float("nan")))
        elif float(got) > float(limit):
            out.append(SLOViolation(key, float(limit), float(got)))
    return out
