"""Seeded trace synthesizers: the workload scenario zoo.

Every synthesizer is a pure function of its keyword parameters -- a fresh
``np.random.default_rng(seed)`` per call, no module state -- so synthesis
order can never change a trace (``scenario_seed`` derives independent
per-scenario seeds from one base, the same crc32 mix the transport uses
for per-session digitizer seeds).

The zoo (``SCENARIOS``):

    ``diurnal``        sinusoidal arrival intensity (day/night load)
    ``flash_crowd``    a quiet baseline fleet, then a cohort arriving at once
    ``dropout_churn``  sensors dropping mid-stream and reconnecting as new
                       sessions that resume the same source row
    ``mixed_fleet``    raw-mode and pieces-mode senders sharing one table
    ``slot_churn``     adversarial short-lived session waves sized past the
                       slot table, forcing autoscale thrash + LRU eviction

plus the three legacy ``--arrival-pattern`` shapes (``roundrobin``,
``random``, ``bursty``) as shims: :func:`legacy_arrival_schedule` is the
verbatim port of ``launch.stream._arrival_schedule``, so a legacy trace's
``schedule()`` is tick-for-tick what the retired generator yielded for the
same seed (pinned by the shim-equivalence battery).
"""
from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Callable, Dict, Mapping, Optional

import numpy as np

from repro.workload.trace import TICK_MS, Trace, TraceBuilder

__all__ = [
    "Scenario", "SCENARIOS", "Workload", "scenario_seed", "synthesize",
    "legacy_arrival_schedule",
]


def scenario_seed(name: str, base_seed: int = 0) -> int:
    """Deterministic per-scenario seed (same mix as transport sessions)."""
    return (zlib.crc32(name.encode("utf-8")) ^ base_seed) & 0xFFFFFFFF


# ------------------------------------------------------------ legacy shims


def legacy_arrival_schedule(pattern: str, n_sessions: int, n_windows: int,
                            rng):
    """Yield per-tick lists of (session index, window index) arrivals.

    Verbatim port of the retired ``launch.stream._arrival_schedule`` --
    the rng call sequence is the contract (same seed => same schedule), so
    this function must not be "improved".
    """
    cursors = [0] * n_sessions
    if pattern == "roundrobin":
        while any(c < n_windows for c in cursors):
            tick = [(s, cursors[s]) for s in range(n_sessions)
                    if cursors[s] < n_windows]
            for s, _ in tick:
                cursors[s] += 1
            yield tick
    elif pattern == "random":
        while any(c < n_windows for c in cursors):
            live = [s for s in range(n_sessions) if cursors[s] < n_windows]
            pick = [s for s in live if rng.random() < 0.6] or live[:1]
            tick = [(s, cursors[s]) for s in pick]
            for s, _ in tick:
                cursors[s] += 1
            yield tick
    elif pattern == "bursty":
        s = 0
        while any(c < n_windows for c in cursors):
            live = [i for i in range(n_sessions) if cursors[i] < n_windows]
            s = live[s % len(live)]
            burst = min(int(rng.integers(1, 4)), n_windows - cursors[s])
            for _ in range(burst):
                yield [(s, cursors[s])]
                cursors[s] += 1
            s += 1
    else:
        raise ValueError(f"unknown arrival pattern {pattern!r}")


def _synth_legacy(pattern: str):
    def synth(*, sessions: int, length: int, window: int, seed: int,
              tick_ms: int = TICK_MS) -> Trace:
        rng = np.random.default_rng(seed)
        n_windows = -(-length // window)
        b = TraceBuilder(pattern, seed, sessions, length, window)
        opened: set = set()
        for t, tick in enumerate(legacy_arrival_schedule(
                pattern, sessions, n_windows, rng)):
            t_ms = t * tick_ms
            for s, w in tick:
                sid = f"stream-{s}"
                if s not in opened:
                    b.open(t_ms, sid, s)
                    opened.add(s)
                b.data(t_ms, sid, w)
                if w == n_windows - 1:
                    b.close(t_ms, sid)
        return b.build()
    return synth


# ------------------------------------------------------------ scenario zoo


def _synth_diurnal(*, sessions: int, length: int, window: int, seed: int,
                   tick_ms: int = TICK_MS, period: int = 16,
                   floor: float = 0.15) -> Trace:
    """Sinusoidal arrival intensity: every stream delivers its next window
    with a probability that swings from ``floor`` (night) toward 1 (noon)."""
    rng = np.random.default_rng(seed)
    n_windows = -(-length // window)
    b = TraceBuilder("diurnal", seed, sessions, length, window)
    cursors = [0] * sessions
    t = 0
    while any(c < n_windows for c in cursors):
        phase = 0.5 - 0.5 * np.cos(2.0 * np.pi * t / period)
        p = floor + (1.0 - floor) * phase
        t_ms = t * tick_ms
        if t < sessions:  # staggered dawn arrival for stream t
            b.open(t_ms, f"stream-{t}", t)
        for s in range(sessions):
            if cursors[s] >= n_windows or t < s:  # not yet dawned
                continue
            if rng.random() < p or t > 50 * n_windows:  # force-drain tail
                sid = f"stream-{s}"
                b.data(t_ms, sid, cursors[s])
                cursors[s] += 1
                if cursors[s] == n_windows:
                    b.close(t_ms, sid)
        t += 1
    return b.build()


def _synth_flash_crowd(*, sessions: int, length: int, window: int, seed: int,
                       tick_ms: int = TICK_MS, baseline: Optional[int] = None,
                       spike_tick: int = 6) -> Trace:
    """A small steady fleet, then the rest of the crowd lands in one tick."""
    rng = np.random.default_rng(seed)
    n_windows = -(-length // window)
    base = max(1, sessions // 4) if baseline is None else baseline
    base = min(base, sessions)
    b = TraceBuilder("flash_crowd", seed, sessions, length, window)
    cursors = [0] * sessions
    started = [0 if s < base else None for s in range(sessions)]
    for s in range(base):
        b.open(0, f"stream-{s}", s)
    t = 0
    while any(c < n_windows for c in cursors):
        t_ms = t * tick_ms
        if t == spike_tick:
            # arrival order inside the spike is part of the workload: a
            # seeded shuffle, not index order
            for s in rng.permutation(np.arange(base, sessions)):
                b.open(t_ms, f"stream-{int(s)}", int(s))
                started[int(s)] = t
        for s in range(sessions):
            if started[s] is None or t < started[s]:
                continue
            if cursors[s] >= n_windows:
                continue
            sid = f"stream-{s}"
            b.data(t_ms, sid, cursors[s])
            cursors[s] += 1
            if cursors[s] == n_windows:
                b.close(t_ms, sid)
        t += 1
    return b.build()


def _synth_dropout_churn(*, sessions: int, length: int, window: int,
                         seed: int, tick_ms: int = TICK_MS,
                         p_drop: float = 0.12) -> Trace:
    """Sensors drop mid-stream and reconnect: the source row resumes under
    a fresh session id after a seeded silence gap (the paper's flaky edge
    links, exercised against slot reuse)."""
    rng = np.random.default_rng(seed)
    n_windows = -(-length // window)
    b = TraceBuilder("dropout_churn", seed, sessions, length, window)
    cursors = [0] * sessions
    gen = [0] * sessions          # reconnect generation per stream
    silent_until = [0] * sessions
    live = [False] * sessions

    def sid_of(s):
        return f"stream-{s}" if gen[s] == 0 else f"stream-{s}-r{gen[s]}"

    t = 0
    while any(c < n_windows for c in cursors):
        t_ms = t * tick_ms
        for s in range(sessions):
            if cursors[s] >= n_windows or t < silent_until[s]:
                continue
            if not live[s]:
                b.open(t_ms, sid_of(s), s)
                live[s] = True
            b.data(t_ms, sid_of(s), cursors[s])
            cursors[s] += 1
            if cursors[s] == n_windows:
                b.close(t_ms, sid_of(s))
                live[s] = False
            elif rng.random() < p_drop:  # drop mid-stream
                b.close(t_ms, sid_of(s))
                live[s] = False
                gen[s] += 1
                silent_until[s] = t + 1 + int(rng.integers(1, 5))
        t += 1
    return b.build()


def _synth_mixed_fleet(*, sessions: int, length: int, window: int, seed: int,
                       tick_ms: int = TICK_MS) -> Trace:
    """Raw-in and compressed-in senders interleaving on one slot table
    (even rows raw, odd rows pieces), staggered opens, round-robin data."""
    n_windows = -(-length // window)
    b = TraceBuilder("mixed_fleet", seed, sessions, length, window)
    cursors = [0] * sessions
    t = 0
    while any(c < n_windows for c in cursors):
        t_ms = t * tick_ms
        for s in range(sessions):  # stream s dawns at tick min(s, 3)
            if min(s, 3) == t:
                b.open(t_ms, f"stream-{s}", s,
                       mode="raw" if s % 2 == 0 else "pieces")
        for s in range(sessions):
            if cursors[s] >= n_windows or t < min(s, 3):
                continue
            sid = f"stream-{s}"
            b.data(t_ms, sid, cursors[s])
            cursors[s] += 1
            if cursors[s] == n_windows:
                b.close(t_ms, sid)
        t += 1
    return b.build()


def _synth_slot_churn(*, sessions: int, length: int, window: int, seed: int,
                      tick_ms: int = TICK_MS, phases: int = 3,
                      gap_ticks: int = 4) -> Trace:
    """Adversarial autoscale thrash: ``phases`` waves of ``sessions``
    short-lived sessions land nearly at once (sized past the slot table, so
    LRU eviction fires), separated by quiet gaps where only one background
    session trickles -- the table must grow, shrink, and regrow.
    """
    rng = np.random.default_rng(seed)
    short_windows = 2
    n_streams = phases * sessions + 1
    bg_row = n_streams - 1
    n_windows = -(-length // window)
    b = TraceBuilder("slot_churn", seed, n_streams, length, window)
    b.open(0, "bg", bg_row)
    bg_cursor = 0
    t = 0

    def bg_tick(t_ms):
        nonlocal bg_cursor
        if bg_cursor < n_windows:
            b.data(t_ms, "bg", bg_cursor)
            bg_cursor += 1
            if bg_cursor == n_windows:
                b.close(t_ms, "bg")

    for phase in range(phases):
        # the wave: all of this phase's sessions open in one tick, in a
        # seeded shuffle, then deliver a couple of windows and leave
        order = rng.permutation(np.arange(sessions))
        t_ms = t * tick_ms
        for i in order:
            b.open(t_ms, f"p{phase}s{int(i)}", phase * sessions + int(i))
        for w in range(short_windows):
            t_ms = t * tick_ms
            bg_tick(t_ms)
            for i in range(sessions):
                b.data(t_ms, f"p{phase}s{i}", w)
            t += 1
        t_ms = (t - 1) * tick_ms
        for i in range(sessions):
            b.close(t_ms, f"p{phase}s{i}")
        for _ in range(gap_ticks):  # quiet: occupancy collapses to bg
            bg_tick(t * tick_ms)
            t += 1
    while bg_cursor < n_windows:
        bg_tick(t * tick_ms)
        t += 1
    return b.build()


# --------------------------------------------------------------- registry


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named synthesizer plus the server shape and SLOs it is scored
    against.  ``defaults`` feed the synthesizer; ``server_kw`` feed
    ``StreamServer``; ``slos`` are the default thresholds
    (``repro.workload.slo``) a replay of this scenario must meet."""
    name: str
    synth: Callable[..., Trace]
    description: str
    defaults: Mapping[str, object]
    server_kw: Mapping[str, object]
    slos: Mapping[str, float]
    legacy: bool = False


_COMMON_SLOS = {
    "p99_symbol_ms": 2000.0,   # generous: shared CI runners, cold caches
    "max_queue_depth": 64.0,
    "evict_rate": 0.0,
}

SCENARIOS: Dict[str, Scenario] = {}


def _register(sc: Scenario) -> None:
    SCENARIOS[sc.name] = sc


_register(Scenario(
    "diurnal", _synth_diurnal,
    "sinusoidal day/night arrival intensity over a steady fleet",
    defaults=dict(sessions=8, length=192, window=32),
    server_kw=dict(max_sessions=8, pretrace=True),
    slos=dict(_COMMON_SLOS),
))
_register(Scenario(
    "flash_crowd", _synth_flash_crowd,
    "quiet baseline fleet, then a cohort lands in one tick (autoscale up)",
    defaults=dict(sessions=12, length=192, window=32),
    server_kw=dict(max_sessions=16, min_slots=4, autoscale=True,
                   shrink_patience=2, pretrace=True),
    slos=dict(_COMMON_SLOS),
))
_register(Scenario(
    "dropout_churn", _synth_dropout_churn,
    "sensors drop mid-stream and reconnect as fresh sessions (slot reuse)",
    defaults=dict(sessions=6, length=192, window=32),
    server_kw=dict(max_sessions=8, pretrace=True),
    slos=dict(_COMMON_SLOS),
))
_register(Scenario(
    "mixed_fleet", _synth_mixed_fleet,
    "raw-mode and pieces-mode senders sharing one slot table",
    defaults=dict(sessions=8, length=192, window=32),
    server_kw=dict(max_sessions=8, pretrace=True),
    slos=dict(_COMMON_SLOS),
))
_register(Scenario(
    "slot_churn", _synth_slot_churn,
    "short-lived session waves sized past the table: autoscale thrash + "
    "LRU eviction",
    defaults=dict(sessions=6, length=192, window=32),
    server_kw=dict(max_sessions=4, min_slots=1, autoscale=True,
                   evict_idle=True, shrink_patience=1, pretrace=True),
    slos={**_COMMON_SLOS, "evict_rate": 0.6},
))
for _pattern in ("roundrobin", "random", "bursty"):
    _register(Scenario(
        _pattern, _synth_legacy(_pattern),
        f"legacy --arrival-pattern {_pattern} shim",
        defaults=dict(sessions=6, length=384, window=48),
        server_kw=dict(max_sessions=8, pretrace=True),
        slos=dict(_COMMON_SLOS),
        legacy=True,
    ))


def synthesize(name: str, *, seed: int, **overrides) -> Trace:
    """Build ``name``'s trace with ``seed`` and parameter ``overrides``.

    The seed is explicit on purpose -- callers thread
    ``scenario_seed(name, base)`` (or their own) so no shared rng state can
    couple rows (the fleet_scale reorder-invariance pin).
    """
    sc = SCENARIOS.get(name)
    if sc is None:
        raise ValueError(
            f"unknown scenario {name!r} (have: {', '.join(sorted(SCENARIOS))})")
    params = {**sc.defaults, **overrides}
    return sc.synth(seed=seed, **params)


class Workload:
    """A scenario bound to its parameters: the first-class load object.

    ``Workload("flash_crowd").trace()`` synthesizes the trace;
    ``server_kw()`` / ``slos()`` expose the scenario's replay defaults with
    any construction-time overrides merged in.  The legacy
    ``--arrival-pattern`` values construct through :meth:`from_pattern`,
    which is the deprecation seam.
    """

    def __init__(self, scenario: str, *, seed: Optional[int] = None,
                 server_kw: Optional[dict] = None,
                 slos: Optional[dict] = None, **params):
        if scenario not in SCENARIOS:
            raise ValueError(
                f"unknown scenario {scenario!r} "
                f"(have: {', '.join(sorted(SCENARIOS))})")
        self.scenario = SCENARIOS[scenario]
        self.name = scenario
        self.seed = scenario_seed(scenario) if seed is None else int(seed)
        self.params = params
        self._server_kw = dict(server_kw or {})
        self._slos = dict(slos or {})

    @classmethod
    def from_pattern(cls, pattern: str, *, sessions: int, length: int,
                     window: int, seed: int, _warn: bool = True) -> "Workload":
        """Shim for the retired ``--arrival-pattern`` string toggles."""
        if _warn:
            warnings.warn(
                f"--arrival-pattern {pattern!r} is deprecated; use "
                f"workload.Workload({pattern!r}, ...) or a workload_trace/v1 "
                "file (same seed synthesizes the identical tick schedule)",
                DeprecationWarning, stacklevel=2)
        return cls(pattern, seed=seed, sessions=sessions, length=length,
                   window=window)

    def trace(self) -> Trace:
        return synthesize(self.name, seed=self.seed, **self.params)

    def server_kw(self) -> dict:
        return {**self.scenario.server_kw, **self._server_kw}

    def slos(self) -> dict:
        return {**self.scenario.slos, **self._slos}
