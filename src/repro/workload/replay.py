"""Deterministic trace replay against the stream service or the transport.

The engine walks a ``workload_trace/v1`` trace in drain buckets
(``service_every_ms`` of trace time per service call), and drives either:

* **in-process** (default): a ``StreamServer`` directly -- all of a
  drain's arrivals go through one batched ``ingest_many`` /
  ``ingest_pieces_many`` pair, exactly the transport loop's flush shape.
  This path is bit-reproducible: same trace + seed => identical delta
  bytes and counter totals, on 1 or N forced host devices.
* **over loopback TCP** (``transport=True``): a ``TransportServer`` thread
  plus one ``SenderClient`` socket carrying every session (mixed raw and
  pieces modes per the trace's session metadata).  Socket scheduling makes
  byte timing nondeterministic, so only the schedule-determined counters
  participate in this mode's fingerprint; latency SLOs are the point here.

Pacing: ``rate=0`` replays as fast as the service drains; ``rate=r``
paces drains against the trace clock scaled by ``r`` (1.0 = real time).
Pacing changes wall time, never batch composition.

Queue depth is measured at the drain boundary (windows staged since the
last service call), eviction rate and totals come from the server, and
per-symbol latency comes from the ``repro.obs`` histogram the service
already records -- the SLO keys in ``repro.workload.slo`` map 1:1 onto
:meth:`ReplayResult.measured`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.workload.trace import Trace

__all__ = ["ReplayResult", "replay_trace"]

#: counters that socket scheduling cannot perturb (transport fingerprint)
LOOSE_COUNTER_KEYS = ("opened", "closed", "evicted", "points_in",
                      "symbols_out")


def _default_cfg():
    from repro.core.symed import SymEDConfig
    return SymEDConfig(tol=0.5, alpha=0.01, n_max=256, k_max=32, len_max=256)


@dataclasses.dataclass
class ReplayResult:
    """One replay's measurements, identity, and per-session outcomes."""
    trace_name: str
    trace_digest: str
    seed: int
    transport: bool
    wall_seconds: float
    counters: Dict[str, float]
    queue: Dict[str, float]
    latency: Dict[str, float]
    delta_sha256: str
    sessions: Dict[str, dict]
    closed: Dict[str, dict] = dataclasses.field(default_factory=dict)
    verified: int = -1

    @property
    def evict_rate(self) -> float:
        return self.counters.get("evicted", 0.0) / max(
            self.counters.get("opened", 0.0), 1.0)

    def measured(self) -> Dict[str, float]:
        """The flat measurement map the SLO layer checks (slo.KNOWN_SLOS)."""
        return {
            "p50_symbol_ms": self.latency.get("p50_ms", 0.0),
            "p99_symbol_ms": self.latency.get("p99_ms", 0.0),
            "p999_symbol_ms": self.latency.get("p999_ms", 0.0),
            "max_queue_depth": self.queue.get("max_depth", 0.0),
            "mean_queue_depth": self.queue.get("mean_depth", 0.0),
            "evict_rate": self.evict_rate,
        }

    def fingerprint(self) -> str:
        """Replay identity for the determinism battery.

        In-process: the delta-stream hash plus *every* counter total.
        Over transport: only the schedule-determined counter subset
        (socket coalescing legitimately perturbs step/frame counts).
        """
        if self.transport:
            counters = {k: self.counters.get(k, 0.0)
                        for k in LOOSE_COUNTER_KEYS}
        else:
            counters = dict(self.counters)
        payload = json.dumps(
            {"delta_sha256": self.delta_sha256, "counters": counters},
            sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(payload.encode("utf-8")).hexdigest()


def _slice_window(data: np.ndarray, row: int, ref: int, window: int,
                  length: int) -> np.ndarray:
    lo = ref * window
    return data[row, lo: min(lo + window, length)]


def _delta_sha256(sids, deltas, closed) -> str:
    """Order-independent hash of every session's concatenated delta stream."""
    h = hashlib.sha256()
    for sid in sorted(sids):
        labels = [np.asarray(d["labels"], np.int32)
                  for d in deltas.get(sid, [])]
        endpoints = [np.asarray(d["endpoints"], np.float32)
                     for d in deltas.get(sid, [])]
        res = closed.get(sid)
        if res is not None:
            labels.append(np.asarray(res["delta"]["labels"], np.int32))
            endpoints.append(
                np.asarray(res["delta"]["endpoints"], np.float32))
        lab = np.concatenate(labels) if labels else np.zeros((0,), np.int32)
        eps = (np.concatenate(endpoints) if endpoints
               else np.zeros((0,), np.float32))
        h.update(sid.encode("utf-8"))
        h.update(lab.tobytes())
        h.update(eps.tobytes())
    return h.hexdigest()


class _PieceSender:
    """Sender-side compressor for an in-process pieces-mode session
    (the ``SenderClient`` arithmetic without the socket)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.state = None
        self.t0 = 0.0
        self.t_seen = 0

    def compress(self, window: np.ndarray):
        import jax.numpy as jnp

        from repro.core.compress import pieces_on_wire
        from repro.core.symed import symed_encode_chunk

        if self.state is None and self.t_seen == 0:
            self.t0 = float(window[0])
        self.state, events = symed_encode_chunk(
            jnp.asarray(window), self.cfg, self.state)
        endpoints, steps = pieces_on_wire(events, self.t_seen)
        self.t_seen += len(window)
        return np.asarray(endpoints), np.asarray(steps)

    def tail(self):
        from repro.core.compress import compressor_finalize

        if self.state is None:
            return None
        t = compressor_finalize(self.state)
        return float(t.endpoint) if bool(t.emit) else None


class _InProcess:
    """Drain adapter driving a ``StreamServer`` directly."""

    def __init__(self, trace: Trace, cfg, server, data: np.ndarray):
        import jax

        from repro.core.receiver import PIECE_TUPLE_BYTES
        from repro.launch.transport import session_seed

        self._jax = jax
        self._piece_bytes = PIECE_TUPLE_BYTES
        self._session_seed = session_seed
        self.trace = trace
        self.cfg = cfg
        self.server = server
        self.data = data
        self.deltas: Dict[str, List[dict]] = {}
        self.closed: Dict[str, dict] = {}
        self.fed: Dict[str, List[np.ndarray]] = {}
        self._senders: Dict[str, _PieceSender] = {}

    def _terminated(self, sid: str) -> bool:
        return sid in self.closed or sid in self.server.evicted

    def drain(self, events) -> None:
        trace = self.trace
        staged: Dict[str, List[np.ndarray]] = {}
        closes: List[str] = []
        for ev in events:
            if self._terminated(ev.sid):
                continue  # eviction drops the stream's remainder
            if ev.kind == "open":
                meta = trace.sessions[ev.sid]
                key = self._jax.random.key(
                    self._session_seed(ev.sid, trace.seed))
                self.server.open(ev.sid, key=key)
                if meta["mode"] == "pieces":
                    self._senders[ev.sid] = _PieceSender(self.cfg)
            elif ev.kind == "data":
                win = _slice_window(
                    self.data, trace.sessions[ev.sid]["stream"],
                    ev.window_ref, trace.window, trace.length)
                staged.setdefault(ev.sid, []).append(win)
            else:
                closes.append(ev.sid)
        # opening a session may LRU-evict one staged earlier this drain
        raw_batch: Dict[str, np.ndarray] = {}
        pieces_batch: Dict[str, dict] = {}
        for sid, wins in staged.items():
            if sid not in self.server:
                continue
            self.fed.setdefault(sid, []).extend(wins)
            sender = self._senders.get(sid)
            if sender is None:
                raw_batch[sid] = (np.concatenate(wins) if len(wins) > 1
                                  else wins[0])
            else:
                eps, steps, wire = [], [], 0.0
                for w in wins:
                    e, s = sender.compress(w)
                    eps.append(e)
                    steps.append(s)
                    wire += 12.0 + self._piece_bytes * len(e)
                pieces_batch[sid] = {
                    "endpoints": (np.concatenate(eps) if eps
                                  else np.zeros((0,), np.float32)),
                    "steps": (np.concatenate(steps) if steps
                              else np.zeros((0,), np.int32)),
                    "t_seen": sender.t_seen, "t0": sender.t0,
                    "wire_bytes": wire,
                }
        # a closing pieces session ships its sender tail in the same drain
        # (the transport loop's CLOSE handling)
        for sid in closes:
            sender = self._senders.get(sid)
            if sender is None or sid not in self.server:
                continue
            tail = sender.tail()
            if tail is None:
                continue
            p = pieces_batch.setdefault(sid, {
                "endpoints": np.zeros((0,), np.float32),
                "steps": np.zeros((0,), np.int32),
                "t_seen": sender.t_seen, "t0": sender.t0, "wire_bytes": 0.0,
            })
            p["endpoints"] = np.concatenate(
                [p["endpoints"], np.asarray([tail], np.float32)])
            p["steps"] = np.concatenate(
                [p["steps"], np.asarray([sender.t_seen], np.int32)])
            p["wire_bytes"] += 4.0
        if raw_batch:
            for sid, d in self.server.ingest_many(raw_batch).items():
                self.deltas.setdefault(sid, []).append(d)
        if pieces_batch:
            for sid, d in self.server.ingest_pieces_many(
                    pieces_batch).items():
                self.deltas.setdefault(sid, []).append(d)
        for sid in closes:
            if sid in self.server:
                self.closed[sid] = self.server.close(sid)

    def finish(self):
        self.closed.update(self.server.evicted)
        sids = set(self.trace.sessions)
        delta_sha = _delta_sha256(sids, self.deltas, self.closed)
        sessions = {}
        for sid in sorted(sids):
            res = self.closed.get(sid)
            sessions[sid] = {
                "t_seen": int(res["t_seen"]) if res else 0,
                "n_pieces": int(res["n_pieces"]) if res else 0,
                "evicted": sid in self.server.evicted,
                "dtw": (res or {}).get("dtw"),
            }
        return delta_sha, sessions

    def verify(self) -> int:
        """Bitwise delta-concatenation check against ``symed_encode`` over
        the windows each session actually ingested.

        Evicted *pieces-mode* sessions are skipped: the sender's unfinished
        tail piece is legitimately lost at eviction, so no whole-stream
        reference exists for them (raw-mode evictions verify fine -- the
        receiver's own compressor flushes its tail over the ingested
        prefix).
        """
        import jax
        import jax.numpy as jnp

        from repro.core.symed import symed_encode

        checked = 0
        for sid in sorted(self.closed):
            if sid not in self.trace.sessions:
                continue
            res = self.closed[sid]
            if not res["t_seen"]:
                continue
            if sid in self.server.evicted and sid in self._senders:
                continue
            fed = np.concatenate(self.fed[sid])
            assert len(fed) == res["t_seen"], (sid, len(fed), res["t_seen"])
            got = np.concatenate(
                [np.asarray(d["labels"], np.int32)
                 for d in self.deltas.get(sid, [])]
                + [np.asarray(res["delta"]["labels"], np.int32)])
            key = jax.random.key(self._session_seed(sid, self.trace.seed))
            ref = symed_encode(jnp.asarray(fed), self.cfg, key,
                               reconstruct=False)
            n = int(ref["n_pieces"])
            want = np.asarray(ref["symbols_online"])[:n]
            np.testing.assert_array_equal(got, want, err_msg=sid)
            assert res["n_pieces"] == n, (sid, res["n_pieces"], n)
            checked += 1
        return checked


class _OverTransport:
    """Drain adapter driving a loopback ``TransportServer`` + one
    ``SenderClient`` socket carrying every session."""

    def __init__(self, trace: Trace, cfg, server, data: np.ndarray,
                 close_timeout: float):
        from repro.launch.transport import (
            SenderClient, TransportServer, session_seed)

        self._session_seed = session_seed
        self.trace = trace
        self.cfg = cfg
        self.server = server
        self.data = data
        self.close_timeout = close_timeout
        self.transport = TransportServer(server, host="127.0.0.1", port=0)
        self.thread = threading.Thread(
            target=self.transport.serve,
            kwargs={"expect_sessions": len(trace.sessions)}, daemon=True)
        self.thread.start()
        self.client = SenderClient(
            "127.0.0.1", self.transport.port, cfg, mode="raw",
            reply_timeout=close_timeout)
        self.results: Dict[str, dict] = {}
        self.fed: Dict[str, List[np.ndarray]] = {}

    def drain(self, events) -> None:
        trace = self.trace
        for ev in events:
            if self.client.settled(ev.sid):
                continue  # receiver already closed it (eviction)
            meta = trace.sessions[ev.sid]
            if ev.kind == "open":
                self.client.open(ev.sid,
                                 self._session_seed(ev.sid, trace.seed),
                                 mode=meta["mode"])
            elif ev.kind == "data":
                win = _slice_window(self.data, meta["stream"], ev.window_ref,
                                    trace.window, trace.length)
                self.fed.setdefault(ev.sid, []).append(win)
                self.client.send(ev.sid, win)
            else:
                self.results[ev.sid] = self.client.close(ev.sid)

    def finish(self):
        # every session settles via close() or a parked eviction CLOSED;
        # sids whose trace close was skipped (settled mid-run) still hold
        # their parked result
        for sid in self.trace.sessions:
            if sid not in self.results:
                self.results[sid] = self.client.close(sid)
        self.thread.join(timeout=self.close_timeout)
        deltas = {}
        for sid in self.results:
            labels, endpoints = self.client.delta_concat(sid)
            deltas[sid] = [{"labels": labels, "endpoints": endpoints}]
        # no separate closing frame: delta_concat already folds it in
        delta_sha = _delta_sha256(set(self.trace.sessions), deltas, {})
        sessions = {
            sid: {"t_seen": int(res["t_seen"]),
                  "n_pieces": int(res["n_pieces"]),
                  "evicted": bool(res["evicted"]), "dtw": None}
            for sid, res in sorted(self.results.items())
        }
        self.client.shutdown()
        return delta_sha, sessions

    def verify(self) -> int:
        """Bitwise check of each cleanly-closed session's returned deltas
        (evicted sessions skip: in-flight frames make the ingested prefix
        racy by design)."""
        import jax
        import jax.numpy as jnp

        from repro.core.symed import symed_encode

        checked = 0
        for sid in sorted(self.results):
            res = self.results[sid]
            if res["evicted"] or not res["t_seen"]:
                continue
            fed = np.concatenate(self.fed[sid])
            assert len(fed) == res["t_seen"], (sid, len(fed), res["t_seen"])
            labels, _ = self.client.delta_concat(sid)
            key = jax.random.key(self._session_seed(sid, self.trace.seed))
            ref = symed_encode(jnp.asarray(fed), self.cfg, key,
                               reconstruct=False)
            n = int(ref["n_pieces"])
            np.testing.assert_array_equal(
                np.asarray(labels, np.int32),
                np.asarray(ref["symbols_online"])[:n].astype(np.int32),
                err_msg=sid)
            checked += 1
        return checked


def replay_trace(trace: Trace, *, cfg=None, server=None,
                 server_kw: Optional[dict] = None, obs=None,
                 rate: float = 0.0, transport: bool = False,
                 verify: bool = False,
                 close_timeout: float = 300.0) -> ReplayResult:
    """Replay ``trace``; returns the measured :class:`ReplayResult`.

    ``server`` reuses a caller-built ``StreamServer`` (the stream CLI path:
    its mesh/obs wiring stays in charge); otherwise one is constructed from
    ``server_kw`` (scenario defaults) with ``window_cap=trace.window``.
    """
    from repro.data.synthetic import make_fleet

    if cfg is None:
        cfg = _default_cfg()
    if server is None:
        from repro.launch.stream import StreamServer
        from repro.obs import Observability

        kw = {"max_sessions": 8, **(server_kw or {})}
        kw.setdefault("window_cap", trace.window)
        if obs is None:
            obs = Observability()
        server = StreamServer(cfg, obs=obs, **kw)
    obs = server.obs
    data = np.asarray(make_fleet(trace.n_streams, trace.length,
                                 seed=trace.seed))

    h_depth = obs.metrics.histogram(
        "workload_queue_depth", "windows staged per service drain", unit="")
    if transport:
        backend = _OverTransport(trace, cfg, server, data, close_timeout)
    else:
        backend = _InProcess(trace, cfg, server, data)

    service = trace.service_every_ms
    depth_max = 0
    depth_sum = 0
    drains = 0
    t0 = time.perf_counter()
    for bucket, group in itertools.groupby(
            trace.ticks(), key=lambda kv: kv[0] // service):
        events = [ev for _, evs in group for ev in evs]
        if rate > 0.0:
            target = t0 + ((bucket + 1) * service) / (1e3 * rate)
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
        depth = sum(1 for ev in events if ev.kind == "data")
        h_depth.observe(depth)
        depth_max = max(depth_max, depth)
        depth_sum += depth
        drains += 1
        backend.drain(events)
    delta_sha, sessions = backend.finish()
    wall = time.perf_counter() - t0

    snap = obs.snapshot()
    lat = snap.get("histograms", {}).get("symed_symbol_latency_seconds", {})
    latency = {
        "p50_ms": 1e3 * float(lat.get("p50", 0.0)),
        "p99_ms": 1e3 * float(lat.get("p99", 0.0)),
        "p999_ms": 1e3 * float(lat.get("p999", 0.0)),
        "mean_ms": 1e3 * float(lat.get("mean", 0.0)),
        "count": float(lat.get("count", 0.0)),
    }
    counts = trace.counts()
    queue = {
        "max_depth": float(depth_max),
        "mean_depth": depth_sum / max(drains, 1),
        "drains": float(drains),
        "events": float(counts["events"]),
        "windows": float(counts["windows"]),
    }
    result = ReplayResult(
        trace_name=trace.name,
        trace_digest=trace.digest(),
        seed=trace.seed,
        transport=transport,
        wall_seconds=wall,
        counters={k: float(v) for k, v in server.totals.items()},
        queue=queue,
        latency=latency,
        delta_sha256=delta_sha,
        sessions=sessions,
        closed=getattr(backend, "closed", {}),
    )
    if verify:
        result.verified = backend.verify()
    return result
