"""symlint: repo-native static analysis for the SymED codebase.

``python -m repro.analysis`` (or the ``symlint`` entry point) sweeps
``src``/``examples``/``benchmarks`` and enforces the contracts the ROADMAP
states as standing policy but until now checked only by review:

  ======  ==================  ==============================================
  SL001   compat-policy       version-sensitive JAX names via jax_compat
  SL002   retrace-hazard      no tracer misuse / per-call retraces under jit
  SL003   donation-aliasing   donated buffers rebound before reuse
  SL004   host-sync           no hidden device syncs in marked hot paths
  SL005   wire-consistency    encoder/decoder struct layouts agree by bytes
  ======  ==================  ==============================================

Pure AST analysis -- the swept code is never imported or executed, so the
pass runs in CI without JAX initialization cost (and on files that would
fail to import).  Suppress one line with ``# symlint: disable=SL00x``;
grandfathered findings live in ``.symlint-baseline.json`` with written
justifications.
"""
from repro.analysis.engine import (  # noqa: F401
    AnalysisResult, Baseline, Finding, Project, RULES, analyze, load_project,
)
from repro.analysis.cli import main  # noqa: F401

__all__ = [
    "AnalysisResult", "Baseline", "Finding", "Project", "RULES",
    "analyze", "load_project", "main",
]
