"""Single-pass taint propagation over one function body.

SL002 (tracers inside jit) and SL004 (device values in host hot paths) ask
the same shape of question: *does this expression carry a value of suspect
origin, and is it flowing into a sink that would concretize it?*  The walker
here is deliberately simple -- one forward pass over the statements in
source order, dotted-path environments, no fixpoint -- because a linter
should be predictable: a developer reading the flagged line must be able to
see the flow the rule saw.

Taint model:

  * seeds: taint the given dotted paths (traced parameters / device tables);
  * calls: a call whose callee matches ``source_call`` taints its result;
    conversion sinks (``float``/``int``/``bool``/``np.asarray``/``np.array``/
    ``jax.device_get``/``.item()``/``.tolist()``) *un*-taint theirs (they are
    the concretization point -- flagged once, then the value is host-side);
    ``len()`` and static metadata (``.shape``/``.dtype``/``.ndim``/``.size``)
    are never tainted (host-known without a sync);
  * propagation: assignment targets inherit the RHS taint (and are cleansed
    when the RHS is clean -- rebinding to a host value ends the taint);
    attribute/subscript access on a tainted base stays tainted.

Sinks are reported through a callback; nested ``def``s are skipped (they get
their own analysis if jitted), nested lambdas are walked with their
parameters tainted (vmap bodies).
"""
from __future__ import annotations

import ast
from typing import Callable, Iterable, Optional, Set

from repro.analysis.astutil import dotted

__all__ = ["STATIC_ATTRS", "CONVERTER_CALLS", "TaintWalker", "assigned_names"]

STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}

#: callee paths that concretize their (tainted) argument on the host
CONVERTER_CALLS = {
    "float", "int", "bool",
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get",
}
_CONVERTER_METHODS = {"item", "tolist"}
_NEVER_TAINTED_CALLS = {"len", "isinstance", "range", "enumerate", "max",
                        "min", "print", "sorted", "list", "tuple", "dict",
                        "set", "repr", "str"}


def assigned_names(node: ast.AST) -> Set[str]:
    """Every simple name bound by assignments / for-targets under ``node``."""
    out: Set[str] = set()

    def targets(t):
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e)
        elif isinstance(t, ast.Starred):
            targets(t.value)

    for n in ast.walk(node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                targets(t)
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            targets(n.target)
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            targets(n.target)
        elif isinstance(n, (ast.withitem,)) and n.optional_vars is not None:
            targets(n.optional_vars)
        elif isinstance(n, ast.NamedExpr):
            targets(n.target)
        elif isinstance(n, ast.comprehension):
            targets(n.target)
    return out


class TaintWalker:
    """Walk one function body, reporting ``(node, kind, detail)`` sinks.

    ``kind`` is one of ``"convert"`` (explicit concretization call),
    ``"branch"`` (if/while/ternary/assert on a tainted test).
    """

    def __init__(
        self,
        seeds: Iterable[str],
        source_call: Callable[[ast.Call], bool],
        on_sink: Callable[[ast.AST, str, str], None],
        branch_sinks: bool = True,
    ):
        self.tainted: Set[str] = set(seeds)
        self.source_call = source_call
        self.on_sink = on_sink
        self.branch_sinks = branch_sinks

    # -- expression taint --------------------------------------------------

    def expr_tainted(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            path = dotted(node)
            if path is not None and path in self.tainted:
                return True
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            callee = dotted(node.func) or ""
            method = (node.func.attr
                      if isinstance(node.func, ast.Attribute) else "")
            if (callee in CONVERTER_CALLS
                    or method in _CONVERTER_METHODS
                    or callee in _NEVER_TAINTED_CALLS):
                return False  # result is host-side by construction
            if self.source_call(node):
                return True
            return (any(self.expr_tainted(a) for a in node.args)
                    or any(self.expr_tainted(k.value) for k in node.keywords))
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return (self.expr_tainted(node.left)
                    or any(self.expr_tainted(c) for c in node.comparators))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or self.expr_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        return False

    # -- sink scan ---------------------------------------------------------

    def _scan_expr(self, node: ast.AST) -> None:
        """Find sinks inside one expression (ordered, lambda-aware)."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs get their own analysis
        if isinstance(node, ast.Lambda):
            sub = TaintWalker(
                self.tainted | {a.arg for a in node.args.args},
                self.source_call, self.on_sink, self.branch_sinks)
            sub._scan_expr(node.body)
            return
        if isinstance(node, ast.Call):
            callee = dotted(node.func) or ""
            method = (node.func.attr
                      if isinstance(node.func, ast.Attribute) else "")
            args_tainted = (
                any(self.expr_tainted(a) for a in node.args)
                or any(self.expr_tainted(k.value) for k in node.keywords))
            if callee in CONVERTER_CALLS and args_tainted:
                self.on_sink(node, "convert", f"{callee}()")
            elif (method in _CONVERTER_METHODS
                    and self.expr_tainted(node.func.value)):
                self.on_sink(node, "convert", f".{method}()")
        if isinstance(node, ast.IfExp) and self.branch_sinks:
            if self.expr_tainted(node.test):
                self.on_sink(node, "branch", "conditional expression")
        for child in ast.iter_child_nodes(node):
            self._scan_expr(child)

    # -- statement walk ----------------------------------------------------

    def _assign(self, target: ast.AST, value_tainted: bool) -> None:
        path = dotted(target)
        if path is not None:
            if value_tainted:
                self.tainted.add(path)
            else:
                self.tainted.discard(path)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign(e, value_tainted)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, value_tainted)

    def walk(self, body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, (ast.If, ast.While)):
            self._scan_expr(stmt.test)
            if self.branch_sinks and self.expr_tainted(stmt.test):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                self.on_sink(stmt, "branch", f"`{kind}` statement")
            self.walk(stmt.body)
            self.walk(stmt.orelse)
            return
        if isinstance(stmt, ast.Assert):
            self._scan_expr(stmt.test)
            if self.branch_sinks and self.expr_tainted(stmt.test):
                self.on_sink(stmt, "branch", "`assert` statement")
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter)
            self._assign(stmt.target, self.expr_tainted(stmt.iter))
            self.walk(stmt.body)
            self.walk(stmt.orelse)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars,
                                 self.expr_tainted(item.context_expr))
            self.walk(stmt.body)
            return
        if isinstance(stmt, (ast.Try,)):
            self.walk(stmt.body)
            for h in stmt.handlers:
                self.walk(h.body)
            self.walk(stmt.orelse)
            self.walk(stmt.finalbody)
            return
        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value)
            t = self.expr_tainted(stmt.value)
            for target in stmt.targets:
                self._assign(target, t)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._scan_expr(stmt.value)
            self._assign(stmt.target, self.expr_tainted(stmt.value))
            return
        if isinstance(stmt, ast.AugAssign):
            self._scan_expr(stmt.value)
            if self.expr_tainted(stmt.value):
                self._assign(stmt.target, True)
            return
        if isinstance(stmt, (ast.Return, ast.Expr)) and stmt.value is not None:
            self._scan_expr(stmt.value)
            return
        # anything else: scan child expressions conservatively
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._scan_expr(child)
