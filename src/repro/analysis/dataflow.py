"""CFG + fixpoint taint propagation over one function body.

SL002 (tracers inside jit) and SL004 (device values in host hot paths) ask
the same shape of question: *does this expression carry a value of suspect
origin, and is it flowing into a sink that would concretize it?*

The original walker was a single forward pass over the statements in source
order -- predictable, but blind to two whole families of flows: taint that
only reaches a use through a loop back edge (``prev`` assigned a device
value at the bottom of the loop, read at the top of the next iteration) and
taint that survives a branch because only *one* arm rebinds to a host value
(the straight-line pass saw the rebind and cleansed unconditionally).  This
version builds an explicit control-flow graph per function body -- branch,
loop, and try/except edges -- and solves may-taint reaching definitions
with a worklist fixpoint (union join at merge points), then replays each
block under its fixed-point entry environment to report sinks.  A flagged
line therefore means: *there exists a path through this function on which
the value at this sink is still device-resident*.

Taint model (unchanged from the single-pass walker):

  * seeds: taint the given dotted paths (traced parameters / device tables);
  * calls: a call whose callee matches ``source_call`` taints its result;
    conversion sinks (``float``/``int``/``bool``/``np.asarray``/``np.array``/
    ``jax.device_get``/``.item()``/``.tolist()``) *un*-taint theirs (they are
    the concretization point -- flagged once, then the value is host-side);
    ``len()`` and static metadata (``.shape``/``.dtype``/``.ndim``/``.size``)
    are never tainted (host-known without a sync);
  * propagation: assignment targets inherit the RHS taint (and are cleansed
    when the RHS is clean -- rebinding to a host value ends the taint *on
    paths through that rebind*; the union join keeps the taint alive when
    another path skips it);
  * joins: union (may-taint) -- at an ``if``/``else`` merge, a loop header,
    or an ``except`` entry, a name is tainted if it is tainted on *any*
    inbound edge.  ``except`` entries join the environments after every
    statement of the ``try`` body (the raise may happen anywhere).

Sinks are reported through a callback, each source location at most once;
nested ``def``s are skipped (they get their own analysis if jitted), nested
lambdas are walked with their parameters tainted (vmap bodies).
"""
from __future__ import annotations

import ast
from typing import Callable, Iterable, List, Optional, Set, Tuple

from repro.analysis.astutil import dotted

__all__ = ["STATIC_ATTRS", "CONVERTER_CALLS", "TaintWalker", "assigned_names"]

STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}

#: callee paths that concretize their (tainted) argument on the host
CONVERTER_CALLS = {
    "float", "int", "bool",
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get",
}
_CONVERTER_METHODS = {"item", "tolist"}
_NEVER_TAINTED_CALLS = {"len", "isinstance", "range", "enumerate", "max",
                        "min", "print", "sorted", "list", "tuple", "dict",
                        "set", "repr", "str"}


def assigned_names(node: ast.AST) -> Set[str]:
    """Every simple name bound by assignments / for-targets under ``node``."""
    out: Set[str] = set()

    def targets(t):
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e)
        elif isinstance(t, ast.Starred):
            targets(t.value)

    for n in ast.walk(node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                targets(t)
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            targets(n.target)
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            targets(n.target)
        elif isinstance(n, (ast.withitem,)) and n.optional_vars is not None:
            targets(n.optional_vars)
        elif isinstance(n, ast.NamedExpr):
            targets(n.target)
        elif isinstance(n, ast.comprehension):
            targets(n.target)
    return out


# --------------------------------------------------------------------------
# control-flow graph
#
# Blocks hold a list of *ops* -- (kind, payload...) tuples mirroring exactly
# the statement effects the single-pass walker modeled -- so the fixpoint
# transfer function and the sink-reporting replay interpret one shared
# representation.

class _Block:
    __slots__ = ("ops", "succs", "index")

    def __init__(self, index: int):
        self.ops: List[tuple] = []
        self.succs: List["_Block"] = []
        self.index = index

    def link(self, other: "_Block") -> None:
        if other is not None and other not in self.succs:
            self.succs.append(other)


class _Ctx:
    """Builder context: where ``break``/``continue``/``raise`` edges go."""

    __slots__ = ("break_to", "continue_to", "handlers")

    def __init__(self, break_to=None, continue_to=None, handlers=()):
        self.break_to = break_to
        self.continue_to = continue_to
        self.handlers = tuple(handlers)


class _CFG:
    def __init__(self):
        self.blocks: List[_Block] = []
        self.entry = self.new_block()
        self.exit = self.new_block()

    def new_block(self) -> _Block:
        b = _Block(len(self.blocks))
        self.blocks.append(b)
        return b

    # -- construction ------------------------------------------------------

    def build(self, body: Iterable[ast.stmt]) -> None:
        end = self._stmts(list(body), self.entry, _Ctx())
        if end is not None:
            end.link(self.exit)

    def _emit(self, cur: _Block, op: tuple, ctx: _Ctx) -> _Block:
        """Append ``op``; under a live ``try`` every op gets its own block
        with an exception edge to each handler (the raise may interrupt
        anywhere, so handlers join the environment after every statement)."""
        cur.ops.append(op)
        if ctx.handlers:
            nxt = self.new_block()
            cur.link(nxt)
            for h in ctx.handlers:
                cur.link(h)
            return nxt
        return cur

    def _stmts(self, body: List[ast.stmt], cur: Optional[_Block],
               ctx: _Ctx) -> Optional[_Block]:
        """Lower ``body`` starting at ``cur``; return the fall-through block
        (``None`` when every path terminated via return/break/continue)."""
        for stmt in body:
            if cur is None:  # unreachable tail: park it in a fresh island
                cur = self.new_block()
            cur = self._stmt(stmt, cur, ctx)
        return cur

    def _stmt(self, stmt: ast.stmt, cur: _Block,
              ctx: _Ctx) -> Optional[_Block]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return cur  # nested defs get their own analysis
        if isinstance(stmt, ast.If):
            cur = self._emit(cur, ("test", stmt.test, "`if` statement",
                                   stmt), ctx)
            then_b, else_b, after = (self.new_block(), self.new_block(),
                                     self.new_block())
            cur.link(then_b)
            cur.link(else_b)
            t_end = self._stmts(stmt.body, then_b, ctx)
            e_end = self._stmts(stmt.orelse, else_b, ctx)
            if t_end is not None:
                t_end.link(after)
            if e_end is not None:
                e_end.link(after)
            return after
        if isinstance(stmt, ast.While):
            header, body_b, after = (self.new_block(), self.new_block(),
                                     self.new_block())
            cur.link(header)
            header = self._emit(header, ("test", stmt.test,
                                         "`while` statement", stmt), ctx)
            header.link(body_b)
            loop_ctx = _Ctx(after, header, ctx.handlers)
            b_end = self._stmts(stmt.body, body_b, loop_ctx)
            if b_end is not None:
                b_end.link(header)
            if stmt.orelse:
                else_b = self.new_block()
                header.link(else_b)
                e_end = self._stmts(stmt.orelse, else_b, ctx)
                if e_end is not None:
                    e_end.link(after)
            else:
                header.link(after)
            return after
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            header, body_b, after = (self.new_block(), self.new_block(),
                                     self.new_block())
            cur.link(header)
            # the bind runs once per iteration: placing it in the header
            # lets taint computed at the bottom of the body flow back into
            # the next iteration's environment
            header = self._emit(header, ("forbind", stmt.target, stmt.iter,
                                         stmt), ctx)
            header.link(body_b)
            loop_ctx = _Ctx(after, header, ctx.handlers)
            b_end = self._stmts(stmt.body, body_b, loop_ctx)
            if b_end is not None:
                b_end.link(header)
            if stmt.orelse:
                else_b = self.new_block()
                header.link(else_b)
                e_end = self._stmts(stmt.orelse, else_b, ctx)
                if e_end is not None:
                    e_end.link(after)
            else:
                header.link(after)
            return after
        if isinstance(stmt, ast.Try):
            h_entries = [self.new_block() for _ in stmt.handlers]
            after = self.new_block()
            for h in h_entries:
                cur.link(h)  # the very first statement may raise
            body_ctx = _Ctx(ctx.break_to, ctx.continue_to,
                            tuple(h_entries) + ctx.handlers)
            b_end = self._stmts(stmt.body, cur, body_ctx)
            ends = []
            if b_end is not None:
                if stmt.orelse:
                    ends.append(self._stmts(stmt.orelse, b_end, ctx))
                else:
                    ends.append(b_end)
            for h, entry in zip(stmt.handlers, h_entries):
                ends.append(self._stmts(h.body, entry, ctx))
            if stmt.finalbody:
                fin = self.new_block()
                for e in ends:
                    if e is not None:
                        e.link(fin)
                f_end = self._stmts(stmt.finalbody, fin, ctx)
                if f_end is not None:
                    f_end.link(after)
            else:
                for e in ends:
                    if e is not None:
                        e.link(after)
            return after
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                cur = self._emit(cur, ("withbind", item.optional_vars,
                                       item.context_expr, stmt), ctx)
            return self._stmts(stmt.body, cur, ctx)
        if isinstance(stmt, ast.Assert):
            return self._emit(cur, ("test", stmt.test, "`assert` statement",
                                    stmt), ctx)
        if isinstance(stmt, ast.Assign):
            return self._emit(cur, ("assign", stmt.targets, stmt.value,
                                    stmt), ctx)
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is None:
                return cur
            return self._emit(cur, ("assign", [stmt.target], stmt.value,
                                    stmt), ctx)
        if isinstance(stmt, ast.AugAssign):
            return self._emit(cur, ("augassign", stmt.target, stmt.value,
                                    stmt), ctx)
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                cur = self._emit(cur, ("expr", stmt.value, stmt), ctx)
            cur.link(self.exit)
            return None
        if isinstance(stmt, ast.Raise):
            for v in (stmt.exc, stmt.cause):
                if v is not None:
                    cur = self._emit(cur, ("expr", v, stmt), ctx)
            for h in ctx.handlers:
                cur.link(h)
            cur.link(self.exit)
            return None
        if isinstance(stmt, ast.Break):
            if ctx.break_to is not None:
                cur.link(ctx.break_to)
            return None
        if isinstance(stmt, ast.Continue):
            if ctx.continue_to is not None:
                cur.link(ctx.continue_to)
            return None
        if isinstance(stmt, ast.Expr):
            return self._emit(cur, ("expr", stmt.value, stmt), ctx)
        # anything else (Import, Pass, Delete, Global, ...): scan child
        # expressions conservatively, no environment effect
        return self._emit(cur, ("other", stmt), ctx)


class TaintWalker:
    """Analyze one function body, reporting ``(node, kind, detail)`` sinks.

    ``kind`` is one of ``"convert"`` (explicit concretization call),
    ``"branch"`` (if/while/ternary/assert on a tainted test).

    ``walk(body)`` builds the body's CFG, solves the may-taint fixpoint,
    and replays every reachable block under its fixed-point entry
    environment.  ``expr_tainted``/``_scan_expr`` evaluate against the
    walker's *current* environment (``self.tainted``) -- before ``walk``
    that is the seed set, which is what lambda-body scans rely on.
    """

    def __init__(
        self,
        seeds: Iterable[str],
        source_call: Callable[[ast.Call], bool],
        on_sink: Callable[[ast.AST, str, str], None],
        branch_sinks: bool = True,
    ):
        self.tainted: Set[str] = set(seeds)
        self.seeds = frozenset(self.tainted)
        self.source_call = source_call
        self.on_sink = on_sink
        self.branch_sinks = branch_sinks
        self._reported: Set[Tuple[int, str]] = set()

    # -- expression taint --------------------------------------------------

    def expr_tainted(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            path = dotted(node)
            if path is not None and path in self.tainted:
                return True
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            callee = dotted(node.func) or ""
            method = (node.func.attr
                      if isinstance(node.func, ast.Attribute) else "")
            if (callee in CONVERTER_CALLS
                    or method in _CONVERTER_METHODS
                    or callee in _NEVER_TAINTED_CALLS):
                return False  # result is host-side by construction
            if self.source_call(node):
                return True
            return (any(self.expr_tainted(a) for a in node.args)
                    or any(self.expr_tainted(k.value) for k in node.keywords))
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return (self.expr_tainted(node.left)
                    or any(self.expr_tainted(c) for c in node.comparators))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or self.expr_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        return False

    # -- sink scan ---------------------------------------------------------

    def _report(self, node: ast.AST, kind: str, detail: str) -> None:
        key = (getattr(node, "lineno", -1), getattr(node, "col_offset", -1),
               kind, detail)
        if key in self._reported:
            return  # a loop header replays; each sink fires once
        self._reported.add(key)
        self.on_sink(node, kind, detail)

    def _scan_expr(self, node: ast.AST) -> None:
        """Find sinks inside one expression (ordered, lambda-aware)."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs get their own analysis
        if isinstance(node, ast.Lambda):
            sub = TaintWalker(
                self.tainted | {a.arg for a in node.args.args},
                self.source_call, self.on_sink, self.branch_sinks)
            sub._reported = self._reported
            sub._scan_expr(node.body)
            return
        if isinstance(node, ast.Call):
            callee = dotted(node.func) or ""
            method = (node.func.attr
                      if isinstance(node.func, ast.Attribute) else "")
            args_tainted = (
                any(self.expr_tainted(a) for a in node.args)
                or any(self.expr_tainted(k.value) for k in node.keywords))
            if callee in CONVERTER_CALLS and args_tainted:
                self._report(node, "convert", f"{callee}()")
            elif (method in _CONVERTER_METHODS
                    and self.expr_tainted(node.func.value)):
                self._report(node, "convert", f".{method}()")
        if isinstance(node, ast.IfExp) and self.branch_sinks:
            if self.expr_tainted(node.test):
                self._report(node, "branch", "conditional expression")
        for child in ast.iter_child_nodes(node):
            self._scan_expr(child)

    # -- environment effects -----------------------------------------------

    def _assign(self, target: ast.AST, value_tainted: bool) -> None:
        path = dotted(target)
        if path is not None:
            if value_tainted:
                self.tainted.add(path)
            else:
                self.tainted.discard(path)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign(e, value_tainted)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, value_tainted)

    def _apply(self, op: tuple) -> None:
        """Mutate ``self.tainted`` with one op's binding effect."""
        kind = op[0]
        if kind == "assign":
            _, targets, value, _ = op
            t = self.expr_tainted(value)
            for target in targets:
                self._assign(target, t)
        elif kind == "augassign":
            _, target, value, _ = op
            if self.expr_tainted(value):
                self._assign(target, True)
        elif kind == "forbind":
            _, target, it, _ = op
            self._assign(target, self.expr_tainted(it))
        elif kind == "withbind":
            _, var, ctx_expr, _ = op
            if var is not None:
                self._assign(var, self.expr_tainted(ctx_expr))

    def _scan_op(self, op: tuple) -> None:
        """Report the sinks one op can reach (run *before* its effect)."""
        kind = op[0]
        if kind == "assign":
            self._scan_expr(op[2])
        elif kind == "augassign":
            self._scan_expr(op[2])
        elif kind == "forbind":
            self._scan_expr(op[2])
        elif kind == "withbind":
            self._scan_expr(op[2])
        elif kind == "test":
            _, expr, label, stmt = op
            self._scan_expr(expr)
            if self.branch_sinks and self.expr_tainted(expr):
                self._report(stmt, "branch", label)
        elif kind == "expr":
            self._scan_expr(op[1])
        elif kind == "other":
            for child in ast.iter_child_nodes(op[1]):
                if isinstance(child, ast.expr):
                    self._scan_expr(child)

    # -- fixpoint ----------------------------------------------------------

    def walk(self, body: Iterable[ast.stmt]) -> None:
        cfg = _CFG()
        cfg.build(body)

        # worklist may-taint: in[b] = union(out[p] for p in preds(b)),
        # out[b] = transfer(b, in[b]); monotone (union join, effects applied
        # under growing environments only ever grow the union), so it
        # terminates in O(blocks * names) rounds
        in_env = {cfg.entry.index: frozenset(self.seeds)}
        work = [cfg.entry]
        while work:
            b = work.pop()
            env = in_env.get(b.index)
            if env is None:
                continue
            self.tainted = set(env)
            for op in b.ops:
                self._apply(op)
            out = frozenset(self.tainted)
            for s in b.succs:
                prev = in_env.get(s.index)
                merged = out if prev is None else (prev | out)
                if prev is None or merged != prev:
                    in_env[s.index] = merged
                    work.append(s)

        # replay reachable blocks in source order under their fixed-point
        # entry environments, reporting sinks as the single-pass walker did
        def first_line(b: _Block) -> int:
            for op in b.ops:  # every op carries its statement node last
                ln = getattr(op[-1], "lineno", None)
                if ln is not None:
                    return ln
            return 1 << 30

        for b in sorted(cfg.blocks, key=lambda b: (first_line(b), b.index)):
            env = in_env.get(b.index)
            if env is None or not b.ops:
                continue  # unreachable
            self.tainted = set(env)
            for op in b.ops:
                self._scan_op(op)
                self._apply(op)

        self.tainted = set(self.seeds)
