"""Shared pass: find every ``jax.jit``-wrapped function in the sweep.

SL002 (retrace hazards) needs each jitted function's static argument split,
SL003 (donation aliasing) needs the donated positions at call sites, and
SL004 (host sync) treats calls into jitted code as device-value sources --
so the discovery runs once per project and the result is cached.

Two spellings are recognized:

  * decorator form -- ``@jax.jit`` or
    ``@functools.partial(jax.jit, static_argnames=..., donate_argnums=...)``
    (bare ``partial`` too) directly on a ``def``;
  * assignment form -- ``g = jax.jit(f, donate_argnums=...)`` where ``f`` is
    a name or lambda; the wrapper is registered under ``g``.

Resolution at call sites is by *bare name* across the whole sweep (this repo
has no colliding jit names; a collision would simply merge their specs,
which at worst over-reports -- the right failure direction for a linter).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import call_keywords, dotted, iter_functions

__all__ = ["JitSpec", "jit_registry", "JIT_WRAPPER_PATHS"]

#: dotted callables recognized as the jit entry point
JIT_WRAPPER_PATHS = {"jax.jit", "jit"}
_PARTIAL_PATHS = {"functools.partial", "partial"}


@dataclasses.dataclass(frozen=True)
class JitSpec:
    """One jitted function: where it lives and how its arguments split."""

    name: str                    # bare registration name (call-site key)
    qualname: str
    relpath: str
    line: int
    params: Tuple[str, ...]      # positional-or-keyword parameter names
    static_argnames: frozenset
    static_argnums: Tuple[int, ...]
    donate_argnums: Tuple[int, ...]
    donate_argnames: frozenset
    func_node: Optional[ast.AST]  # the def/lambda, when syntactically present

    @property
    def traced_params(self) -> frozenset:
        """Parameter names whose values are traced (non-static)."""
        static = set(self.static_argnames)
        for i in self.static_argnums:
            if i < len(self.params):
                static.add(self.params[i])
        return frozenset(self.params) - static

    def donated_positions(self) -> Tuple[int, ...]:
        pos = list(self.donate_argnums)
        for n in self.donate_argnames:
            if n in self.params:
                pos.append(self.params.index(n))
        return tuple(sorted(set(pos)))


def _str_tuple(node: ast.expr) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str))
    return ()


def _int_tuple(node: ast.expr) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(
            e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int))
    return ()


def _jit_call_opts(call: ast.Call) -> Optional[dict]:
    """``jax.jit`` call (or partial over it) -> its keyword split, else None."""
    if dotted(call.func) not in JIT_WRAPPER_PATHS:
        return None
    kw = call_keywords(call)
    return {
        "static_argnames": frozenset(_str_tuple(kw["static_argnames"]))
        if "static_argnames" in kw else frozenset(),
        "static_argnums": _int_tuple(kw["static_argnums"])
        if "static_argnums" in kw else (),
        "donate_argnums": _int_tuple(kw["donate_argnums"])
        if "donate_argnums" in kw else (),
        "donate_argnames": frozenset(_str_tuple(kw["donate_argnames"]))
        if "donate_argnames" in kw else frozenset(),
    }


def _decorator_opts(dec: ast.expr) -> Optional[dict]:
    """Jit options from a decorator expression, if it is a jit decorator."""
    if dotted(dec) in JIT_WRAPPER_PATHS:  # bare @jax.jit
        return {"static_argnames": frozenset(), "static_argnums": (),
                "donate_argnums": (), "donate_argnames": frozenset()}
    if isinstance(dec, ast.Call):
        if dotted(dec.func) in _PARTIAL_PATHS and dec.args:
            inner = dec.args[0]
            if dotted(inner) in JIT_WRAPPER_PATHS:
                # partial(jax.jit, **opts): options live on the partial call
                fake = ast.Call(func=inner, args=[], keywords=dec.keywords)
                return _jit_call_opts(fake)
        return _jit_call_opts(dec)  # @jax.jit(static_argnames=...)
    return None


def _func_params(node: ast.AST) -> Tuple[str, ...]:
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
        return ()
    a = node.args
    names = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
    return tuple(names) + tuple(p.arg for p in a.kwonlyargs)


def _specs_for_file(relpath: str, tree: ast.AST) -> List[JitSpec]:
    specs: List[JitSpec] = []
    funcs = dict(iter_functions(tree))

    # decorator form
    for qual, node in funcs.items():
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            opts = _decorator_opts(dec)
            if opts is None:
                continue
            specs.append(JitSpec(
                name=node.name, qualname=qual, relpath=relpath,
                line=node.lineno, params=_func_params(node),
                func_node=node, **opts))
            break

    # assignment form: g = jax.jit(f_or_lambda, ...)
    by_name = {n.name: n for n in funcs.values()
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        target = dotted(node.targets[0])
        if target is None or not isinstance(node.value, ast.Call):
            continue
        opts = _jit_call_opts(node.value)
        if opts is None or not node.value.args:
            continue
        wrapped = node.value.args[0]
        func_node: Optional[ast.AST] = None
        if isinstance(wrapped, ast.Lambda):
            func_node = wrapped
        elif isinstance(wrapped, ast.Name):
            func_node = by_name.get(wrapped.id)
        specs.append(JitSpec(
            name=target.split(".")[-1], qualname=target, relpath=relpath,
            line=node.lineno, params=_func_params(func_node)
            if func_node is not None else (),
            func_node=func_node, **opts))
    return specs


def jit_registry(project) -> Dict[str, List[JitSpec]]:
    """Bare name -> every JitSpec registered under it, sweep-wide (cached)."""

    def build() -> Dict[str, List[JitSpec]]:
        reg: Dict[str, List[JitSpec]] = {}
        for rel, sf in sorted(project.files.items()):
            for spec in _specs_for_file(rel, sf.tree):
                reg.setdefault(spec.name, []).append(spec)
        return reg

    return project.cache("jit_registry", build)
