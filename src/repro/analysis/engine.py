"""symlint core: findings, rule registry, suppressions, baseline.

The analyzer parses every swept file once into a ``Project`` (source text,
AST, comment channel) and hands the whole project to each registered rule --
rules are free to be per-file (SL001) or cross-file (SL005 pairs sender
encoders in one module with receiver decoders in another).

Contracts enforced at this layer, shared by every rule:

  * **suppression** -- a ``# symlint: disable=SL001`` (or bare
    ``# symlint: disable``) comment on the finding's line silences it;
  * **baseline** -- grandfathered findings live in a committed JSON file
    (``.symlint-baseline.json``), keyed by a line-number-free fingerprint so
    unrelated edits don't invalidate entries; every entry carries a written
    justification, and entries that no longer match anything are reported as
    stale so the baseline can only shrink.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.astutil import line_comments

__all__ = [
    "Finding", "Rule", "RULES", "register", "SourceFile", "Project",
    "Baseline", "AnalysisResult", "analyze", "load_project",
    "DEFAULT_SWEEP", "BASELINE_NAME", "TODO_JUSTIFICATION",
]

#: repo-relative directories ``python -m repro.analysis`` sweeps by default
DEFAULT_SWEEP = ("src", "examples", "benchmarks")
BASELINE_NAME = ".symlint-baseline.json"
#: placeholder stamped on new baseline entries; entries still carrying it
#: are reported by ``--update-baseline`` (exit 1) so they cannot land
TODO_JUSTIFICATION = "TODO: justify or fix"

_DISABLE_RE = re.compile(r"symlint:\s*disable(?:=([A-Za-z0-9_,\s]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``message`` must be stable under unrelated edits (rules never embed line
    numbers in it) -- the baseline fingerprint hashes ``rule|path|message``.
    """

    rule: str
    path: str        # repo-relative, posix separators
    line: int
    col: int
    message: str
    context: str = ""    # enclosing function qualname, if any

    @property
    def fingerprint(self) -> str:
        key = f"{self.rule}|{self.path}|{self.message}"
        return hashlib.sha256(key.encode("utf-8")).hexdigest()[:16]

    def to_json(self) -> dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "col": self.col, "message": self.message,
            "context": self.context, "fingerprint": self.fingerprint,
        }


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    doc: str
    check: Callable[["Project"], Iterable[Finding]]
    tier: str = "ast"    # "ast": pure-interpreter; "deep": needs jax (SL006+)


RULES: Dict[str, Rule] = {}


def register(rule_id: str, name: str, doc: str, tier: str = "ast"):
    """Decorator: register ``check(project) -> Iterable[Finding]`` as a rule."""

    def wrap(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        RULES[rule_id] = Rule(id=rule_id, name=name, doc=doc, check=fn,
                              tier=tier)
        return fn

    return wrap


class SourceFile:
    """One parsed source file: text, AST, and the comment-channel markers."""

    def __init__(self, relpath: str, text: str):
        self.relpath = relpath
        self.text = text
        self.tree = ast.parse(text, filename=relpath)
        self.comments = line_comments(text)

    def disabled_rules(self, line: int) -> Optional[frozenset]:
        """Rules suppressed on ``line``; empty frozenset means *all* rules."""
        comment = self.comments.get(line)
        if comment is None:
            return None
        m = _DISABLE_RE.search(comment)
        if m is None:
            return None
        if m.group(1) is None:
            return frozenset()  # bare "symlint: disable": everything
        return frozenset(
            r.strip().upper() for r in m.group(1).split(",") if r.strip())

    def has_marker(self, line: int, marker: str) -> bool:
        """True when ``line`` carries the given comment annotation."""
        return marker in self.comments.get(line, "")


class Project:
    """The whole sweep, parsed once and shared by every rule."""

    def __init__(self, root: Path, files: Dict[str, SourceFile]):
        self.root = root
        self.files = files
        self._caches: Dict[str, object] = {}

    def cache(self, key: str, build: Callable[[], object]) -> object:
        """Memoize cross-rule shared passes (e.g. the jit registry)."""
        if key not in self._caches:
            self._caches[key] = build()
        return self._caches[key]

    def find_file(self, suffix: str) -> Optional[SourceFile]:
        """First file whose relpath ends with ``suffix`` (posix)."""
        for rel, sf in sorted(self.files.items()):
            if rel.endswith(suffix):
                return sf
        return None


def load_project(root: Path, paths: Sequence[Path]) -> Project:
    """Parse every ``.py`` under ``paths`` into a ``Project``.

    Files that fail to parse surface as a synthetic ``SL000`` finding from
    ``analyze`` rather than crashing the run (a syntax error in one file must
    not hide findings in the rest).
    """
    files: Dict[str, SourceFile] = {}
    errors: List[Tuple[str, str]] = []
    seen = set()
    for p in paths:
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in candidates:
            f = f.resolve()
            if f in seen:
                continue
            seen.add(f)
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            try:
                files[rel] = SourceFile(rel, f.read_text())
            except SyntaxError as e:
                errors.append((rel, f"line {e.lineno}: {e.msg}"))
    proj = Project(root, files)
    proj.parse_errors = errors  # type: ignore[attr-defined]
    return proj


class Baseline:
    """The committed grandfather file: fingerprint -> justification."""

    def __init__(self, path: Optional[Path]):
        self.path = path
        self.entries: Dict[str, dict] = {}
        if path is not None and path.exists():
            doc = json.loads(path.read_text())
            for e in doc.get("entries", []):
                self.entries[e["fingerprint"]] = e

    def __contains__(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def stale(self, findings: Iterable[Finding]) -> List[dict]:
        live = {f.fingerprint for f in findings}
        return [e for fp, e in sorted(self.entries.items()) if fp not in live]

    @staticmethod
    def write(path: Path, findings: Sequence[Finding],
              keep: Dict[str, dict]) -> int:
        """Write ``findings`` as the new baseline, carrying over any existing
        justification (new entries get an explicit TODO placeholder --
        a baseline entry without a reason is itself a review finding)."""
        entries = []
        seen = set()
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
            if f.fingerprint in seen:  # one entry covers every same-message site
                continue
            seen.add(f.fingerprint)
            prev = keep.get(f.fingerprint, {})
            entries.append({
                "fingerprint": f.fingerprint,
                "rule": f.rule,
                "file": f.path,
                "line": f.line,  # informational only; matching is by hash
                "message": f.message,
                "justification": prev.get(
                    "justification", TODO_JUSTIFICATION),
            })
        path.write_text(json.dumps(
            {"version": 1, "entries": entries}, indent=2) + "\n")
        return len(entries)

    @staticmethod
    def unjustified(path: Path) -> List[dict]:
        """Entries in the written baseline whose justification is still the
        TODO placeholder.  ``--update-baseline`` refuses (exit 1) while any
        exist: a grandfathered finding without a written reason is exactly
        the review debt the baseline exists to prevent.  Reading an old
        baseline stays lenient -- only (re)writing one enforces this."""
        if not path.exists():
            return []
        doc = json.loads(path.read_text())
        return [e for e in doc.get("entries", [])
                if e.get("justification", TODO_JUSTIFICATION).strip()
                == TODO_JUSTIFICATION]


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]              # actionable (not suppressed/baselined)
    baselined: List[Finding]
    suppressed: List[Finding]
    stale_baseline: List[dict]
    parse_errors: List[Tuple[str, str]]

    @property
    def exit_code(self) -> int:
        return 1 if (self.findings or self.parse_errors
                     or self.stale_baseline) else 0


def analyze(
    project: Project,
    rule_ids: Optional[Sequence[str]] = None,
    baseline: Optional[Baseline] = None,
    *,
    include_deep: bool = False,
) -> AnalysisResult:
    """Run the selected rules over ``project`` and partition the findings.

    By default only the pure-AST tier runs; ``include_deep=True`` adds the
    jax-importing rules (the caller must have run ``deep.prepare(project)``
    first -- deep rules read the prepared context off the project cache and
    report nothing when it is absent).  An explicit ``rule_ids`` overrides
    the tier filter either way.
    """
    import repro.analysis.rules  # noqa: F401  -- populates RULES on import

    if rule_ids is None:
        ids = [r for r in sorted(RULES)
               if include_deep or RULES[r].tier == "ast"]
    else:
        ids = list(rule_ids)
    unknown = [r for r in ids if r not in RULES]
    if unknown:
        raise ValueError(
            f"unknown rule ids {unknown}; known: {sorted(RULES)}")
    raw: List[Finding] = []
    for rid in ids:
        raw.extend(RULES[rid].check(project))
    raw.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    actionable, baselined, suppressed = [], [], []
    for f in raw:
        sf = project.files.get(f.path)
        disabled = sf.disabled_rules(f.line) if sf is not None else None
        if disabled is not None and (not disabled or f.rule in disabled):
            suppressed.append(f)
        elif baseline is not None and f in baseline:
            baselined.append(f)
        else:
            actionable.append(f)
    stale = baseline.stale(raw) if baseline is not None else []
    return AnalysisResult(
        findings=actionable, baselined=baselined, suppressed=suppressed,
        stale_baseline=stale,
        parse_errors=list(getattr(project, "parse_errors", [])),
    )
