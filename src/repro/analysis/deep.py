"""symlint deep tier: jaxpr-grounded verification of the perf contracts.

The AST tier (SL001-SL005) pattern-matches source text; this tier checks
what jax *actually compiles*.  Hot functions opt in with a registry
annotation on their ``def`` (or decorator) line:

    # symlint: entry(drive=stream, budget=0, shapes=table-step)
    # symlint: entry(pair=chunk/table, shapes=pair-chunk-table)

Annotation keys (any subset; comma-separated, order-free):

  * ``drive=<name>``  -- the scripted workload that exercises this entry
    (``stream``: the resident ``StreamServer`` grow/shrink/ingest cycle of
    ``benchmarks/check_bench.py``; ``chunked``: windowed encode/receive/
    finish passes; ``digitize``: repeated ``digitize_pieces`` calls;
    ``fleet``: repeated ``run_fleet`` slabs).  SL006 measures how many new
    programs the entry's jit cache gained during the drive's *measured*
    window (everything after the declared warm-up -- for ``stream`` that is
    server construction including the pretrace ladder).
  * ``budget=<int>``  -- the entry's retrace budget over that measured
    window.  The serving-loop entries declare ``budget=0``: steady state
    must never trace.
  * ``shapes=<builder>`` -- operand builder (a name from ``OPERANDS``, or
    inline space-separated specs like ``f32[4,8] i32[4]`` for fixtures).
    Entries with shapes are traced at representative configurations
    (capacity rungs, cadences k in {1, 2}, raw + pieces) for SL007's
    dtype/weak-type discipline scan and, when the jit declares donation,
    compiled for SL008's input-output aliasing check.
  * ``pair=<label>/<role>`` -- bitwise-contract pair registration, role
    ``slot`` or ``table``.  SL007 compares the two members' output trees
    leaf-for-leaf (dtype *and* weak type, via ``jax.eval_shape``; the slot
    member is vmapped by its builder so the trees align): an asymmetry is
    exactly the kind of silent upcast that breaks the per-slot == table
    bitwise equivalence the property batteries assert numerically at a few
    points.

``entry_registry`` is pure AST (importable without jax -- the CLI uses it
for ``--list``-style introspection); everything else lives behind
``prepare``, which imports jax lazily (forced to CPU), resolves each entry
to its live module attribute, runs the probes and drives once, and caches a
``DeepContext`` on the project for the SL006-SL008 rules to read.  Probe
and drive failures are recorded as errors and surfaced as findings by the
owning rule -- a contract that cannot be verified is a finding, not a pass.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import importlib
import importlib.util
import os
import re
import sys
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.astutil import iter_functions
from repro.analysis.engine import Project
from repro.analysis.jaxinfo import jit_registry

__all__ = [
    "Entry", "DeepContext", "entry_registry", "prepare", "OPERANDS",
    "DRIVES",
]

_ENTRY_RE = re.compile(r"symlint:\s*entry\(([^)]*)\)")

#: regression budget for warning-based 64-bit detection: under the default
#: (x64-off) config an explicit 64-bit dtype request is *truncated* with
#: this UserWarning -- the only spoor a float64 upcast leaves in the jaxpr
_TRUNCATE_RE = re.compile(
    r"requested dtype.*64|truncated to dtype", re.IGNORECASE)


def _split_args(argstr: str) -> List[str]:
    """Split on top-level commas (inline shape specs carry ``[4,8]``)."""
    parts, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "[(":
            depth += 1
        elif ch in "])":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


@dataclasses.dataclass
class Entry:
    """One ``# symlint: entry(...)`` registration (module-level def)."""

    relpath: str
    qualname: str
    line: int
    drive: Optional[str] = None
    budget: int = 0
    shapes: Optional[str] = None
    pair_label: Optional[str] = None
    pair_role: Optional[str] = None
    # resolved by prepare():
    module: object = None
    fn: object = None

    @property
    def where(self) -> str:
        return f"{self.relpath}:{self.qualname}"


def _parse_entry(relpath: str, qualname: str, line: int,
                 argstr: str) -> Tuple[Optional[Entry], Optional[str]]:
    e = Entry(relpath=relpath, qualname=qualname, line=line)
    for part in _split_args(argstr):
        if "=" not in part:
            return None, f"entry() arg {part!r} is not key=value"
        key, _, val = part.partition("=")
        key, val = key.strip(), val.strip()
        if key == "drive":
            e.drive = val
        elif key == "budget":
            try:
                e.budget = int(val)
            except ValueError:
                return None, f"entry() budget {val!r} is not an int"
        elif key == "shapes":
            e.shapes = val
        elif key == "pair":
            label, sep, role = val.partition("/")
            if not sep or role not in ("slot", "table"):
                return None, (f"entry() pair {val!r} must be "
                              f"<label>/slot or <label>/table")
            e.pair_label, e.pair_role = label, role
        else:
            return None, f"entry() key {key!r} unknown"
    if e.drive is None and e.shapes is None:
        return None, "entry() needs at least drive= or shapes="
    return e, None


def entry_registry(project: Project) -> Tuple[List[Entry], List[Tuple[str, int, str]]]:
    """All entry annotations in the sweep (pure AST; no jax import).

    Returns ``(entries, errors)`` where each error is ``(relpath, line,
    message)`` -- malformed annotations and annotations on nested defs are
    errors, not silent skips.
    """

    def build():
        entries: List[Entry] = []
        errors: List[Tuple[str, int, str]] = []
        for rel, sf in sorted(project.files.items()):
            claimed = set()
            for qual, node in iter_functions(sf.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                lines = [node.lineno] + [d.lineno
                                         for d in node.decorator_list]
                for ln in lines:
                    m = _ENTRY_RE.search(sf.comments.get(ln, ""))
                    if m is None:
                        continue
                    claimed.add(ln)
                    if "." in qual:
                        errors.append(
                            (rel, ln, f"entry() on nested def {qual!r}: "
                             "entries must be module-level"))
                        continue
                    e, err = _parse_entry(rel, qual, node.lineno, m.group(1))
                    if err is not None:
                        errors.append((rel, ln, err))
                    else:
                        entries.append(e)
                    break
            for ln, comment in sf.comments.items():
                if ln not in claimed and _ENTRY_RE.search(comment):
                    errors.append(
                        (rel, ln, "entry() annotation not attached to any "
                         "function def/decorator line"))
        return entries, errors

    return project.cache("deep_entries", build)


# --------------------------------------------------------------------------
# runtime context

@dataclasses.dataclass
class Probe:
    """One traced/compiled call configuration of an entry."""

    tag: str            # pair-matching key ("k=1", "span", ...)
    fn: object          # callable to trace (slot pairs: vmapped wrapper)
    args: tuple
    kwargs: dict
    direct: bool        # fn IS the entry attribute (lower()-able if jitted)


@dataclasses.dataclass
class TraceReport:
    entry: Entry
    tag: str
    warnings_64: List[str]
    jaxpr_64: List[str]          # 64-bit convert/output dtypes in the jaxpr
    out_shape: object = None     # eval_shape result (pair comparison)


@dataclasses.dataclass
class PairReport:
    label: str
    tag: str
    slot: Entry
    table: Entry
    mismatches: List[str]        # "leaf: slot=f32 table=f64(weak)" strings


@dataclasses.dataclass
class DonationReport:
    entry: Entry
    tag: str
    aliased: bool                # input_output_alias present in executable
    dropped_warning: Optional[str]


@dataclasses.dataclass
class DeepContext:
    entries: List[Entry]
    traces: List[TraceReport]
    pairs: List[PairReport]
    donations: List[DonationReport]
    drives: Dict[str, Dict[str, int]]   # drive -> qualname -> new compiles
    errors: List[Tuple[str, Optional[Entry], str]]  # (stage, entry, message)


class _Rt:
    """Lazy jax namespace handed to builders and drives."""

    def __init__(self):
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax
        import jax.numpy as jnp
        import numpy as np
        self.jax, self.jnp, self.np = jax, jnp, np

    def small_cfg(self, mod):
        """Representative config, sized so tracing stays in seconds."""
        return mod.SymEDConfig(tol=0.5, alpha=0.02, scl=1.0, k_min=3,
                               k_max=8, len_max=16, n_max=32, lloyd_iters=2)


# --------------------------------------------------------------------------
# operand builders
#
# Each builder returns the probe list for one entry: tiny-but-representative
# shapes, cadences k in {1, 2} where the cadence is part of the contract.
# Builders pull constructors off the *entry's own module* (``receiver_init``
# etc. are imported there), so a test sweeping a mutated copy of a repo file
# probes the copy, not the installed module.

_S, _C, _P, _NMAX = 2, 8, 4, 32


def _b_table_step(rt, mod, fn):
    cfg = rt.small_cfg(mod)
    tab = rt.jax.vmap(lambda k: mod.receiver_init(cfg, k))(
        rt.jax.random.split(rt.jax.random.key(0), _S))
    w = rt.jnp.zeros((_S, _C), rt.jnp.float32)
    nv = rt.jnp.full((_S,), _C, rt.jnp.int32)
    return [Probe(f"k={k}", fn, (tab, w, nv),
                  dict(cfg=cfg, digitize_every_k=k, use_kernel=False), True)
            for k in (1, 2)]


def _b_table_step_pieces(rt, mod, fn):
    cfg = rt.small_cfg(mod)
    tab = rt.jax.vmap(lambda k: mod.receiver_init(cfg, k))(
        rt.jax.random.split(rt.jax.random.key(0), _S))
    pe = rt.jnp.zeros((_S, _C), rt.jnp.float32)
    ps = rt.jnp.zeros((_S, _C), rt.jnp.int32)
    nv = rt.jnp.full((_S,), _P, rt.jnp.int32)
    hello = rt.jnp.zeros((_S,), rt.jnp.float32)
    tsn = rt.jnp.full((_S,), _C, rt.jnp.int32)
    return [Probe(f"k={k}", fn, (tab, pe, ps, nv, hello, tsn),
                  dict(cfg=cfg, digitize_every_k=k, use_kernel=False), True)
            for k in (1, 2)]


def _pair_state(rt, mod):
    cfg = rt.small_cfg(mod)
    tab = rt.jax.vmap(lambda k: mod.receiver_init(cfg, k))(
        rt.jax.random.split(rt.jax.random.key(0), _S))
    return cfg, tab


def _b_pair_chunk_slot(rt, mod, fn):
    cfg, tab = _pair_state(rt, mod)
    w = rt.jnp.zeros((_S, _C), rt.jnp.float32)
    nv = rt.jnp.full((_S,), _C, rt.jnp.int32)
    return [Probe(
        f"k={k}",
        rt.jax.vmap(lambda w1, n1, s1, _k=k: fn(
            w1, n1, cfg, s1, digitize_every_k=_k)),
        (w, nv, tab), {}, False) for k in (1, 2)]


def _b_pair_chunk_table(rt, mod, fn):
    cfg, tab = _pair_state(rt, mod)
    w = rt.jnp.zeros((_S, _C), rt.jnp.float32)
    nv = rt.jnp.full((_S,), _C, rt.jnp.int32)
    return [Probe(
        f"k={k}",
        lambda w1, n1, t1, _k=k: fn(w1, n1, cfg, t1, digitize_every_k=_k),
        (w, nv, tab), {}, False) for k in (1, 2)]


def _pieces_operands(rt):
    pe = rt.jnp.zeros((_S, _P), rt.jnp.float32)
    ps = rt.jnp.zeros((_S, _P), rt.jnp.int32)
    nv = rt.jnp.full((_S,), _P, rt.jnp.int32)
    hello = rt.jnp.zeros((_S,), rt.jnp.float32)
    tsn = rt.jnp.full((_S,), _C, rt.jnp.int32)
    return pe, ps, nv, hello, tsn


def _b_pair_pieces_slot(rt, mod, fn):
    cfg, tab = _pair_state(rt, mod)
    ops = _pieces_operands(rt)
    return [Probe(
        f"k={k}",
        rt.jax.vmap(lambda a, b, c, d, e, s1, _k=k: fn(
            a, b, c, d, e, cfg, s1, digitize_every_k=_k)),
        ops + (tab,), {}, False) for k in (1, 2)]


def _b_pair_pieces_table(rt, mod, fn):
    cfg, tab = _pair_state(rt, mod)
    ops = _pieces_operands(rt)
    return [Probe(
        f"k={k}",
        lambda a, b, c, d, e, t1, _k=k: fn(
            a, b, c, d, e, cfg, t1, digitize_every_k=_k),
        ops + (tab,), {}, False) for k in (1, 2)]


def _span_operands(rt, mod):
    dst = rt.jax.vmap(lambda k: mod.digitizer_init(_NMAX, 8, k))(
        rt.jax.random.split(rt.jax.random.key(0), _S))
    lens = rt.jnp.zeros((_S, _NMAX), rt.jnp.float32)
    incs = rt.jnp.zeros((_S, _NMAX), rt.jnp.float32)
    lo = rt.jnp.zeros((_S,), rt.jnp.int32)
    hi = rt.jnp.full((_S,), _P, rt.jnp.int32)
    return dst, lens, incs, lo, hi


_SPAN_KW = dict(tol=0.5, scl=1.0, k_min=3, k_max_active=8, lloyd_iters=2)


def _b_pair_span_slot(rt, mod, fn):
    ops = _span_operands(rt, mod)
    return [Probe(
        "span",
        rt.jax.vmap(lambda s1, l1, i1, lo1, hi1: fn(
            s1, l1, i1, lo1, hi1, **_SPAN_KW)),
        ops, {}, False)]


def _b_pair_span_table(rt, mod, fn):
    ops = _span_operands(rt, mod)
    return [Probe("span", lambda *a: fn(*a, **_SPAN_KW), ops, {}, False)]


def _b_digitize_pieces(rt, mod, fn):
    lens = rt.jnp.zeros((_NMAX,), rt.jnp.float32)
    incs = rt.jnp.zeros((_NMAX,), rt.jnp.float32)
    n = rt.jnp.asarray(_P, rt.jnp.int32)
    key = rt.jax.random.key(0)
    return [Probe("pieces", fn, (lens, incs, n, key),
                  dict(k_cap=8, tol=0.5, scl=1.0, k_min=3, k_max_active=8,
                       lloyd_iters=2), True)]


def _b_encode_chunk(rt, mod, fn):
    chunk = rt.jnp.zeros((_C,), rt.jnp.float32)
    return [Probe("first", fn, (chunk, None),
                  dict(tol=0.5, alpha=0.02, len_max=16, first=True), True)]


def _b_receive_chunk(rt, mod, fn):
    chunk = rt.jnp.zeros((_C,), rt.jnp.float32)
    key = rt.jax.random.key(0)
    return [Probe(f"k={k}", fn, (chunk, None, key),
                  dict(tol=0.5, alpha=0.02, scl=1.0, len_max=16, n_max=_NMAX,
                       k_min=3, k_max=8, lloyd_iters=2, digitize_every_k=k,
                       first=True), True) for k in (1, 2)]


def _b_receive_finish(rt, mod, fn):
    cfg = rt.small_cfg(mod)
    state = mod.receiver_init(cfg, rt.jax.random.key(0))
    ts = rt.jnp.zeros((1,), rt.jnp.float32)
    return [Probe("finish", fn, (state, ts),
                  dict(tol=0.5, scl=1.0, n_max=_NMAX, k_min=3, k_max=8,
                       lloyd_iters=2, reconstruct=False, with_delta=True),
                  True)]


OPERANDS: Dict[str, Callable] = {
    "table-step": _b_table_step,
    "table-step-pieces": _b_table_step_pieces,
    "pair-chunk-slot": _b_pair_chunk_slot,
    "pair-chunk-table": _b_pair_chunk_table,
    "pair-pieces-slot": _b_pair_pieces_slot,
    "pair-pieces-table": _b_pair_pieces_table,
    "pair-span-slot": _b_pair_span_slot,
    "pair-span-table": _b_pair_span_table,
    "digitize-pieces": _b_digitize_pieces,
    "encode-chunk": _b_encode_chunk,
    "receive-chunk": _b_receive_chunk,
    "receive-finish": _b_receive_finish,
}

_SPEC_RE = re.compile(r"^(f16|bf16|f32|f64|i32|i64|u32|u64|bool)"
                      r"\[([0-9,\s]*)\]$")
_SPEC_DTYPES = {"f16": "float16", "bf16": "bfloat16", "f32": "float32",
                "f64": "float64", "i32": "int32", "i64": "int64",
                "u32": "uint32", "u64": "uint64", "bool": "bool"}


def _inline_probes(rt, fn, spec: str) -> List[Probe]:
    """``shapes=f32[4,8] i32[4]`` -> one probe with zero-filled operands."""
    args = []
    for tok in spec.split():
        m = _SPEC_RE.match(tok)
        if m is None:
            raise ValueError(f"bad inline shape spec {tok!r}")
        shape = tuple(int(d) for d in m.group(2).replace(" ", "").split(",")
                      if d)
        args.append(rt.jnp.zeros(shape, _SPEC_DTYPES[m.group(1)]))
    return [Probe("inline", fn, tuple(args), {}, True)]


# --------------------------------------------------------------------------
# drives (SL006): warm up, snapshot each entry's jit cache, run the
# measured script, report the delta

def _cache_sizes(entries):
    return {e.qualname: e.fn._cache_size() for e in entries}


def _drive_stream(rt, entries) -> Dict[str, int]:
    """The check_bench cache-flatness script, generalized: a pretrace-warmed
    autoscaled server (capacity ladder 1 -> 2) serves two grow/shrink
    cycles of mixed raw + pieces sessions; the measured window starts after
    construction, so deleting the pretrace warm-up makes the first ingest
    compile inside the window."""
    mod = entries[0].module
    cfg = rt.small_cfg(mod)
    srv = mod.StreamServer(cfg, max_sessions=2, window_cap=_C,
                           autoscale=True, min_slots=1, shrink_patience=1,
                           pretrace=True)
    base = _cache_sizes(entries)
    rng = rt.np.random.default_rng(0)
    for cycle in range(2):
        raw, pcs = f"r{cycle}", f"p{cycle}"
        srv.open(raw)
        srv.open(pcs)  # 1 -> 2 slots: grow
        srv.ingest(raw, rng.normal(size=_C).astype(rt.np.float32))
        srv.ingest_pieces_many({pcs: {
            "endpoints": rng.normal(size=3).astype(rt.np.float32),
            "steps": rt.np.array([2, 5, 7], rt.np.int32),
            "t_seen": _C, "t0": 0.0,
        }})
        srv.close(raw)
        srv.close(pcs)  # back to 1 slot: shrink
    return {q: _cache_sizes(entries)[q] - base[q] for q in base}


def _drive_chunked(rt, entries) -> Dict[str, int]:
    """Windowed encode -> finish and receive -> finish passes at cadences
    k in {1, 2}; warm-up is one full pass, the measured window a second
    pass over different data at the same shapes."""
    mod = entries[0].module
    cfg = rt.small_cfg(mod)
    key = rt.jax.random.key(0)

    def one_pass(seed):
        rng = rt.np.random.default_rng(seed)
        ts = rng.normal(size=4 * _C).astype(rt.np.float32)
        for k in (1, 2):
            st, evs = None, []
            for i in range(0, len(ts), _C):
                st, ev = mod.symed_encode_chunk(ts[i:i + _C], cfg, st)
                evs.append(ev)
            events = {name: rt.jnp.concatenate([e[name] for e in evs],
                                               axis=-1) for name in evs[0]}
            mod.symed_finish(events, st, cfg, key, ts)
            rs = None
            for i in range(0, len(ts), _C):
                rs, _ = mod.symed_receive_chunk(ts[i:i + _C], cfg, rs, key,
                                                digitize_every_k=k)
            mod.symed_receive_finish(rs, cfg, None, False, with_delta=True)

    one_pass(0)
    base = _cache_sizes(entries)
    one_pass(1)
    return {q: _cache_sizes(entries)[q] - base[q] for q in base}


def _drive_digitize(rt, entries) -> Dict[str, int]:
    mod = entries[0].module
    key = rt.jax.random.key(0)

    def call(seed):
        rng = rt.np.random.default_rng(seed)
        lens = rt.np.abs(rng.normal(size=_NMAX)).astype(rt.np.float32)
        incs = rng.normal(size=_NMAX).astype(rt.np.float32)
        mod.digitize_pieces(lens, incs, rt.jnp.asarray(6, rt.jnp.int32), key,
                            k_cap=8, tol=0.5, scl=1.0, k_min=3,
                            k_max_active=8, lloyd_iters=2)

    call(0)
    base = _cache_sizes(entries)
    call(1)
    return {q: _cache_sizes(entries)[q] - base[q] for q in base}


def _drive_fleet(rt, entries) -> Dict[str, int]:
    """Two same-shape ``run_fleet`` slabs; the lru-cached shard_map runner
    must serve the second from its jit cache (repeat fleet runs pay
    trace+compile once per configuration)."""
    mod = entries[0].module
    cfg = rt.small_cfg(mod)
    mesh = mod.fleet_data_mesh(1)
    rng = rt.np.random.default_rng(0)

    def run(seed):
        data = rng.normal(size=(_S, 4 * _C)).astype(rt.np.float32)
        mod.run_fleet(data, cfg, rt.jax.random.key(seed), mesh,
                      chunk_len=_C, digitize_every_k=1, reconstruct=False,
                      axis="data")

    run(0)
    # the lru_cache returns the same jitted runner for this configuration
    runner = mod._mapped_runner(mesh, ("data",), cfg, _C, 1, False)
    base = runner._cache_size()
    run(1)
    delta = runner._cache_size() - base
    return {e.qualname: delta for e in entries}


DRIVES: Dict[str, Callable] = {
    "stream": _drive_stream,
    "chunked": _drive_chunked,
    "digitize": _drive_digitize,
    "fleet": _drive_fleet,
}


# --------------------------------------------------------------------------
# jaxpr / executable inspection

def _scan_jaxpr_64(jaxpr, hits) -> None:
    """Collect 64-bit float/complex conversions and outputs, recursively.

    Under the default x64-off config these *cannot* appear (requests are
    truncated, with a warning we capture separately); the scan keeps the
    rule honest if the tier ever runs under ``jax_enable_x64``."""
    import numpy as np

    def wide(dt) -> bool:
        try:
            dt = np.dtype(dt)
        except TypeError:
            # extended dtypes (PRNG keys) are 8 bytes but never float64
            return False
        return dt.kind in "fc" and dt.itemsize == 8

    for v in jaxpr.outvars:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is not None and wide(dt):
            hits.add(f"output {dt}")
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            dt = eqn.params.get("new_dtype")
            if wide(dt):
                hits.add(f"convert_element_type -> {dt}")
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                name = type(sub).__name__
                if name == "ClosedJaxpr":
                    _scan_jaxpr_64(sub.jaxpr, hits)
                elif name == "Jaxpr":
                    _scan_jaxpr_64(sub, hits)


def _leaf_sig(rt, tree) -> List[Tuple[str, str]]:
    flat = rt.jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        weak = " (weak)" if getattr(leaf, "weak_type", False) else ""
        out.append((rt.jax.tree_util.keystr(path), f"{leaf.dtype}{weak}"))
    return out


# --------------------------------------------------------------------------
# module resolution

def _load_module(root, relpath: str):
    """Repo files import as ``repro.*`` (so jitted module attrs are the real
    live objects); anything else (test fixtures) loads from its file path
    under a content-hashed synthetic name."""
    if relpath.startswith("src/") and relpath.endswith(".py"):
        mod_name = relpath[len("src/"):-len(".py")].replace("/", ".")
        if mod_name.endswith(".__init__"):
            mod_name = mod_name[:-len(".__init__")]
        return importlib.import_module(mod_name)
    path = root / relpath
    digest = hashlib.sha1(path.read_bytes()).hexdigest()[:12]
    name = f"_symlint_deep_{digest}"
    if name in sys.modules:
        return sys.modules[name]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    # registered before exec: dataclass/typing machinery in the loaded file
    # looks itself up through sys.modules
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
    except BaseException:
        sys.modules.pop(name, None)
        raise
    return mod


# --------------------------------------------------------------------------
# prepare

def prepare(project: Project) -> DeepContext:
    """Resolve, trace, compile, and drive every registered entry (cached).

    Must run before ``analyze(..., include_deep=True)``; the SL006-SL008
    rules read the returned context off ``project._caches['deep']``.
    """

    def build() -> DeepContext:
        entries, reg_errors = entry_registry(project)
        errors: List[Tuple[str, Optional[Entry], str]] = [
            ("registry", Entry(relpath=rel, qualname="", line=ln), msg)
            for rel, ln, msg in reg_errors]
        rt = _Rt()

        resolved: List[Entry] = []
        for e in entries:
            try:
                e.module = _load_module(project.root, e.relpath)
                e.fn = getattr(e.module, e.qualname)
            except Exception as exc:  # noqa: BLE001 -- surfaced as finding
                errors.append(("resolve", e, f"{type(exc).__name__}: {exc}"))
                continue
            resolved.append(e)

        # -- probes: trace + warning capture + pair shapes ------------------
        traces: List[TraceReport] = []
        probe_lists: Dict[Tuple[str, str], List[Probe]] = {}
        jits = jit_registry(project)
        for e in resolved:
            if e.shapes is None:
                continue
            try:
                if e.shapes in OPERANDS:
                    probes = OPERANDS[e.shapes](rt, e.module, e.fn)
                else:
                    probes = _inline_probes(rt, e.fn, e.shapes)
            except Exception as exc:  # noqa: BLE001
                errors.append(("operands", e,
                               f"{type(exc).__name__}: {exc}"))
                continue
            probe_lists[(e.relpath, e.qualname)] = probes
            for probe in probes:
                # jitted entries must trace through their own wrapper:
                # make_jaxpr/eval_shape know nothing of static_argnames and
                # would feed tracers into the static parameters
                jitted = probe.direct and hasattr(probe.fn, "trace")
                try:
                    with warnings.catch_warnings(record=True) as ws:
                        warnings.simplefilter("always")
                        if jitted:
                            closed = probe.fn.trace(
                                *probe.args, **probe.kwargs).jaxpr
                            out_shape = probe.fn.eval_shape(
                                *probe.args, **probe.kwargs)
                        else:
                            closed = rt.jax.make_jaxpr(probe.fn)(
                                *probe.args, **probe.kwargs)
                            out_shape = rt.jax.eval_shape(
                                probe.fn, *probe.args, **probe.kwargs)
                except Exception as exc:  # noqa: BLE001
                    errors.append(
                        ("trace", e, f"[{probe.tag}] "
                         f"{type(exc).__name__}: {exc}"))
                    continue
                w64 = sorted({str(w.message) for w in ws
                              if _TRUNCATE_RE.search(str(w.message))})
                hits: set = set()
                _scan_jaxpr_64(closed.jaxpr, hits)
                traces.append(TraceReport(
                    entry=e, tag=probe.tag, warnings_64=w64,
                    jaxpr_64=sorted(hits), out_shape=out_shape))

        # -- pairs: leaf-for-leaf dtype/weak-type comparison ----------------
        pairs: List[PairReport] = []
        by_label: Dict[str, Dict[str, Entry]] = {}
        for e in resolved:
            if e.pair_label is not None:
                by_label.setdefault(e.pair_label, {})[e.pair_role] = e
        shape_of = {(t.entry.relpath, t.entry.qualname, t.tag): t.out_shape
                    for t in traces}
        for label, roles in sorted(by_label.items()):
            if set(roles) != {"slot", "table"}:
                only = next(iter(roles.values()))
                errors.append(("pair", only,
                               f"pair {label!r} is missing its "
                               f"{'table' if 'slot' in roles else 'slot'} "
                               "member"))
                continue
            slot, table = roles["slot"], roles["table"]
            slot_probes = probe_lists.get((slot.relpath, slot.qualname), [])
            for probe in slot_probes:
                a = shape_of.get((slot.relpath, slot.qualname, probe.tag))
                b = shape_of.get((table.relpath, table.qualname, probe.tag))
                if a is None or b is None:
                    continue  # trace already failed; error recorded above
                sa, sb = _leaf_sig(rt, a), _leaf_sig(rt, b)
                if [x[0] for x in sa] != [x[0] for x in sb]:
                    mism = ["output tree structures differ"]
                else:
                    mism = [f"{pa}: slot={da} table={db}"
                            for (pa, da), (_, db) in zip(sa, sb) if da != db]
                pairs.append(PairReport(label=label, tag=probe.tag,
                                        slot=slot, table=table,
                                        mismatches=mism))

        # -- donation: lower + compile, check the executable aliases --------
        donations: List[DonationReport] = []
        for e in resolved:
            probes = probe_lists.get((e.relpath, e.qualname), [])
            spec = next((s for s in jits.get(e.qualname, [])
                         if s.relpath == e.relpath), None)
            declared = spec is not None and spec.donated_positions()
            if not declared:
                continue
            for probe in probes:
                if not probe.direct:
                    continue
                try:
                    with warnings.catch_warnings(record=True) as ws:
                        warnings.simplefilter("always")
                        compiled = probe.fn.lower(
                            *probe.args, **probe.kwargs).compile()
                        text = compiled.as_text()
                except Exception as exc:  # noqa: BLE001
                    errors.append(
                        ("compile", e, f"[{probe.tag}] "
                         f"{type(exc).__name__}: {exc}"))
                    continue
                dropped = next(
                    (str(w.message) for w in ws
                     if "donated" in str(w.message).lower()), None)
                donations.append(DonationReport(
                    entry=e, tag=probe.tag,
                    aliased="input_output_alias" in text,
                    dropped_warning=dropped))

        # -- drives: warm-up, snapshot, measured window ---------------------
        drive_results: Dict[str, Dict[str, int]] = {}
        by_drive: Dict[str, List[Entry]] = {}
        for e in resolved:
            if e.drive is not None:
                by_drive.setdefault(e.drive, []).append(e)
        for name, group in sorted(by_drive.items()):
            fn = DRIVES.get(name)
            if fn is None:
                for e in group:
                    errors.append(("drive", e, f"unknown drive {name!r}"))
                continue
            missing = [e for e in group
                       if not hasattr(e.fn, "_cache_size")
                       and name != "fleet"]
            if missing:
                for e in missing:
                    errors.append(
                        ("drive", e, "entry is not a jitted callable "
                         "(no _cache_size); budget cannot be measured"))
                continue
            if len({id(e.module) for e in group}) != 1:
                for e in group:
                    errors.append(
                        ("drive", e, f"drive {name!r} spans multiple "
                         "modules; entries of one drive must share one"))
                continue
            try:
                drive_results[name] = fn(rt, group)
            except Exception as exc:  # noqa: BLE001
                for e in group:
                    errors.append(("drive", e,
                                   f"{type(exc).__name__}: {exc}"))
        return DeepContext(entries=resolved, traces=traces, pairs=pairs,
                           donations=donations, drives=drive_results,
                           errors=errors)

    return project.cache("deep", build)


def context(project: Project) -> Optional[DeepContext]:
    """The prepared context, or None when ``prepare`` has not run."""
    return project._caches.get("deep")
