"""SL005 wire-protocol consistency: encoder and decoder must agree, by bytes.

The transport's frame layouts (``repro/launch/transport.py``) and the
receiver's payload codecs (``repro/core/receiver.py``) are two halves of one
contract, written in two files.  A one-sided edit -- widening a count field,
reordering a header, changing a dtype -- type-checks, imports, and fails only
when real bytes cross the wire (or worse, *doesn't* fail and silently
mis-decodes).  This rule cross-checks the halves statically:

  * **token match** -- each codec pair must use the same multiset of struct
    format strings, dtype literals, record layouts, and pack/unpack helper
    calls (``encode_closed`` packs ``"!IIB"`` + a delta blob, so
    ``decode_closed`` must unpack ``"!IIB"`` + a delta blob);
  * **offset check** -- every fixed offset the decoder reads at
    (``unpack_from(fmt, buf, k)``, ``frombuffer(..., offset=k)``,
    ``payload[k:]``) must land on a boundary of the encoder's cumulative
    struct layout;
  * **pairing** -- if one half of a pair exists in the sweep and the other
    does not, that is itself a finding (inline decodes drift);
  * **constant contracts** -- the accounting constants
    (``DELTA_SYMBOL_BYTES`` etc.) must equal the byte width of the record
    layout they describe.

Functions are located by name anywhere in the sweep, so the rule (and its
mutation test) runs unchanged on fixture copies of the codec files.
"""
from __future__ import annotations

import ast
import re
import struct as struct_mod
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.astutil import dotted, iter_functions, walk_in_order
from repro.analysis.engine import Finding, Project, register

RULE = "SL005"

#: (encoder name, decoder name, check_offsets) -- bare function names,
#: resolved anywhere in the sweep
CODEC_PAIRS: Tuple[Tuple[str, str, bool], ...] = (
    ("encode_open", "decode_open", True),
    ("encode_data_raw", "decode_data_raw", True),
    ("encode_data_pieces", "decode_data_pieces", True),
    ("encode_close", "decode_close", True),
    ("encode_closed", "decode_closed", True),
    ("pack_delta_frame", "unpack_delta_frame", True),
    ("pack_piece_tuples", "unpack_piece_tuples", True),
    # framing layer: feed() parses length prefix before the body header, so
    # token order differs by design and offsets are dynamic (sid_len)
    ("_frame", "feed", False),
)

#: accounting constants tied to a record layout's byte width
CONST_REC_CONTRACTS = (
    ("DELTA_SYMBOL_BYTES", "_DELTA_REC"),
    ("PIECE_TUPLE_BYTES", "_PIECE_REC"),
)
#: accounting constants tied to an encoder's struct header width
CONST_HEADER_CONTRACTS = (
    ("DELTA_FRAME_HEADER_BYTES", "pack_delta_frame"),
)

_STRUCT_CALLS = {"struct.pack", "struct.unpack", "struct.unpack_from",
                 "struct.pack_into"}
_DTYPE_RE = re.compile(r"^[<>=|]?[a-zA-Z]\d+$")
_DTYPE_SINKS = ("frombuffer", "astype", "asarray", "empty", "zeros",
                "dtype", "array")


def _dtype_size(s: str) -> Optional[int]:
    m = re.match(r"^[<>=|]?[a-zA-Z](\d+)$", s)
    return int(m.group(1)) if m else None


def _calcsize(fmt: str) -> Optional[int]:
    try:
        return struct_mod.calcsize(fmt)
    except struct_mod.error:
        return None


def _rec_defs(project: Project) -> Dict[str, Tuple[str, ...]]:
    """Module-level ``NAME = np.dtype([("f", "u1"), ...])`` -> field dtypes."""
    recs: Dict[str, Tuple[str, ...]] = {}
    for rel, sf in sorted(project.files.items()):
        for node in sf.tree.body:
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and dotted(node.value.func) in ("np.dtype", "numpy.dtype")
                    and node.value.args):
                continue
            fields = node.value.args[0]
            if not isinstance(fields, (ast.List, ast.Tuple)):
                continue
            dts = []
            for f in fields.elts:
                if (isinstance(f, ast.Tuple) and len(f.elts) >= 2
                        and isinstance(f.elts[1], ast.Constant)
                        and isinstance(f.elts[1].value, str)):
                    dts.append(f.elts[1].value)
            recs[node.targets[0].id] = tuple(dts)
    return recs


class _Codec:
    """One codec function's extracted wire-shape evidence."""

    def __init__(self, rel: str, qual: str, node: ast.AST):
        self.rel = rel
        self.qual = qual
        self.node = node
        self.tokens: List[str] = []     # fmt:… / dtype:… / rec:… / blob:…
        self.fmts: List[str] = []       # struct formats, source order
        self.offsets: List[Tuple[int, ast.AST]] = []  # decoder read offsets

    def boundaries(self) -> Optional[set]:
        """Cumulative byte boundaries of the struct-format layout."""
        out, acc = {0}, 0
        for fmt in self.fmts:
            size = _calcsize(fmt)
            if size is None:
                return None
            acc += size
            out.add(acc)
        return out


def _int_const(node: Optional[ast.expr]) -> Optional[int]:
    if (isinstance(node, ast.Constant) and isinstance(node.value, int)
            and not isinstance(node.value, bool)):
        return node.value
    return None


def _extract(rel: str, qual: str, node: ast.AST,
             recs: Dict[str, Tuple[str, ...]]) -> _Codec:
    c = _Codec(rel, qual, node)
    for n in walk_in_order(node):
        if isinstance(n, ast.Subscript):
            sl = n.slice
            if (isinstance(sl, ast.Slice) and sl.upper is None
                    and sl.step is None):
                k = _int_const(sl.lower)
                if k is not None:
                    c.offsets.append((k, n))
            continue
        if not isinstance(n, ast.Call):
            continue
        callee = dotted(n.func) or ""
        bare = callee.split(".")[-1]
        if callee in _STRUCT_CALLS and n.args and isinstance(
                n.args[0], ast.Constant) and isinstance(n.args[0].value, str):
            fmt = n.args[0].value
            c.fmts.append(fmt)
            c.tokens.append(f"fmt:{fmt}")
            if bare == "unpack_from":
                k = _int_const(n.args[2]) if len(n.args) > 2 else None
                if k is None:
                    for kw in n.keywords:
                        if kw.arg == "offset":
                            k = _int_const(kw.value)
                if k is not None:
                    c.offsets.append((k, n))
            continue
        if bare.startswith(("pack_", "unpack_")):
            c.tokens.append(
                "blob:" + bare.split("_", 1)[1])
            continue
        if bare in _DTYPE_SINKS:
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and _DTYPE_RE.match(arg.value)):
                    c.tokens.append(f"dtype:{arg.value}")
                elif isinstance(arg, ast.Name) and arg.id in recs:
                    c.tokens.append(
                        "rec[" + ",".join(recs[arg.id]) + "]")
            if bare == "frombuffer":
                for kw in n.keywords:
                    if kw.arg == "offset":
                        k = _int_const(kw.value)
                        if k is not None:
                            c.offsets.append((k, n))
    return c


def _find_codec(project: Project, name: str,
                recs) -> Optional[_Codec]:
    for rel, sf in sorted(project.files.items()):
        for qual, node in iter_functions(sf.tree):
            if qual.split(".")[-1] == name and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return _extract(rel, qual, node, recs)
    return None


@register(
    RULE, "wire-consistency",
    "Sender encoders and receiver decoders must agree on struct formats, "
    "dtypes, record layouts, and fixed payload offsets; accounting "
    "constants must match the layouts they describe.",
)
def check(project: Project) -> Iterable[Finding]:
    recs = _rec_defs(project)
    findings: List[Finding] = []

    for enc_name, dec_name, check_offsets in CODEC_PAIRS:
        enc = _find_codec(project, enc_name, recs)
        dec = _find_codec(project, dec_name, recs)
        if enc is None and dec is None:
            continue
        if enc is None or dec is None:
            have = enc or dec
            missing = dec_name if dec is None else enc_name
            findings.append(Finding(
                rule=RULE, path=have.rel, line=have.node.lineno,
                col=have.node.col_offset, context=have.qual,
                message=(f"codec `{have.qual}` has no `{missing}` "
                         f"counterpart in the sweep: inline or missing "
                         f"{'decoders' if dec is None else 'encoders'} "
                         f"drift from the wire layout -- define the pair "
                         f"side by side")))
            continue

        if sorted(enc.tokens) != sorted(dec.tokens):
            enc_only = _diff(enc.tokens, dec.tokens)
            dec_only = _diff(dec.tokens, enc.tokens)
            findings.append(Finding(
                rule=RULE, path=dec.rel, line=dec.node.lineno,
                col=dec.node.col_offset, context=dec.qual,
                message=(f"wire layout mismatch between `{enc.qual}` and "
                         f"`{dec.qual}`: encoder-only {enc_only or '[]'}, "
                         f"decoder-only {dec_only or '[]'}")))

        if check_offsets:
            bounds = enc.boundaries()
            if bounds is not None:
                for k, n in dec.offsets:
                    if k not in bounds:
                        findings.append(Finding(
                            rule=RULE, path=dec.rel, line=n.lineno,
                            col=n.col_offset, context=dec.qual,
                            message=(f"`{dec.qual}` reads at fixed offset "
                                     f"{k}, but `{enc.qual}`'s struct "
                                     f"layout has boundaries "
                                     f"{sorted(bounds)}")))

    findings.extend(_constant_contracts(project, recs))
    return findings


def _diff(a: List[str], b: List[str]) -> List[str]:
    out = list(a)
    for t in b:
        if t in out:
            out.remove(t)
    return sorted(set(out))


def _num_consts(sf) -> Dict[str, Tuple[float, int]]:
    out = {}
    for node in sf.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, (int, float))
                and not isinstance(node.value.value, bool)):
            out[node.targets[0].id] = (float(node.value.value), node.lineno)
    return out


def _constant_contracts(project: Project, recs) -> List[Finding]:
    findings: List[Finding] = []
    for rel, sf in sorted(project.files.items()):
        consts = _num_consts(sf)
        for const_name, rec_name in CONST_REC_CONTRACTS:
            if const_name not in consts or rec_name not in recs:
                continue
            value, line = consts[const_name]
            sizes = [_dtype_size(d) for d in recs[rec_name]]
            if any(s is None for s in sizes):
                continue
            width = sum(sizes)
            if value != width:
                findings.append(Finding(
                    rule=RULE, path=rel, line=line, col=0,
                    message=(f"`{const_name}` is {value:g} but record "
                             f"layout `{rec_name}` is {width} bytes wide: "
                             f"wire accounting diverges from the bytes")))
        for const_name, enc_name in CONST_HEADER_CONTRACTS:
            if const_name not in consts:
                continue
            enc = _find_codec(project, enc_name, recs)
            if enc is None or not enc.fmts:
                continue
            width = _calcsize(enc.fmts[-1])
            if width is None:
                continue
            value, line = consts[const_name]
            if value != width:
                findings.append(Finding(
                    rule=RULE, path=rel, line=line, col=0,
                    message=(f"`{const_name}` is {value:g} but "
                             f"`{enc.qual}`'s header format "
                             f"`{enc.fmts[-1]}` is {width} bytes: wire "
                             f"accounting diverges from the bytes")))
    return findings
