"""SL006 retrace-budget: entries must not compile past their declared budget.

Generalizes ``benchmarks/check_bench.py``'s cache-flatness assertion into the
linter: every ``# symlint: entry(drive=..., budget=N)`` function is exercised
by its scripted drive (grow/shrink/ingest cycles for the stream server,
repeated same-shape passes for the chunked/digitize/fleet paths) after a
declared warm-up, and the number of *new* programs its jit cache gained
during the measured window must be <= the budget.  The serving-loop entries
declare ``budget=0``: steady state never traces.

Deep tier -- requires ``deep.prepare(project)`` to have run; silent when it
has not (the AST tier must stay importable and runnable without jax).
Preparation failures that make the budget unmeasurable (unresolvable entry,
crashed drive, malformed annotation) are findings, not passes.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.analysis.engine import Finding, Project, register
from repro.analysis import deep

RULE = "SL006"

_OWNED_STAGES = ("registry", "resolve", "drive")


@register(
    RULE, "retrace-budget",
    "A registered entry point compiled more new programs during its scripted "
    "drive's measured window than its declared trace budget allows.",
    tier="deep",
)
def check(project: Project) -> Iterable[Finding]:
    ctx = deep.context(project)
    if ctx is None:
        return []
    findings: List[Finding] = []
    for stage, entry, msg in ctx.errors:
        if stage not in _OWNED_STAGES:
            continue
        findings.append(Finding(
            rule=RULE, path=entry.relpath, line=entry.line or 1, col=0,
            context=entry.qualname,
            message=f"deep-tier {stage} failed for this entry: {msg}"))
    for e in ctx.entries:
        if e.drive is None or e.drive not in ctx.drives:
            continue
        delta = ctx.drives[e.drive].get(e.qualname)
        if delta is None or delta <= e.budget:
            continue
        findings.append(Finding(
            rule=RULE, path=e.relpath, line=e.line, col=0,
            context=e.qualname,
            message=(f"`{e.qualname}` compiled {delta} new program(s) during "
                     f"the `{e.drive}` drive's measured window, over its "
                     f"declared budget of {e.budget}: the steady-state "
                     f"serving loop is retracing")))
    return findings
