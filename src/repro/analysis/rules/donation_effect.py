"""SL008 donation-effectiveness: declared donation must actually alias.

``donate_argnums`` is a *request*: XLA drops it silently when the donated
input's shape/dtype/layout matches no output, and the only runtime spoor is
a UserWarning ("Some donated buffers were not usable").  On an edge node a
dropped donation doubles the resident table's memory high-water mark, so it
is a finding, not a nit.  For every registered entry whose jit declares
donation (per the AST jit registry), each representative probe is lowered
and compiled and the executable is checked for an ``input_output_alias``
annotation; a dropped-donation warning during compilation is reported with
the compiler's own message.

Deep tier -- silent when ``deep.prepare(project)`` has not run; compile
failures on a donation-declaring entry are findings.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.analysis.engine import Finding, Project, register
from repro.analysis import deep

RULE = "SL008"

_OWNED_STAGES = ("compile",)


@register(
    RULE, "donation-effectiveness",
    "A jitted entry declares donate_argnums but the compiled executable "
    "does not input-output-alias the donated operand (donation silently "
    "dropped).",
    tier="deep",
)
def check(project: Project) -> Iterable[Finding]:
    ctx = deep.context(project)
    if ctx is None:
        return []
    findings: List[Finding] = []
    for stage, entry, msg in ctx.errors:
        if stage not in _OWNED_STAGES:
            continue
        findings.append(Finding(
            rule=RULE, path=entry.relpath, line=entry.line or 1, col=0,
            context=entry.qualname,
            message=f"deep-tier {stage} failed for this entry: {msg}"))
    for d in ctx.donations:
        if d.aliased and d.dropped_warning is None:
            continue
        detail = (d.dropped_warning if d.dropped_warning is not None
                  else "no input_output_alias in the compiled executable")
        findings.append(Finding(
            rule=RULE, path=d.entry.relpath, line=d.entry.line, col=0,
            context=d.entry.qualname,
            message=(f"donation declared on `{d.entry.qualname}` [{d.tag}] "
                     f"was not honored by the compiler: {detail}")))
    return findings
