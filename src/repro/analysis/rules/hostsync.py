"""SL004 host-sync: no hidden device->host transfers in designated hot paths.

A ``np.asarray(...)``, ``.item()``, or implicit ``bool()`` on a device array
blocks until the device catches up -- one stray sync in the per-chunk
StreamServer step or the fleet slab loop serializes the whole pipeline (the
ROADMAP's resident_speedup regression was five of these per ingest round).

Hot paths are *designated in source*: a ``# symlint: hot-path`` comment on
(or directly under) a ``def`` line marks that function.  Inside it, values
returned by jitted functions (shared jit registry) or ``jnp.``/``jax.lax.``
calls are device-resident; flowing one into a concretization or a branch
test is a finding unless the line carries ``# sync: ok`` -- the annotation
is the documented, reviewed place where the transfer happens (ideally a
single batched ``jax.device_get`` per step).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.astutil import dotted, iter_functions
from repro.analysis.dataflow import TaintWalker
from repro.analysis.engine import Finding, Project, SourceFile, register
from repro.analysis.jaxinfo import jit_registry

RULE = "SL004"
HOT_PATH_MARKER = "symlint: hot-path"
SYNC_OK_MARKER = "sync: ok"

#: call prefixes whose results live on device
_DEVICE_PREFIXES = ("jnp.", "jax.numpy.", "jax.lax.", "lax.")


def _is_hot_path(sf: SourceFile, node: ast.AST) -> bool:
    """Marker on the decorator/def lines or the first body line."""
    first_body = node.body[0].lineno if getattr(node, "body", None) else \
        node.lineno
    start = min([node.lineno] + [d.lineno for d in
                                 getattr(node, "decorator_list", [])])
    return any(sf.has_marker(ln, HOT_PATH_MARKER)
               for ln in range(start, first_body + 1))


@register(
    RULE, "host-sync",
    "Functions marked `# symlint: hot-path` must not concretize or branch "
    "on device values except on lines annotated `# sync: ok`.",
)
def check(project: Project) -> Iterable[Finding]:
    registry = jit_registry(project)
    findings: List[Finding] = []

    def is_device_call(call: ast.Call) -> bool:
        callee = dotted(call.func) or ""
        if callee.startswith(_DEVICE_PREFIXES):
            return True
        bare = callee.split(".")[-1]
        return bare in registry

    for rel, sf in sorted(project.files.items()):
        for qual, node in iter_functions(sf.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _is_hot_path(sf, node):
                continue

            def on_sink(n: ast.AST, kind: str, detail: str,
                        qual=qual, rel=rel, sf=sf) -> None:
                line = n.lineno
                if sf.has_marker(line, SYNC_OK_MARKER):
                    return
                if kind == "branch":
                    msg = (f"{detail} tests a device value in hot path "
                           f"`{qual}`: the implicit bool() blocks on the "
                           f"device -- hoist one batched `jax.device_get` "
                           f"(annotated `# sync: ok`) and branch on the "
                           f"host copy")
                else:
                    msg = (f"{detail} on a device value in hot path "
                           f"`{qual}`: hidden device->host sync -- batch "
                           f"transfers into one `jax.device_get` per step "
                           f"and annotate it `# sync: ok`")
                findings.append(Finding(
                    rule=RULE, path=rel, line=line, col=n.col_offset,
                    message=msg, context=qual))

            TaintWalker((), is_device_call, on_sink).walk(node.body)
    return findings
