"""SL001 compat-policy: version-sensitive JAX/Pallas names stay in jax_compat.

ROADMAP standing policy: every API surface that was renamed across JAX
releases (Pallas TPU memory spaces, compiler params, ``dimension_semantics``,
``make_mesh`` axis types, ``shard_map``) is used through the feature-detected
shims in ``repro/utils/jax_compat.py`` -- never directly.  A direct use works
today and breaks on the next rename, silently for anyone not running the
jax-canary job.

The banned-name table is **read out of jax_compat's module docstring** (the
RST table that already documents each shim row): every ``pltpu.X`` /
``jax.x.y`` / ``kwarg=`` token between the table rules is banned outside the
compat module itself.  Adding a shim row to the docstring therefore *is*
extending the lint -- one source of truth.  When the sweep does not include
jax_compat.py (fixture runs), a frozen fallback copy of the table is used.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.astutil import dotted, iter_functions, parent_map
from repro.analysis.engine import Finding, Project, register

RULE = "SL001"
COMPAT_SUFFIX = "utils/jax_compat.py"

#: modules whose import aliases are tracked for banned-attribute checks
PLTPU_MODULE = "jax.experimental.pallas.tpu"

# Frozen copy of the jax_compat docstring table tokens, used only when the
# compat module itself is outside the sweep (unit-test fixtures).  Keep in
# sync with the docstring; the repo sweep always prefers the live docstring.
FALLBACK_TOKENS = (
    "pltpu.TPUMemorySpace", "pltpu.MemorySpace",
    "pltpu.TPUCompilerParams", "pltpu.CompilerParams",
    "dimension_semantics=", "GridDimensionSemantics",
    "pltpu.VMEM",
    "axis_types=",
    "jax.make_mesh",
    "jax.experimental.shard_map", "jax.shard_map",
    "check_rep=", "check_vma=",
    "jax.profiler.TraceAnnotation", "jax.profiler.TraceContext",
)

_TOKEN_RE = re.compile(r"``([^`]+)``")
_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")


def _docstring_tokens(project: Project) -> Tuple[str, ...]:
    sf = project.find_file(COMPAT_SUFFIX)
    if sf is None:
        return FALLBACK_TOKENS
    doc = ast.get_docstring(sf.tree) or ""
    # restrict to the RST table region (between the first and last ==== rule)
    rules = [m.start() for m in re.finditer(r"^=+\s+=+", doc, re.M)]
    region = doc[rules[0]: rules[-1]] if len(rules) >= 2 else doc
    tokens = []
    for tok in _TOKEN_RE.findall(region):
        tok = tok.strip()
        if tok.endswith("="):
            tokens.append(tok)
        elif _NAME_RE.match(tok):
            tokens.append(tok)
    return tuple(tokens) or FALLBACK_TOKENS


def _classify(tokens: Iterable[str]):
    """Split table tokens into banned kwargs / pltpu attrs / dotted paths."""
    kwargs: Set[str] = set()
    pltpu_attrs: Set[str] = set()
    paths: Set[str] = set()
    for tok in tokens:
        if tok.endswith("="):
            kwargs.add(tok[:-1])
        elif tok.startswith("pltpu."):
            pltpu_attrs.add(tok.split(".", 1)[1])
        elif "." in tok:
            paths.add(tok)
        else:  # bare class-like name (e.g. GridDimensionSemantics)
            pltpu_attrs.add(tok)
    return kwargs, pltpu_attrs, paths


def _pltpu_aliases(tree: ast.AST) -> Set[str]:
    """Local names bound to the Pallas TPU module by imports."""
    aliases: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for a in node.names:
                if f"{mod}.{a.name}" == PLTPU_MODULE:
                    aliases.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == PLTPU_MODULE:
                    aliases.add(a.asname or a.name.split(".")[0])
    return aliases


def _context_of(node, ctx_ranges) -> str:
    for qual, lo, hi in ctx_ranges:
        if lo <= node.lineno <= hi:
            return qual
    return ""


@register(
    RULE, "compat-policy",
    "Version-sensitive JAX/Pallas names must route through "
    "repro/utils/jax_compat.py (its docstring table is the banned list).",
)
def check(project: Project) -> Iterable[Finding]:
    kwargs, pltpu_attrs, paths = _classify(_docstring_tokens(project))
    findings: List[Finding] = []
    for rel, sf in sorted(project.files.items()):
        if rel.endswith(COMPAT_SUFFIX):
            continue
        aliases = _pltpu_aliases(sf.tree)
        parents = parent_map(sf.tree)
        ctx_ranges = [
            (q, n.lineno, max(n.lineno, getattr(n, "end_lineno", n.lineno)))
            for q, n in iter_functions(sf.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]

        def hit(node, message):
            findings.append(Finding(
                rule=RULE, path=rel, line=node.lineno,
                col=node.col_offset, message=message,
                context=_context_of(node, ctx_ranges)))

        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for a in node.names:
                    full = f"{mod}.{a.name}"
                    if full in paths or mod in paths:
                        hit(node, f"direct import of `{full}`: use the "
                                  f"shim in repro/utils/jax_compat.py")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name in paths:
                        hit(node, f"direct import of `{a.name}`: use the "
                                  f"shim in repro/utils/jax_compat.py")
            elif isinstance(node, ast.Attribute):
                # only outermost chains: `a.b.c` reports once, not per link
                par = parents.get(node)
                if isinstance(par, ast.Attribute) and par.value is node:
                    continue
                path = dotted(node)
                if path is None:
                    continue
                parts = path.split(".")
                if (len(parts) >= 2 and parts[0] in aliases
                        and parts[1] in pltpu_attrs):
                    hit(node, f"direct use of `pltpu.{parts[1]}`: import the "
                              f"shimmed name from repro/utils/jax_compat.py")
                elif path in paths or any(
                        path.startswith(p + ".") for p in paths):
                    hit(node, f"direct use of `{path}`: use the wrapper in "
                              f"repro/utils/jax_compat.py")
            elif isinstance(node, ast.Call):
                callee = dotted(node.func) or ""
                for kw in node.keywords:
                    if kw.arg in kwargs:
                        hit(kw.value,
                            f"version-sensitive kwarg `{kw.arg}=` passed to "
                            f"`{callee or '<call>'}`: use the compat helper "
                            f"in repro/utils/jax_compat.py")
    return findings
