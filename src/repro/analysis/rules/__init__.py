"""symlint rule modules -- importing this package populates the registry.

The deep-tier modules (retrace_budget, dtype_discipline, donation_effect)
register here too but import jax only inside ``deep.prepare`` -- importing
this package never pulls in jax, so the AST tier stays interpreter-only.
"""
from repro.analysis.rules import (  # noqa: F401
    compat, donation, donation_effect, dtype_discipline, hostsync, retrace,
    retrace_budget, wire,
)
