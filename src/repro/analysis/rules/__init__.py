"""symlint rule modules -- importing this package populates the registry."""
from repro.analysis.rules import (  # noqa: F401
    compat, donation, hostsync, retrace, wire,
)
