"""SL002 retrace-hazard: tracer misuse that forces recompiles (or crashes).

The resident-service regression tracked in ROADMAP ("resident_speedup 0.68x")
came from exactly this class of bug: code inside a jitted function treating a
tracer like a concrete value, or a call site feeding a static argument a
value that changes every call.  Four checks, all scoped by the shared jit
registry:

  (a) **branch on a traced argument** -- ``if``/``while``/ternary/``assert``
      whose test depends on a traced (non-static) parameter inside a jitted
      body.  Branching on *static* parameters is fine and idiomatic
      (``if first:`` in the SymED chunk kernels); ``x is None`` checks are
      exempt (None-ness is resolved at trace time, intentionally).
  (b) **concretization of a tracer** -- ``float()``/``int()``/``bool()``/
      ``.item()``/``.tolist()``/``np.asarray()`` applied to a value derived
      from a traced parameter inside a jitted body.
  (c) **non-static closure capture** -- a jitted ``def`` nested inside
      another function reads a name from the enclosing function's scope;
      the capture is baked into the trace as a constant and silently goes
      stale (or retraces) when the enclosing value changes.
  (d) **loop-varying static operand** -- a call to a jitted function where a
      static argument's expression uses a name rebound inside the enclosing
      loop: every distinct value is a fresh trace.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis.astutil import dotted, iter_functions, parent_map
from repro.analysis.dataflow import TaintWalker, assigned_names
from repro.analysis.engine import Finding, Project, register
from repro.analysis.jaxinfo import JitSpec, jit_registry

RULE = "SL002"


def _is_none_check(test: ast.expr) -> bool:
    return (isinstance(test, ast.Compare)
            and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Is, ast.IsNot))
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None)


def _body_checks(spec: JitSpec, findings: List[Finding]) -> None:
    """(a) + (b): taint traced params, flag branches and concretizations."""
    node = spec.func_node
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
        return
    traced = spec.traced_params
    if not traced:
        return

    def on_sink(n: ast.AST, kind: str, detail: str) -> None:
        if kind == "branch":
            test = getattr(n, "test", None)
            if test is not None and _is_none_check(test):
                return
            msg = (f"{detail} on a traced argument inside jitted "
                   f"`{spec.qualname}`: each concrete value forces a "
                   f"retrace -- use `jnp.where`/`lax.cond`, or declare the "
                   f"argument static")
        else:
            msg = (f"{detail} applied to a traced value inside jitted "
                   f"`{spec.qualname}`: tracers have no concrete value -- "
                   f"this raises at trace time or silently constant-folds")
        findings.append(Finding(
            rule=RULE, path=spec.relpath, line=n.lineno,
            col=n.col_offset, message=msg, context=spec.qualname))

    body = node.body if not isinstance(node, ast.Lambda) else None
    walker = TaintWalker(traced, lambda c: False, on_sink)
    if body is not None:
        walker.walk(body)
    else:
        walker._scan_expr(node.body)


def _closure_checks(project: Project, specs: List[JitSpec],
                    findings: List[Finding]) -> None:
    """(c): jitted defs nested in a function that read enclosing locals."""
    for spec in specs:
        node = spec.func_node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        sf = project.files.get(spec.relpath)
        if sf is None:
            continue
        parents = parent_map(sf.tree)
        enclosing = None
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                enclosing = cur
                break
            cur = parents.get(cur)
        if enclosing is None:
            continue  # module-level jit: module globals are fine

        enclosing_locals = assigned_names(enclosing)
        enclosing_locals.update(
            a.arg for a in enclosing.args.args + enclosing.args.kwonlyargs)
        own = set(spec.params) | assigned_names(node)
        own.update(n.name for n in ast.walk(node)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)))
        declared_static: Set[str] = set(spec.static_argnames)

        reported: Set[str] = set()
        for n in ast.walk(node):
            if not (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)):
                continue
            name = n.id
            if (name in own or name in declared_static
                    or name in reported
                    or name not in enclosing_locals):
                continue
            reported.add(name)
            findings.append(Finding(
                rule=RULE, path=spec.relpath, line=n.lineno,
                col=n.col_offset, context=spec.qualname,
                message=(f"jitted `{spec.qualname}` closes over "
                         f"`{name}` from enclosing "
                         f"`{enclosing.name}`: the capture is traced once "
                         f"and goes stale (or retraces) when it changes -- "
                         f"pass it as an argument")))


def _call_site_checks(project: Project, findings: List[Finding]) -> None:
    """(d): static operands of jit calls that vary per loop iteration."""
    registry = jit_registry(project)
    for rel, sf in sorted(project.files.items()):
        parents = parent_map(sf.tree)
        ctx = {n: q for q, n in iter_functions(sf.tree)}
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func)
            if callee is None:
                continue
            specs = registry.get(callee.split(".")[-1])
            if not specs:
                continue
            # names rebound by the nearest enclosing loop
            loop = parents.get(node)
            while loop is not None and not isinstance(
                    loop, (ast.For, ast.While, ast.AsyncFor)):
                loop = parents.get(loop)
            if loop is None:
                continue
            loop_names = assigned_names(loop)
            for spec in specs:
                for operand, pname in _static_operands(node, spec):
                    varying = sorted(
                        n.id for n in ast.walk(operand)
                        if isinstance(n, ast.Name) and n.id in loop_names)
                    if not varying:
                        continue
                    qual = ""
                    cur = parents.get(node)
                    while cur is not None:
                        if cur in ctx:
                            qual = ctx[cur]
                            break
                        cur = parents.get(cur)
                    findings.append(Finding(
                        rule=RULE, path=rel, line=operand.lineno,
                        col=operand.col_offset, context=qual,
                        message=(f"static argument `{pname}` of jitted "
                                 f"`{spec.name}` built from loop-varying "
                                 f"`{', '.join(varying)}`: every distinct "
                                 f"value compiles a fresh trace")))


def _static_operands(call: ast.Call, spec: JitSpec):
    """Yield ``(operand_expr, param_name)`` for the call's static slots."""
    static_names = set(spec.static_argnames)
    for i in spec.static_argnums:
        if i < len(spec.params):
            static_names.add(spec.params[i])
    for i, arg in enumerate(call.args):
        if i in spec.static_argnums or (
                i < len(spec.params) and spec.params[i] in static_names):
            pname = spec.params[i] if i < len(spec.params) else f"#{i}"
            yield arg, pname
    for kw in call.keywords:
        if kw.arg is not None and kw.arg in static_names:
            yield kw.value, kw.arg


@register(
    RULE, "retrace-hazard",
    "Inside jitted code: no Python branches or concretizations on traced "
    "values, no enclosing-scope captures; at call sites: static operands "
    "must not vary per loop iteration.",
)
def check(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    registry = jit_registry(project)
    all_specs = [s for specs in registry.values() for s in specs]
    for spec in all_specs:
        _body_checks(spec, findings)
    _closure_checks(project, all_specs, findings)
    _call_site_checks(project, findings)
    return findings
