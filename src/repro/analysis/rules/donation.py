"""SL003 donation-aliasing: donated buffers must not be read after the call.

``donate_argnums`` hands the argument's device buffer to XLA for reuse; the
Python reference still exists but points at freed (or overwritten) memory.
JAX raises on *some* post-donation uses and silently returns garbage on
others (notably under buffer reuse on TPU), so the lint is strict:

  * an argument passed at a donated position of a jitted call must be
    **rebound before its next read** -- the idiomatic
    ``state = step(state, ...)`` rebinding on the call statement itself
    satisfies this;
  * a donated argument inside a loop must be rebound *somewhere in the loop
    body* (otherwise the second iteration reads the donated buffer).

Donated operands are tracked as dotted paths, so ``self._table`` style
resident-state donation (StreamServer) is checked the same as locals.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.astutil import dotted, iter_functions, parent_map
from repro.analysis.engine import Finding, Project, register
from repro.analysis.jaxinfo import jit_registry

RULE = "SL003"


def _binding_paths(node: ast.AST) -> Set[str]:
    """Dotted paths rebound by assignments / for-targets under ``node``."""
    out: Set[str] = set()

    def targets(t):
        p = dotted(t)
        if p is not None:
            out.add(p)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e)
        elif isinstance(t, ast.Starred):
            targets(t.value)

    for n in ast.walk(node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                targets(t)
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            targets(n.target)
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            targets(n.target)
        elif isinstance(n, ast.NamedExpr):
            targets(n.target)
    return out


def _enclosing(parents, node, kinds):
    cur = parents.get(node)
    while cur is not None and not isinstance(cur, kinds):
        cur = parents.get(cur)
    return cur


def _containing_stmt(parents, node) -> Optional[ast.stmt]:
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = parents.get(cur)
    return cur


@register(
    RULE, "donation-aliasing",
    "An array passed at a donate_argnums position of a jitted call must be "
    "rebound before it is read again (including across loop iterations).",
)
def check(project: Project) -> Iterable[Finding]:
    registry = jit_registry(project)
    findings: List[Finding] = []
    for rel, sf in sorted(project.files.items()):
        parents = parent_map(sf.tree)
        ctx = {n: q for q, n in iter_functions(sf.tree)}
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            callee = dotted(call.func)
            if callee is None:
                continue
            for spec in registry.get(callee.split(".")[-1], ()):
                donated = spec.donated_positions()
                dn = set(spec.donate_argnames)
                if not donated and not dn:
                    continue
                operands = [call.args[i] for i in donated
                            if i < len(call.args)]
                operands += [kw.value for kw in call.keywords
                             if kw.arg in dn or (
                                 kw.arg in spec.params
                                 and spec.params.index(kw.arg) in donated)]
                for op in operands:
                    path = dotted(op)
                    if path is None:
                        continue
                    _check_operand(sf, rel, parents, ctx, call, op, path,
                                   spec.name, findings)
    return findings


def _check_operand(sf, rel, parents, ctx, call, op, path, jit_name,
                   findings: List[Finding]) -> None:
    stmt = _containing_stmt(parents, call)
    if stmt is None:
        return
    qual = ""
    cur = parents.get(call)
    while cur is not None:
        if cur in ctx:
            qual = ctx[cur]
            break
        cur = parents.get(cur)

    # rebinding on the call's own statement (``x = f(x)``) is the idiom
    rebound_here = path in _binding_paths(stmt)

    scope = _enclosing(
        parents, call, (ast.FunctionDef, ast.AsyncFunctionDef)) or sf.tree
    call_end = getattr(call, "end_lineno", call.lineno)

    if not rebound_here:
        # earliest later rebinding vs. earliest later read
        rebind_line = None
        for b in ast.walk(scope):
            if isinstance(b, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                              ast.For, ast.AsyncFor, ast.NamedExpr)):
                if path in _binding_paths(b) and b.lineno > call_end:
                    if rebind_line is None or b.lineno < rebind_line:
                        rebind_line = b.lineno
        for n in ast.walk(scope):
            p = dotted(n)
            if p != path or not isinstance(getattr(n, "ctx", None), ast.Load):
                continue
            if n.lineno <= call_end:
                continue
            if rebind_line is not None and n.lineno >= rebind_line:
                continue
            findings.append(Finding(
                rule=RULE, path=rel, line=n.lineno, col=n.col_offset,
                context=qual,
                message=(f"`{path}` is read after being donated to jitted "
                         f"`{jit_name}`: the buffer was handed to XLA -- "
                         f"rebind it from the call result first")))
            return

    # loop check: donation each iteration needs a rebind inside the loop
    loop = _enclosing(parents, call, (ast.For, ast.While, ast.AsyncFor))
    if loop is not None and path not in _binding_paths(loop):
        findings.append(Finding(
            rule=RULE, path=rel, line=op.lineno, col=op.col_offset,
            context=qual,
            message=(f"`{path}` is donated to jitted `{jit_name}` inside a "
                     f"loop but never rebound in the loop body: the second "
                     f"iteration passes an already-donated buffer")))
