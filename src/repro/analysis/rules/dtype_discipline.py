"""SL007 dtype-discipline: no 64-bit leaks, no slot/table dtype asymmetry.

Two jaxpr-grounded checks on every traced entry:

  * **64-bit leak** -- tracing must not request a float64/complex128 dtype.
    Under the repo's default (x64-off) config such a request is truncated
    with a UserWarning, which we capture; the jaxpr itself is also scanned
    for 64-bit ``convert_element_type`` targets and outputs so the rule
    stays honest if the tier ever runs under ``jax_enable_x64``.
  * **pair asymmetry** -- entries registered as ``pair=<label>/slot`` and
    ``pair=<label>/table`` must produce leaf-for-leaf identical output
    dtypes *and* weak types (slot member vmapped so the trees align).  An
    asymmetry is a silent upcast that breaks the bitwise per-slot == table
    contract the property batteries assert numerically.

Deep tier -- silent when ``deep.prepare(project)`` has not run; trace and
pair-construction failures are findings (an unverifiable contract is not a
pass).
"""
from __future__ import annotations

from typing import Iterable, List

from repro.analysis.engine import Finding, Project, register
from repro.analysis import deep

RULE = "SL007"

_OWNED_STAGES = ("operands", "trace", "pair")


@register(
    RULE, "dtype-discipline",
    "A traced entry requested a 64-bit dtype, or a registered slot/table "
    "pair's output trees disagree on dtype or weak type.",
    tier="deep",
)
def check(project: Project) -> Iterable[Finding]:
    ctx = deep.context(project)
    if ctx is None:
        return []
    findings: List[Finding] = []
    for stage, entry, msg in ctx.errors:
        if stage not in _OWNED_STAGES:
            continue
        findings.append(Finding(
            rule=RULE, path=entry.relpath, line=entry.line or 1, col=0,
            context=entry.qualname,
            message=f"deep-tier {stage} failed for this entry: {msg}"))
    for t in ctx.traces:
        if t.warnings_64:
            findings.append(Finding(
                rule=RULE, path=t.entry.relpath, line=t.entry.line, col=0,
                context=t.entry.qualname,
                message=(f"tracing `{t.entry.qualname}` [{t.tag}] requested "
                         f"a 64-bit dtype (truncated under the default "
                         f"x64-off config): {t.warnings_64[0]}")))
        if t.jaxpr_64:
            findings.append(Finding(
                rule=RULE, path=t.entry.relpath, line=t.entry.line, col=0,
                context=t.entry.qualname,
                message=(f"jaxpr of `{t.entry.qualname}` [{t.tag}] contains "
                         f"64-bit values: {', '.join(t.jaxpr_64)}")))
    for p in ctx.pairs:
        if not p.mismatches:
            continue
        shown = "; ".join(p.mismatches[:4])
        findings.append(Finding(
            rule=RULE, path=p.table.relpath, line=p.table.line, col=0,
            context=p.table.qualname,
            message=(f"pair `{p.label}` [{p.tag}]: per-slot and table "
                     f"output trees disagree on dtype/weak-type -- {shown}")))
    return findings
