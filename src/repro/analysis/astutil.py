"""Shared AST plumbing for the symlint rules.

Everything here is pure syntax -- no file in the sweep is ever imported or
executed.  The helpers cover the three things every rule needs: resolving
dotted expressions (``a.b.c``) to strings, walking functions with their
qualified names (``Class.method``), and reading the per-line comment channel
(suppressions and annotations ride on comments, extracted with ``tokenize``
so a ``#`` inside a string literal never counts).
"""
from __future__ import annotations

import ast
import io
import tokenize
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = [
    "dotted", "parent_map", "iter_functions", "line_comments",
    "call_keywords", "walk_in_order",
]


def dotted(node: ast.AST) -> Optional[str]:
    """``Name``/``Attribute`` chain as ``"a.b.c"``; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    """Child -> parent for every node (ast has no parent pointers)."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def iter_functions(
    tree: ast.AST,
) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(qualname, node)`` for every def/lambda, outermost first.

    Qualnames follow ``Class.method`` / ``outer.<locals>.inner`` shape (the
    ``<locals>`` hop is dropped for readability: ``outer.inner``).
    """

    def visit(node: ast.AST, prefix: str) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            elif isinstance(child, ast.Lambda):
                yield f"{prefix}<lambda>", child
                yield from visit(child, f"{prefix}<lambda>.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def line_comments(text: str) -> Dict[int, str]:
    """Line number -> comment text (sans ``#``), via the tokenizer."""
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:  # unterminated something: best effort
        pass
    return out


def call_keywords(call: ast.Call) -> Dict[str, ast.expr]:
    """Keyword arguments of a call as ``{name: value}`` (no ``**kwargs``)."""
    return {kw.arg: kw.value for kw in call.keywords if kw.arg is not None}


def walk_in_order(node: ast.AST) -> Iterator[ast.AST]:
    """Depth-first, *source-order* walk (``ast.walk`` is breadth-first)."""
    yield node
    for child in ast.iter_child_nodes(node):
        yield from walk_in_order(child)
