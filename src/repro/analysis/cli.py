"""symlint command line: ``python -m repro.analysis`` / ``symlint``.

Exit codes: 0 clean, 1 findings (or stale baseline entries / parse errors),
2 usage error.  ``--format=github`` emits workflow annotation commands so
the CI ``lint-analysis`` job shows findings inline on the PR diff.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import subprocess
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Set

from repro.analysis.engine import (
    BASELINE_NAME, DEFAULT_SWEEP, RULES, AnalysisResult, Baseline,
    analyze, load_project,
)


def find_root(start: Optional[Path] = None) -> Path:
    """Walk up from ``start`` to the directory holding pyproject.toml."""
    cur = (start or Path.cwd()).resolve()
    for cand in [cur, *cur.parents]:
        if (cand / "pyproject.toml").exists():
            return cand
    return cur


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="symlint",
        description="Repo-native static analysis for the SymED codebase: "
                    "compat routing (SL001), retrace hazards (SL002), "
                    "donation aliasing (SL003), hot-path host syncs (SL004), "
                    "wire-protocol consistency (SL005); with --deep also "
                    "retrace budgets (SL006), dtype discipline (SL007), and "
                    "donation effectiveness (SL008) against what jax "
                    "actually compiles.")
    p.add_argument("paths", nargs="*", type=Path,
                   help=f"files/directories to sweep (default: "
                        f"{'/'.join(DEFAULT_SWEEP)} under the repo root)")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--format", dest="fmt", default="text",
                   choices=("text", "json", "github"))
    p.add_argument("--baseline", type=Path, default=None,
                   help=f"baseline file (default: <root>/{BASELINE_NAME})")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: report grandfathered findings")
    p.add_argument("--write-baseline", "--update-baseline",
                   action="store_true",
                   help="rewrite the baseline from the current findings "
                        "(keeps existing justifications); exits 1 listing "
                        "any entry whose justification is still the TODO "
                        "placeholder, so unjustified baselines cannot land")
    p.add_argument("--deep", action="store_true",
                   help="also run the jax-importing deep tier (SL006-SL008): "
                        "traces/compiles every `# symlint: entry(...)` "
                        "registration on CPU and runs the scripted drives")
    p.add_argument("--changed", action="store_true",
                   help="report findings only for files that differ from the "
                        "merge-base with origin/main (plus uncommitted and "
                        "untracked files); the whole sweep is still parsed "
                        "so cross-file rules keep their context")
    p.add_argument("--list-rules", action="store_true")
    p.add_argument("--show-baselined", action="store_true",
                   help="also print baselined/suppressed findings (text)")
    return p


def _emit_text(result: AnalysisResult, show_baselined: bool) -> None:
    for rel, err in result.parse_errors:
        print(f"{rel}: SL000 parse error: {err}")
    for f in result.findings:
        where = f" [{f.context}]" if f.context else ""
        print(f"{f.path}:{f.line}:{f.col}: {f.rule}{where}: {f.message}")
    if show_baselined:
        for f in result.baselined:
            print(f"{f.path}:{f.line}:{f.col}: {f.rule} (baselined): "
                  f"{f.message}")
        for f in result.suppressed:
            print(f"{f.path}:{f.line}:{f.col}: {f.rule} (suppressed): "
                  f"{f.message}")
    for e in result.stale_baseline:
        print(f"{e['file']}: stale baseline entry {e['fingerprint']} "
              f"({e['rule']}): finding no longer exists -- remove it")
    n = len(result.findings)
    print(f"symlint: {n} finding{'s' if n != 1 else ''}"
          f" ({len(result.baselined)} baselined,"
          f" {len(result.suppressed)} suppressed,"
          f" {len(result.stale_baseline)} stale baseline entries)")


def _emit_github(result: AnalysisResult) -> None:
    for rel, err in result.parse_errors:
        print(f"::error file={rel},title=SL000 parse error::{err}")
    for f in result.findings:
        print(f"::error file={f.path},line={f.line},col={f.col + 1},"
              f"title={f.rule} {RULES[f.rule].name}::{f.message}")
    for e in result.stale_baseline:
        print(f"::error file={e['file']},title=stale baseline::"
              f"entry {e['fingerprint']} ({e['rule']}) no longer matches "
              f"any finding -- remove it from {BASELINE_NAME}")


def _emit_json(result: AnalysisResult) -> None:
    print(json.dumps({
        "findings": [f.to_json() for f in result.findings],
        "baselined": [f.to_json() for f in result.baselined],
        "suppressed": [f.to_json() for f in result.suppressed],
        "stale_baseline": result.stale_baseline,
        "parse_errors": [
            {"path": p, "error": e} for p, e in result.parse_errors],
        "exit_code": result.exit_code,
    }, indent=2))


def _changed_files(root: Path) -> Optional[Set[str]]:
    """Repo-relative posix paths differing from the merge-base (committed,
    uncommitted, and untracked); None when git/merge-base is unavailable."""

    def git(*cmd):
        try:
            r = subprocess.run(["git", *cmd], cwd=root, capture_output=True,
                               text=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None
        return r.stdout.strip() if r.returncode == 0 else None

    base = None
    for ref in ("origin/main", "main", "HEAD"):
        base = git("merge-base", ref, "HEAD")
        if base is not None:
            break
    if base is None:
        return None
    diff = git("diff", "--name-only", base, "--")
    if diff is None:
        return None
    changed = {p for p in diff.splitlines() if p}
    untracked = git("ls-files", "--others", "--exclude-standard")
    if untracked:
        changed |= {p for p in untracked.splitlines() if p}
    return changed


def main(argv: Optional[Sequence[str]] = None) -> int:
    import repro.analysis.rules  # noqa: F401 -- populate the registry

    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rid in sorted(RULES):
            r = RULES[rid]
            print(f"{r.id}  {r.name} [{r.tier}]: {r.doc}")
        return 0

    root = find_root()
    if args.paths:
        paths: List[Path] = [p if p.is_absolute() else Path.cwd() / p
                             for p in args.paths]
        missing = [p for p in paths if not p.exists()]
        if missing:
            print(f"symlint: no such path: "
                  f"{', '.join(str(p) for p in missing)}", file=sys.stderr)
            return 2
    else:
        paths = [root / d for d in DEFAULT_SWEEP if (root / d).is_dir()]

    rule_ids = None
    if args.rules:
        rule_ids = [r.strip().upper() for r in args.rules.split(",")
                    if r.strip()]
        unknown = [r for r in rule_ids if r not in RULES]
        if unknown:
            print(f"symlint: unknown rule(s) {', '.join(unknown)}; "
                  f"known: {', '.join(sorted(RULES))}", file=sys.stderr)
            return 2

    baseline_path = args.baseline or (root / BASELINE_NAME)
    baseline = None if args.no_baseline else Baseline(baseline_path)

    project = load_project(root, paths)
    if args.deep:
        from repro.analysis import deep
        deep.prepare(project)
    result = analyze(project, rule_ids, baseline, include_deep=args.deep)

    if args.changed:
        changed = _changed_files(root)
        if changed is None:
            print("symlint: --changed needs a git checkout with a resolvable "
                  "merge-base", file=sys.stderr)
            return 2
        result = dataclasses.replace(
            result,
            findings=[f for f in result.findings if f.path in changed],
            baselined=[f for f in result.baselined if f.path in changed],
            suppressed=[f for f in result.suppressed if f.path in changed],
            # a stale entry is an attribute of the whole baseline, not of
            # any changed file -- full sweeps own that failure mode
            stale_baseline=[],
            parse_errors=[(p, e) for p, e in result.parse_errors
                          if p in changed],
        )

    if args.write_baseline:
        grandfather = result.findings + result.baselined
        n = Baseline.write(baseline_path, grandfather,
                           baseline.entries if baseline is not None else {})
        print(f"symlint: wrote {n} entr{'y' if n == 1 else 'ies'} to "
              f"{baseline_path}")
        todo = Baseline.unjustified(baseline_path)
        if todo:
            for e in todo:
                print(f"{e['file']}: baseline entry {e['fingerprint']} "
                      f"({e['rule']}) still carries the placeholder "
                      f"justification -- write a real reason or fix it")
            print(f"symlint: {len(todo)} unjustified baseline "
                  f"entr{'y' if len(todo) == 1 else 'ies'}", file=sys.stderr)
            return 1
        return 0

    if args.fmt == "json":
        _emit_json(result)
    elif args.fmt == "github":
        _emit_github(result)
        n = len(result.findings)
        print(f"symlint: {n} finding{'s' if n != 1 else ''}")
    else:
        _emit_text(result, args.show_baselined)
    return result.exit_code
