"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) -- the [ssm]-family arch xlstm-125m.

mLSTM exponential gating is *separable*: with F_t = sum_{s<=t} logsigmoid(f_s)
and g_s = i_s - F_s, the gate matrix is D_ts = F_t + g_s (s <= t) and its row
max is m_t = F_t + cummax(g)_t -- both computable in O(S) up front.  The
quadratic form then chunks exactly like flash attention but with *fixed*
per-row stabilizers (no online max rescaling), and weights exp(g_s - M_t) <= 1
by construction.  Decode uses the O(1) recurrent form with (C, n, m) state.

sLSTM keeps per-head scalar memories with block-diagonal recurrence and is
inherently sequential: a lax.scan over time (cheap at xlstm-125m scale; noted
in DESIGN.md as the TPU-unfriendly layer).  Both blocks carry their own
up/down projections (``has_mlp=False`` in their LayerSpec).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, model_dtype
from repro.models.ssm import _causal_conv

__all__ = [
    "mlstm_init", "mlstm_apply_train", "MLSTMState", "init_mlstm_state",
    "mlstm_apply_decode", "slstm_init", "slstm_apply_train", "SLSTMState",
    "init_slstm_state", "slstm_apply_decode",
]

_CLAMP = 80.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mdims(cfg):
    d_in = 2 * cfg.d_model
    hd = d_in // cfg.n_heads
    return d_in, hd


def mlstm_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    d = cfg.d_model
    d_in, hd = _mdims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": init_dense(ks[0], d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (4, d_in), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "wq": init_dense(ks[2], d_in, d_in, dt),
        "wk": init_dense(ks[3], d_in, d_in, dt),
        "wv": init_dense(ks[4], d_in, d_in, dt),
        "wi": init_dense(ks[5], d_in, cfg.n_heads, jnp.float32, scale=0.01),
        "wf": init_dense(ks[6], d_in, cfg.n_heads, jnp.float32, scale=0.01),
        "bi": jnp.zeros((cfg.n_heads,), jnp.float32),
        "bf": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # open forget gates
        "down": init_dense(ks[7], d_in, cfg.d_model, dt),
    }


def _mlstm_qkv_gates(params, cfg, xm):
    b, s, d_in = xm.shape
    h = cfg.n_heads
    hd = d_in // h
    xc = _causal_conv(xm, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xm.dtype)
    q = dense(xc, params["wq"]).reshape(b, s, h, hd)
    k = dense(xc, params["wk"]).reshape(b, s, h, hd) * (hd ** -0.5)
    v = dense(xm, params["wv"]).reshape(b, s, h, hd)
    i_pre = xm.astype(jnp.float32) @ params["wi"] + params["bi"]   # (b,s,h)
    f_pre = xm.astype(jnp.float32) @ params["wf"] + params["bf"]
    return q, k, v, i_pre, f_pre


def mlstm_apply_train(params: dict, cfg, x: jax.Array, *, chunk: int = 512) -> jax.Array:
    b, s, d = x.shape
    d_in, hd = _mdims(cfg)
    h = cfg.n_heads
    xz = dense(x, params["up"])
    xm, z = jnp.split(xz, 2, axis=-1)

    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(params, cfg, xm)

    logf = jax.nn.log_sigmoid(f_pre)                   # (b,s,h)
    F = jnp.cumsum(logf, axis=1)                       # F_t
    g = i_pre - F                                      # g_s = i_s - F_s
    M = jax.lax.cummax(g, axis=1)                      # row stabilizer source
    # m_t = F_t + M_t; normalizer floor exp(-m_t), clamped
    neg_m = jnp.clip(-(F + M), a_max=_CLAMP)

    cq = min(chunk, s)
    if s % cq:
        cq = s  # non-power-of-two smoke shapes: single chunk
    nq = s // cq

    def per_q(qi, args):
        qc, Mc, negm_c = args                          # (b,cq,h,hd) (b,cq,h) ..
        q0 = qi * cq
        qpos = q0 + jnp.arange(cq)

        def kv_step(carry, xs):
            l_run, acc = carry
            ki, kc, vc, gc = xs
            kpos = ki * cq + jnp.arange(cq)
            # scores: (b, h, cq, ck)
            sc = jnp.einsum("bqhd,bshd->bhqs", qc, kc,
                            preferred_element_type=jnp.float32)
            logw = gc.transpose(0, 2, 1)[:, :, None, :] - Mc.transpose(0, 2, 1)[:, :, :, None]
            mask = (kpos[None, :] <= qpos[:, None])[None, None]
            wgt = jnp.where(mask, jnp.exp(jnp.clip(logw, a_max=0.0)), 0.0)
            sc = sc * wgt
            l_run = l_run + jnp.sum(sc, axis=-1)
            acc = acc + jnp.einsum("bhqs,bshd->bhqd", sc.astype(vc.dtype), vc,
                                   preferred_element_type=jnp.float32)
            return (l_run, acc), None

        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (l_f, acc), _ = jax.lax.scan(
            kv_step, (l0, a0),
            (jnp.arange(nq),
             jnp.moveaxis(k.reshape(b, nq, cq, h, hd), 1, 0),
             jnp.moveaxis(v.reshape(b, nq, cq, h, hd), 1, 0),
             jnp.moveaxis(g.reshape(b, nq, cq, h), 1, 0)),
        )
        norm = jnp.maximum(jnp.abs(l_f), jnp.exp(negm_c.transpose(0, 2, 1)))
        out = acc / norm[..., None]
        return jnp.moveaxis(out, 2, 1)                 # (b, cq, h, hd)

    outs = jax.lax.map(
        jax.checkpoint(lambda xs: per_q(xs[0], (xs[1], xs[2], xs[3]))),
        (jnp.arange(nq),
         jnp.moveaxis(q.reshape(b, nq, cq, h, hd), 1, 0),
         jnp.moveaxis(M.reshape(b, nq, cq, h), 1, 0),
         jnp.moveaxis(neg_m.reshape(b, nq, cq, h), 1, 0)),
    )
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return dense(y, params["down"])


def mlstm_prefill_state(params: dict, cfg, x: jax.Array) -> "MLSTMState":
    """Closed-form recurrent state after a full prompt (separable gating):

    C_T = sum_s exp(F_T - F_s + i_s - m_T) v_s k_s^T,   m_T = F_T + M_T.
    """
    b, s, _ = x.shape
    d_in, hd = _mdims(cfg)
    xz = dense(x, params["up"])
    xm, _ = jnp.split(xz, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(params, cfg, xm)
    del q
    logf = jax.nn.log_sigmoid(f_pre)
    F = jnp.cumsum(logf, axis=1)
    g = i_pre - F                                # (b, s, h)
    m_T = F[:, -1] + jnp.max(g, axis=1)          # (b, h)
    # weight_s = exp(F_T + g_s - m_T) = exp(g_s - max g) <= 1
    w = jnp.exp(g - jnp.max(g, axis=1, keepdims=True))
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = jnp.einsum("bsh,bshv,bshk->bhvk", w, vf, kf)
    n = jnp.einsum("bsh,bshk->bhk", w, kf)
    buf = jnp.pad(xm.astype(jnp.float32), ((0, 0), (3, 0), (0, 0)))[:, -3:]
    return MLSTMState(c=c, n=n, m=m_T, conv_buf=buf)


class MLSTMState(NamedTuple):
    c: jax.Array        # (B, H, hd, hd) f32 matrix memory
    n: jax.Array        # (B, H, hd)
    m: jax.Array        # (B, H)
    conv_buf: jax.Array # (B, 3, d_in)


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    d_in, hd = _mdims(cfg)
    h = cfg.n_heads
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.zeros((batch, h), jnp.float32),
        conv_buf=jnp.zeros((batch, 3, d_in), jnp.float32),
    )


def mlstm_apply_decode(params: dict, cfg, x1: jax.Array, state: MLSTMState):
    b = x1.shape[0]
    d_in, hd = _mdims(cfg)
    xz = dense(x1, params["up"])
    xm, z = jnp.split(xz, 2, axis=-1)

    xc = _causal_conv(xm, params["conv_w"], params["conv_b"], prepend=state.conv_buf)
    xc = jax.nn.silu(xc[:, -1:].astype(jnp.float32)).astype(x1.dtype)
    h_ = cfg.n_heads
    q = dense(xc, params["wq"]).reshape(b, h_, hd)
    k = dense(xc, params["wk"]).reshape(b, h_, hd) * (hd ** -0.5)
    v = dense(xm, params["wv"]).reshape(b, h_, hd)
    i_pre = (xm[:, 0].astype(jnp.float32) @ params["wi"] + params["bi"])
    f_pre = (xm[:, 0].astype(jnp.float32) @ params["wf"] + params["bf"])

    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state.m, i_pre)
    f_eff = jnp.exp(jnp.clip(logf + state.m - m_new, a_max=_CLAMP))
    i_eff = jnp.exp(jnp.clip(i_pre - m_new, a_max=_CLAMP))

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = f_eff[..., None, None] * state.c + i_eff[..., None, None] * (
        vf[..., :, None] * kf[..., None, :]
    )
    n = f_eff[..., None] * state.n + i_eff[..., None] * kf
    num = jnp.einsum("bhvk,bhk->bhv", c, qf)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
        jnp.exp(jnp.clip(-m_new, a_max=_CLAMP)),
    )
    hcell = (num / den[..., None]).reshape(b, 1, d_in).astype(x1.dtype)
    y = hcell * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    new_state = MLSTMState(
        c=c, n=n, m=m_new,
        conv_buf=jnp.concatenate([state.conv_buf[:, 1:], xm.astype(jnp.float32)], axis=1),
    )
    return dense(y, params["down"]), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _sdims(cfg):
    hd = cfg.d_model // cfg.n_heads
    pf = (4 * cfg.d_model + 2) // 3  # xLSTM projection factor 4/3
    return hd, pf


def slstm_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    d, h = cfg.d_model, cfg.n_heads
    hd, pf = _sdims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wx": init_dense(ks[0], d, 4 * d, dt),
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32) * hd ** -0.5).astype(dt),
        "b": jnp.concatenate([
            jnp.zeros((d,), jnp.float32),            # i
            jnp.full((d,), 3.0, jnp.float32),        # f (open)
            jnp.zeros((2 * d,), jnp.float32),        # z, o
        ]),
        "ffn_up": init_dense(ks[2], d, 2 * pf, dt),
        "ffn_down": init_dense(ks[3], pf, d, dt),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd)
    n: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H, hd)
    h: jax.Array  # (B, H, hd)


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    hd, _ = _sdims(cfg)
    shape = (batch, cfg.n_heads, hd)
    z = jnp.zeros(shape, jnp.float32)
    return SLSTMState(c=z, n=z, m=z, h=z)


def _slstm_cell(params, cfg, xg, state: SLSTMState):
    """One time step.  xg: (B, 4*d) f32 pre-activations from x (incl. bias)."""
    b = xg.shape[0]
    h, (hd, _) = cfg.n_heads, _sdims(cfg)
    rec = jnp.einsum("bhk,hkg->bhg", state.h, params["r"].astype(jnp.float32))
    pre = xg.reshape(b, 4, h, hd).transpose(0, 2, 1, 3).reshape(b, h, 4 * hd) + rec
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)      # (b, h, hd) each

    m_new = jnp.maximum(f_p + state.m, i_p)
    i_eff = jnp.exp(jnp.clip(i_p - m_new, a_max=_CLAMP))
    f_eff = jnp.exp(jnp.clip(f_p + state.m - m_new, a_max=_CLAMP))
    c = f_eff * state.c + i_eff * jnp.tanh(z_p)
    n = f_eff * state.n + i_eff
    h_new = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, m=m_new, h=h_new)


def _slstm_ffn(params, cfg, y):
    up = dense(y, params["ffn_up"])
    gate, u = jnp.split(up, 2, axis=-1)
    act = jax.nn.gelu(gate.astype(jnp.float32)).astype(y.dtype) * u
    return dense(act, params["ffn_down"])


def slstm_apply_train(
    params: dict, cfg, x: jax.Array, *, return_state: bool = False,
    chunk: int = 256,
):
    b, s, d = x.shape
    xg = (dense(x, params["wx"]).astype(jnp.float32) + params["b"])

    def step(state, xg_t):
        new = _slstm_cell(params, cfg, xg_t, state)
        return new, new.h

    # two-level checkpointed scan: backward stores only chunk-boundary states
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c
    xg_c = jnp.moveaxis(xg, 1, 0).reshape(nc, c, b, xg.shape[-1])

    @jax.checkpoint
    def chunk_body(state, xg_chunk):
        fin, hs = jax.lax.scan(step, state, xg_chunk)
        return fin, hs

    init = init_slstm_state(cfg, b)
    fin, hs = jax.lax.scan(chunk_body, init, xg_c)
    y = jnp.moveaxis(hs.reshape(s, b, -1), 0, 1).reshape(b, s, d).astype(x.dtype)
    return _slstm_ffn(params, cfg, y), (fin if return_state else None)


def slstm_apply_decode(params: dict, cfg, x1: jax.Array, state: SLSTMState):
    b = x1.shape[0]
    xg = dense(x1, params["wx"])[:, 0].astype(jnp.float32) + params["b"]
    new = _slstm_cell(params, cfg, xg, state)
    y = new.h.reshape(b, 1, cfg.d_model).astype(x1.dtype)
    return _slstm_ffn(params, cfg, y), new
