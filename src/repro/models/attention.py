"""Attention: blockwise online-softmax training path + cached decode path.

Training/prefill uses a flash-style blockwise formulation (lax.scan over KV
chunks carrying running (max, denom, acc)) so the (S, S) score matrix is never
materialized -- on TPU this is the memory-capacity play that makes the 32k
prefill shapes fit HBM.  Masks supported: causal, sliding-window (local),
bidirectional prefix (prefix-LM for the VLM), and full-bidirectional
(whisper encoder) -- all computed from absolute positions inside the chunk
loop.

Decode uses KV caches: ``global`` layers keep the full (S_max) cache; ``local``
layers keep a ring buffer of ``window`` slots (RoPE is applied pre-cache at
absolute positions, so ring rotation is sound).  This bounded-cache path is
what makes sliding-window archs legitimately sub-quadratic for ``long_500k``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, model_dtype, rope

__all__ = [
    "attn_init", "attn_apply_train", "KVCache", "init_kv_cache",
    "attn_apply_decode",
]

_NEG = -1e30


def attn_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, h * hd, dt),
        "wk": init_dense(ks[1], d, kv * hd, dt),
        "wv": init_dense(ks[2], d, kv * hd, dt),
        "wo": init_dense(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _project_qkv(params, cfg, x, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, params["wq"], params.get("bq")).reshape(b, s, h, hd)
    k = dense(x, params["wk"], params.get("bk")).reshape(b, s, kv, hd)
    v = dense(x, params["wv"], params.get("bv")).reshape(b, s, kv, hd)
    if cfg.pos_kind == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(qpos, kpos, *, mode: str, window: int, prefix: int):
    """(..., q, k) boolean validity from absolute positions."""
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    if mode == "bidir":
        return jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    causal = kp <= qp
    if mode == "local":
        causal &= (qp - kp) < window
    if prefix > 0:  # prefix-LM: fully visible prefix block
        causal |= (qp < prefix) & (kp < prefix)
    return causal


def _blockwise_sdpa(q, k, v, *, mode, window, prefix, q0, k0, chunk_q, chunk_kv, group):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, Kv, hd); H = Kv * group.
    q0/k0: absolute position offsets of q/k element 0.
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    cq = min(chunk_q, sq)
    ck = min(chunk_kv, sk)
    if sq % cq:
        cq = sq  # non-power-of-two smoke shapes: single chunk
    if sk % ck:
        ck = sk
    nq, nk = sq // cq, sk // ck
    scale = hd ** -0.5

    qr = q.reshape(b, nq, cq, kvh, group, hd)
    kr = k.reshape(b, nk, ck, kvh, hd)
    vr = v.reshape(b, nk, ck, kvh, hd)

    def per_q_chunk(qi, qc):
        # qc: (B, cq, Kv, G, hd)
        qpos = q0 + qi * cq + jnp.arange(cq)

        def kv_step(carry, xs):
            m_run, l_run, acc = carry
            ki, kc, vc = xs
            kpos = k0 + ki * ck + jnp.arange(ck)
            s = jnp.einsum(
                "bqkgh,bskh->bkgqs", qc, kc, preferred_element_type=jnp.float32
            ) * scale                                   # (B, Kv, G, cq, ck)
            valid = _mask(qpos, kpos, mode=mode, window=window, prefix=prefix)
            s = jnp.where(valid[None, None, None], s, _NEG)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, group, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, group, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, group, cq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)),
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (B, Kv, G, cq, hd)
        return jnp.moveaxis(out, 3, 1)                   # (B, cq, Kv, G, hd)

    # checkpoint each q-chunk: backward recomputes the kv scan instead of
    # storing (m, l, acc) residuals for every kv step -- the memory play that
    # keeps 32k prefill inside HBM.
    outs = jax.lax.map(
        jax.checkpoint(lambda xs: per_q_chunk(xs[0], xs[1])),
        (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)),
    )                                                    # (nq, B, cq, Kv, G, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attn_apply_train(
    params: dict,
    cfg,
    x: jax.Array,
    *,
    attn_type: str = "global",
    mode_override: Optional[str] = None,
    kv_memory: Optional[jax.Array] = None,
    pos0: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``kv_memory``: if given (B, S_enc, d), keys/values come from it
    (cross-attention) and the mask is bidirectional.  Returns
    ``(out, (k, v) if return_kv else None)``.
    """
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s)[None, :]
    group = cfg.n_heads // cfg.n_kv_heads

    if kv_memory is not None:
        sm = kv_memory.shape[1]
        mpos = jnp.arange(sm)[None, :]
        q = dense(x, params["wq"], params.get("bq")).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = dense(kv_memory, params["wk"], params.get("bk")).reshape(b, sm, cfg.n_kv_heads, cfg.head_dim)
        v = dense(kv_memory, params["wv"], params.get("bv")).reshape(b, sm, cfg.n_kv_heads, cfg.head_dim)
        del mpos
        mode = "bidir"
        k0 = 0
    else:
        q, k, v = _project_qkv(params, cfg, x, positions)
        mode = mode_override or ("local" if attn_type == "local" else "causal")
        k0 = pos0

    out = _blockwise_sdpa(
        q, k, v, mode=mode, window=cfg.window, prefix=cfg.prefix_lm,
        q0=pos0, k0=k0, chunk_q=chunk_q, chunk_kv=chunk_kv, group=group,
    )
    proj = dense(out.reshape(b, s, cfg.n_heads * cfg.head_dim), params["wo"])
    return proj, ((k, v) if return_kv else None)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array   # (B, C, Kv, hd) -- C = S_max (global) or window (local ring)
    v: jax.Array


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(position, head) scales -- SymED's bounded-error
    compression idea applied to serving state: halves decode HBM vs bf16, and
    the dequant folds into the attention einsums (scale factors out of the hd
    contraction), so no full-precision copy ever materializes."""

    k_q: jax.Array   # (B, C, Kv, hd) int8
    v_q: jax.Array
    k_s: jax.Array   # (B, C, Kv, 1) bf16 scales
    v_s: jax.Array


def _quantize(x: jax.Array):
    """(..., hd) -> int8 values + bf16 scale over the hd dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def init_kv_cache(cfg, batch: int, max_len: int, attn_type: str, dtype,
                  quant: bool = False):
    c = min(max_len, cfg.window) if attn_type == "local" else max_len
    shape = (batch, c, cfg.n_kv_heads, cfg.head_dim)
    if quant:
        sshape = shape[:-1] + (1,)
        return QuantKVCache(
            k_q=jnp.zeros(shape, jnp.int8), v_q=jnp.zeros(shape, jnp.int8),
            k_s=jnp.zeros(sshape, jnp.bfloat16), v_s=jnp.zeros(sshape, jnp.bfloat16),
        )
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attn_apply_decode(
    params: dict,
    cfg,
    x1: jax.Array,          # (B, 1, d)
    cache: KVCache,
    pos: jax.Array,         # () int32 -- position of the new token
    *,
    attn_type: str = "global",
    kv_memory: Optional[KVCache] = None,
):
    """One-token attention against the cache; returns (out, new_cache)."""
    b = x1.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = h // kvh
    positions = jnp.full((b, 1), pos, jnp.int32)

    quant = isinstance(cache, QuantKVCache) or isinstance(kv_memory, QuantKVCache)
    if kv_memory is not None:
        # cross-attention: static memory, no cache update
        q = dense(x1, params["wq"], params.get("bq")).reshape(b, 1, h, hd)
        kc = kv_memory
        c = (kc.k_q if quant else kc.k).shape[1]
        new_cache = cache
        valid = jnp.ones((c,), bool)
    else:
        q, k1, v1 = _project_qkv(params, cfg, x1, positions)
        c = (cache.k_q if quant else cache.k).shape[1]
        slot = jnp.asarray(pos % c if attn_type == "local" else pos, jnp.int32)
        if quant:
            k1q, k1s = _quantize(k1)
            v1q, v1s = _quantize(v1)
            upd = lambda buf, val: jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (0, slot, 0, 0))
            kc = QuantKVCache(
                k_q=upd(cache.k_q, k1q), v_q=upd(cache.v_q, v1q),
                k_s=upd(cache.k_s, k1s), v_s=upd(cache.v_s, v1s),
            )
        else:
            kc = KVCache(
                k=jax.lax.dynamic_update_slice(
                    cache.k, k1.astype(cache.k.dtype), (0, slot, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    cache.v, v1.astype(cache.v.dtype), (0, slot, 0, 0)),
            )
        new_cache = kc
        idx = jnp.arange(c)
        if attn_type == "local":
            valid = (idx <= pos % c) | (pos >= c)   # occupied ring slots
        else:
            valid = idx <= pos

    qr = q.reshape(b, kvh, group, hd)
    if quant:
        # dequant folds into the einsums: scale factors out of the hd dot
        s = jnp.einsum("bkgh,bskh->bkgs", qr, kc.k_q.astype(qr.dtype),
                       preferred_element_type=jnp.float32)
        s = s * kc.k_s[..., 0].astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    else:
        s = jnp.einsum("bkgh,bskh->bkgs", qr, kc.k,
                       preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    if quant:
        pv = p * kc.v_s[..., 0].astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        out = jnp.einsum("bkgs,bskh->bkgh", pv.astype(x1.dtype),
                         kc.v_q.astype(x1.dtype),
                         preferred_element_type=jnp.float32).astype(x1.dtype)
    else:
        out = jnp.einsum("bkgs,bskh->bkgh", p.astype(kc.v.dtype), kc.v,
                         preferred_element_type=jnp.float32).astype(x1.dtype)
    out = out.reshape(b, 1, h * hd)
    return dense(out, params["wo"]), new_cache
