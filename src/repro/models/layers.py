"""Common model layers: norms, MLP variants, embeddings, rotary positions.

Pure-function style (params are plain dict pytrees) so the partitioner in
``repro.sharding`` can pattern-match on tree paths.  Matmuls run in the model
dtype (bf16 on TPU) with f32 accumulation; norms and softmax run in f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "mlp_apply", "mlp_init", "embed_init", "rope", "dense",
    "init_dense", "model_dtype",
]


def model_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with (1 + scale) gain (gemma convention), f32 internals."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


@jax.custom_vjp
def _matmul_bf16_grads(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w: f32 MXU accumulation forward, *bf16 weight/input gradients*.

    The default VJP inherits preferred_element_type=f32, materializing
    full-size f32 weight-grad partials per layer before their reduce-scatter
    -- the dominant HBM buffer at jamba-398B scale (dry-run iteration log).
    bf16 grads halve that; gradient *accumulation* stays f32 upstream
    (optimizer moments / accum buffers)."""
    return _mm_fwd(x, w)[0]


def _mm_fwd(x, w):
    y = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    return y, (x, w)


def _mm_bwd(res, g):
    x, w = res
    g = g.astype(x.dtype)
    dims = tuple(range(x.ndim - 1))
    dx = jax.lax.dot_general(
        g, w.astype(g.dtype), (((g.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    dw = jax.lax.dot_general(
        x, g, ((dims, dims), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(w.dtype)
    return dx, dw


_matmul_bf16_grads.defvjp(_mm_fwd, _mm_bwd)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    """x @ w with f32 accumulation, output cast back to x.dtype."""
    y = _matmul_bf16_grads(x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# MLP: swiglu (llama/gemma/mixtral), gelu (whisper/paligemma), relu2 (nemotron)
# ---------------------------------------------------------------------------

GATED_MLP = ("swiglu", "geglu")


def mlp_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    k1, k2 = jax.random.split(key)
    d, f = cfg.d_model, cfg.d_ff
    width = 2 * f if cfg.mlp_kind in GATED_MLP else f
    return {"wi": init_dense(k1, d, width, dt), "wo_mlp": init_dense(k2, f, d, dt)}


def mlp_activate(h: jax.Array, kind: str, out_dtype) -> jax.Array:
    """Shared nonlinearity for dense and MoE FFNs."""
    if kind in GATED_MLP:
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        return act(gate.astype(jnp.float32)).astype(out_dtype) * up
    if kind == "gelu":
        return jax.nn.gelu(h.astype(jnp.float32)).astype(out_dtype)
    if kind == "relu2":  # squared ReLU (nemotron-4)
        r = jnp.maximum(h, 0.0)
        return (r * r).astype(out_dtype)
    raise ValueError(f"unknown mlp kind {kind}")


def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    h = dense(x, params["wi"])
    return dense(mlp_activate(h, kind, x.dtype), params["wo_mlp"])


def sinusoid_pos(positions: jax.Array, d: int) -> jax.Array:
    """Parameter-free sinusoidal positions (whisper-style stand-in)."""
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (9.21034 / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
