"""Mixture-of-Experts FFN with top-k routing and grouped one-hot dispatch.

GShard/Switch-style capacity-bounded dispatch, evaluated group-by-group under
``lax.scan`` so the (g, e, cap) dispatch tensors never exceed one group's
working set.  Dispatch/combine are dense einsums: on TPU they are MXU matmuls
and shard cleanly -- experts over the ``model`` axis when divisible (expert
parallelism), otherwise the per-expert hidden dim is tensor-parallel (see
``repro.sharding.rules``).  No ragged all-to-all is required at dry-run level.

Load-balancing auxiliary loss follows Switch/Mixtral: sum(frac_tokens *
frac_router_prob) * E * coef, computed over all tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import GATED_MLP, init_dense, mlp_activate, model_dtype
from repro.sharding import constrain

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    width = 2 * f if cfg.mlp_kind in GATED_MLP else f
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": init_dense(k1, d, e, jnp.float32),  # router kept f32
        "wi_moe": (jax.random.normal(k2, (e, d, width), jnp.float32) * d ** -0.5).astype(dt),
        "wo_moe": (jax.random.normal(k3, (e, f, d), jnp.float32) * f ** -0.5).astype(dt),
    }


def _expert_ffn(params, cfg, buf):
    """buf: (e, cap, d) -> (e, cap, d).

    Constraints pin the EP (+f-over-data) layout so neither expert matmul
    gathers its weight (gathered f32 weight-grads dominated HBM otherwise).
    """
    buf = constrain(buf, "experts_act", None, None)
    h = jnp.einsum("ecz,ezf->ecf", buf, params["wi_moe"],
                   preferred_element_type=jnp.float32).astype(buf.dtype)
    h = constrain(h, "experts_act", None, "moe_f_act")
    h = mlp_activate(h, cfg.mlp_kind, buf.dtype)
    h = constrain(h, "experts_act", None, "moe_f_act")
    out = jnp.einsum("ecf,efz->ecz", h, params["wo_moe"],
                     preferred_element_type=jnp.float32).astype(buf.dtype)
    return constrain(out, "experts_act", None, None)


def moe_apply(params: dict, cfg, x: jax.Array, *, group_size: int = 4096):
    """x: (B, S, d) -> (y, aux_loss).  Capacity-dropped tokens contribute 0."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    g = min(group_size, n)
    if n % g:
        g = n  # odd smoke shapes: single group
    n_groups = n // g
    cap = max(int(cfg.capacity_factor * g * k / e), 1)

    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("Ggd,de->Gge", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    @jax.checkpoint  # backward re-derives dispatch/combine tensors per group
    def per_group(_, xs):
        xg, gi, gv = xs                                        # (g,d) (g,k) (g,k)
        onehot = jax.nn.one_hot(gi, e, dtype=jnp.float32)      # (g, k, e)
        flat = onehot.reshape(g * k, e)
        pos = (jnp.cumsum(flat, axis=0) - 1.0) * flat          # queue position
        pos = pos.reshape(g, k, e)
        keep = (pos < cap) & (onehot > 0)
        slot = jnp.where(keep, pos, cap).astype(jnp.int32)     # cap => dropped
        comb = jax.nn.one_hot(slot, cap, dtype=jnp.float32)    # (g, k, e, cap)
        comb = jnp.sum(comb * gv[..., None, None], axis=1)     # (g, e, cap)
        disp = (comb > 0).astype(xg.dtype)

        buf = jnp.einsum("gec,gz->ecz", disp, xg,
                         preferred_element_type=jnp.float32).astype(xg.dtype)
        out_e = _expert_ffn(params, cfg, buf)
        yg = jnp.einsum("gec,ecz->gz", comb.astype(xg.dtype), out_e,
                        preferred_element_type=jnp.float32).astype(xg.dtype)
        return (), yg

    _, y = jax.lax.scan(per_group, (), (xt, gate_idx, gate_vals))

    # --- Switch-style load-balance aux loss (over all tokens) --------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx.reshape(-1, k)[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * e * cfg.router_aux_coef

    return y.reshape(b, s, d), aux
