"""Model assembly: superblock-scanned heterogeneous stacks, train/prefill/
decode paths, for every assigned architecture family.

Heterogeneity (jamba 1:7 mamba:attn, gemma3 5:1 local:global, xlstm
mLSTM/sLSTM mixes) is expressed as a *superblock* -- a static tuple of
``LayerSpec``s -- scanned ``n_blocks`` times over stacked params.  The lowered
HLO contains each distinct layer body once, which is what keeps 512-device
dry-run compiles tractable at 72-layer scale.

Three execution modes share one layer dispatcher:
  * ``train``   -- full-sequence, blockwise attention, remat inside the scan,
  * ``prefill`` -- train-path compute that additionally materializes decode
                   caches (KV tensors, SSM/xLSTM states),
  * ``decode``  -- one token against the caches (``serve_step``).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import KVCache
from repro.models.layers import (
    dense, embed_init, init_dense, mlp_apply, mlp_init, model_dtype, rms_norm,
    sinusoid_pos,
)
from repro.sharding import constrain

__all__ = [
    "init_params", "forward", "loss_fn", "init_decode_state", "decode_step",
    "prefill",
]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg, spec, decoder: bool) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    if spec.kind == "attn":
        p.update(attn_mod.attn_init(ks[0], cfg))
        if decoder and cfg.cross_attention:
            p["lnx"] = jnp.zeros((d,), jnp.float32)
            p["cross"] = attn_mod.attn_init(ks[1], cfg)
    elif spec.kind == "mamba":
        p["mamba"] = ssm_mod.ssm_init(ks[0], cfg)
    elif spec.kind == "mlstm":
        p["mlstm"] = xlstm_mod.mlstm_init(ks[0], cfg)
    elif spec.kind == "slstm":
        p["slstm"] = xlstm_mod.slstm_init(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.has_mlp:
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if spec.moe:
            p["moe"] = moe_mod.moe_init(ks[2], cfg)
        else:
            p["mlp"] = mlp_init(ks[2], cfg)
    return p


def _superblock_init(key, cfg, pattern, decoder: bool) -> Tuple[Dict, ...]:
    keys = jax.random.split(key, max(len(pattern), 1))
    return tuple(
        _layer_init(k, cfg, spec, decoder) for k, spec in zip(keys, pattern)
    )


def init_params(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, model_dtype(cfg)),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.n_blocks > 0:
        block_keys = jax.random.split(ks[1], cfg.n_blocks)
        params["blocks"] = jax.vmap(
            lambda k: _superblock_init(k, cfg, cfg.block_pattern, decoder=True)
        )(block_keys)
    if cfg.tail_pattern:
        params["tail"] = _superblock_init(ks[2], cfg, cfg.tail_pattern, decoder=True)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[3], cfg.d_model, cfg.vocab, model_dtype(cfg), scale=0.02)
    if cfg.enc_blocks > 0:
        enc_keys = jax.random.split(ks[4], cfg.enc_blocks)
        enc_pattern = (type(cfg.block_pattern[0])(kind="attn"),)
        params["enc_blocks"] = jax.vmap(
            lambda k: _superblock_init(k, cfg, enc_pattern, decoder=False)
        )(enc_keys)
        params["enc_ln_f"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Layer dispatch (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(p, cfg, spec, x, aux, *, enc_mem, mode_override, collect, pos0=0):
    """Returns (x, aux, cache_or_None)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    cache = None
    if spec.kind == "attn":
        out, kv = attn_mod.attn_apply_train(
            p, cfg, h, attn_type=spec.attn_type, mode_override=mode_override,
            pos0=pos0, return_kv=collect,
        )
        x = x + out
        if enc_mem is not None and cfg.cross_attention:
            hx = rms_norm(x, p["lnx"], cfg.norm_eps)
            xo, xkv = attn_mod.attn_apply_train(
                p["cross"], cfg, hx, kv_memory=enc_mem, return_kv=collect
            )
            x = x + xo
            cache = (kv, xkv) if collect else None
        else:
            cache = (kv, None) if collect else None
    elif spec.kind == "mamba":
        out, st = ssm_mod.ssm_apply_train(p["mamba"], cfg, h, return_state=collect)
        x = x + out
        cache = st
    elif spec.kind == "mlstm":
        out = xlstm_mod.mlstm_apply_train(p["mlstm"], cfg, h)
        if collect:
            cache = xlstm_mod.mlstm_prefill_state(p["mlstm"], cfg, h)
        return x + out, aux, cache
    elif spec.kind == "slstm":
        out, st = xlstm_mod.slstm_apply_train(p["slstm"], cfg, h, return_state=collect)
        cache = st
        return x + out, aux, cache
    if spec.has_mlp:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe:
            y, a = moe_mod.moe_apply(p["moe"], cfg, h2)
            aux = aux + a
        else:
            y = mlp_apply(p["mlp"], h2, cfg.mlp_kind)
        x = x + y
    x = constrain(x, "batch", "seq_block", "embed")
    return x, aux, cache


def _stack_fwd(stacked, cfg, pattern, x, *, enc_mem, mode_override, collect,
               remat: bool, decoder: bool):
    """Scan superblocks; returns (x, aux, stacked_caches_or_None)."""

    def one_layer(p, spec, x, aux):
        return _layer_fwd(
            p, cfg, spec, x, aux,
            enc_mem=enc_mem if decoder else None,
            mode_override=mode_override, collect=collect,
        )

    def block_body(carry, block_params):
        x, aux = carry
        caches = []
        for i, (p, spec) in enumerate(zip(block_params, pattern)):
            f = one_layer
            if remat:
                # nested remat: block-level checkpoint bounds boundary storage,
                # layer-level checkpoint bounds the recompute working set to a
                # single layer's internals (critical for 8-layer jamba blocks)
                f = jax.checkpoint(
                    one_layer, policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=(1,),
                )
            x, aux, c = f(p, spec, x, aux)
            caches.append(c)
        return (x, aux), tuple(caches) if collect else None

    body = block_body
    if remat:
        body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux, caches


def _embed_tokens(params, cfg, tokens, pos0=0):
    x = params["embed"][tokens].astype(model_dtype(cfg))
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_kind == "sinusoid":
        pos = pos0 + jnp.arange(tokens.shape[1])[None, :]
        x = x + sinusoid_pos(pos, cfg.d_model).astype(x.dtype)
    return x


def _encode(params, cfg, enc_frames):
    """Whisper-style encoder over (stubbed) frame embeddings."""
    enc_pattern = (type(cfg.block_pattern[0])(kind="attn"),)
    x = enc_frames.astype(model_dtype(cfg))
    x, _, _ = _stack_fwd(
        params["enc_blocks"], cfg, enc_pattern, x,
        enc_mem=None, mode_override="bidir", collect=False, remat=True,
        decoder=False,
    )
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def forward(
    params, cfg, tokens, *,
    prefix_embeds=None, enc_frames=None, collect: bool = False, remat: bool = True,
):
    """Full-sequence forward.

    Returns (activations (B, S_total, d), aux_loss, caches, enc_mem).
    ``S_total`` includes the VLM prefix if present.
    """
    x = _embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", "seq_block", "embed")

    enc_mem = _encode(params, cfg, enc_frames) if enc_frames is not None else None

    caches_tail = []
    x, aux, caches = _stack_fwd(
        params["blocks"], cfg, cfg.block_pattern, x,
        enc_mem=enc_mem, mode_override=None, collect=collect, remat=remat,
        decoder=True,
    )
    if cfg.tail_pattern:
        for p, spec in zip(params["tail"], cfg.tail_pattern):
            x, aux, c = _layer_fwd(
                p, cfg, spec, x, aux, enc_mem=enc_mem, mode_override=None,
                collect=collect,
            )
            caches_tail.append(c)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux, (caches, tuple(caches_tail)), enc_mem


# ---------------------------------------------------------------------------
# Loss (chunked over sequence; vocab-sharded logits never fully materialized)
# ---------------------------------------------------------------------------

def _unembed(params, cfg, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return constrain(logits, "batch", "seq", "vocab")


def chunked_xent(params, cfg, x, labels, *, chunk: int = 512):
    """Mean next-token NLL.  labels < 0 are ignored.  x: (B, S, d)."""
    b, s, _ = x.shape
    c = min(chunk, s)
    while s % c:  # e.g. vlm prefix makes S=4352: largest divisor <= chunk
        c -= 1
    nc = s // c
    xs = jnp.moveaxis(x.reshape(b, nc, c, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    @jax.checkpoint  # recompute per-chunk logits in backward: saves (b,c,V) f32
    def step(carry, xs_c):
        tot, cnt = carry
        xc, lc = xs_c
        logits = _unembed(params, cfg, xc)                  # (b, c, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - tgt) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg, batch, *, remat: bool = True):
    """batch: tokens (B,S) i32, plus optional prefix_embeds / enc_frames."""
    tokens = batch["tokens"]
    x, aux, _, _ = forward(
        params, cfg, tokens,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"),
        collect=False, remat=remat,
    )
    prefix = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    # next-token labels; never predict across the prefix boundary
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1
    )
    if prefix:
        pad = jnp.full((tokens.shape[0], prefix), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = chunked_xent(params, cfg, x, labels)
    return loss + aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _layer_cache_template(cfg, spec, batch, max_len, dtype, with_cross):
    if spec.kind == "attn":
        self_c = attn_mod.init_kv_cache(cfg, batch, max_len, spec.attn_type,
                                        dtype, quant=cfg.kv_quant)
        cross_c = (
            attn_mod.init_kv_cache(cfg, batch, cfg.num_prefix_embeds or 1, "global", dtype)
            if with_cross else None
        )
        return (self_c, cross_c)
    if spec.kind == "mamba":
        return ssm_mod.init_ssm_state(cfg, batch)
    if spec.kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if spec.kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(spec.kind)


def init_decode_state(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """Zeroed decode state (works under jax.eval_shape for the dry-run)."""
    dtype = model_dtype(cfg)
    with_cross = cfg.cross_attention

    def block_caches(_):
        return tuple(
            _layer_cache_template(cfg, s, batch, max_len, dtype, with_cross)
            for s in cfg.block_pattern
        )

    state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.n_blocks:
        state["blocks"] = jax.vmap(block_caches)(jnp.arange(cfg.n_blocks))
    if cfg.tail_pattern:
        state["tail"] = tuple(
            _layer_cache_template(cfg, s, batch, max_len, dtype, with_cross)
            for s in cfg.tail_pattern
        )
    if cfg.enc_blocks:
        state["enc_mem"] = jnp.zeros(
            (batch, cfg.num_prefix_embeds or 1, cfg.d_model), dtype
        )
    return state


def _layer_decode(p, cfg, spec, x1, cache, pos):
    if spec.kind == "attn":
        self_c, cross_c = cache
        h = rms_norm(x1, p["ln1"], cfg.norm_eps)
        out, self_c = attn_mod.attn_apply_decode(
            p, cfg, h, self_c, pos, attn_type=spec.attn_type
        )
        x1 = x1 + out
        if cross_c is not None:
            hx = rms_norm(x1, p["lnx"], cfg.norm_eps)
            xo, _ = attn_mod.attn_apply_decode(
                p["cross"], cfg, hx, self_c, pos, kv_memory=cross_c
            )
            x1 = x1 + xo
        new_cache = (self_c, cross_c)
    elif spec.kind == "mamba":
        h = rms_norm(x1, p["ln1"], cfg.norm_eps)
        out, new_cache = ssm_mod.ssm_apply_decode(p["mamba"], cfg, h, cache)
        x1 = x1 + out
    elif spec.kind == "mlstm":
        h = rms_norm(x1, p["ln1"], cfg.norm_eps)
        out, new_cache = xlstm_mod.mlstm_apply_decode(p["mlstm"], cfg, h, cache)
        return x1 + out, new_cache
    elif spec.kind == "slstm":
        h = rms_norm(x1, p["ln1"], cfg.norm_eps)
        out, new_cache = xlstm_mod.slstm_apply_decode(p["slstm"], cfg, h, cache)
        return x1 + out, new_cache
    else:
        raise ValueError(spec.kind)
    if spec.has_mlp:
        h2 = rms_norm(x1, p["ln2"], cfg.norm_eps)
        if spec.moe:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, h2, group_size=x1.shape[0])
        else:
            y = mlp_apply(p["mlp"], h2, cfg.mlp_kind)
        x1 = x1 + y
    return x1, new_cache


def decode_step(params, cfg, state, token):
    """One serve step: token (B, 1) i32 -> (logits (B, 1, V) f32, new state)."""
    pos = state["pos"]
    x1 = _embed_tokens(params, cfg, token, pos0=pos)
    x1 = constrain(x1, "batch", None, "embed")

    def block_body(x1, xs):
        block_params, block_cache = xs
        new_caches = []
        for i, spec in enumerate(cfg.block_pattern):
            x1, nc = _layer_decode(block_params[i], cfg, spec, x1, block_cache[i], pos)
            new_caches.append(nc)
        return x1, tuple(new_caches)

    new_state = dict(state)
    if cfg.n_blocks:
        x1, new_blocks = jax.lax.scan(
            block_body, x1, (params["blocks"], state["blocks"])
        )
        new_state["blocks"] = new_blocks
    if cfg.tail_pattern:
        new_tail = []
        for p, spec, c in zip(params["tail"], cfg.tail_pattern, state["tail"]):
            x1, nc = _layer_decode(p, cfg, spec, x1, c, pos)
            new_tail.append(nc)
        new_state["tail"] = tuple(new_tail)

    x1 = rms_norm(x1, params["ln_f"], cfg.norm_eps)
    logits = _unembed(params, cfg, x1)
    new_state["pos"] = pos + 1
    return logits, new_state


def prefill(params, cfg, tokens, *, prefix_embeds=None, enc_frames=None,
            max_len: Optional[int] = None):
    """Process a prompt; returns (last-position logits, ready decode state)."""
    s_total = tokens.shape[1] + (
        prefix_embeds.shape[1] if prefix_embeds is not None else 0
    )
    max_len = max_len or s_total
    x, _, (caches, tail_caches), enc_mem = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds, enc_frames=enc_frames,
        collect=True, remat=False,
    )
    batch = tokens.shape[0]
    state = init_decode_state(cfg, batch, max_len)
    state["pos"] = jnp.asarray(s_total, jnp.int32)

    if cfg.n_blocks:
        state["blocks"] = _fill_stacked(cfg, state["blocks"], caches, s_total, max_len)
    if cfg.tail_pattern:
        state["tail"] = tuple(
            _fill_cache(cfg, spec, t, g, s_total, max_len)
            for spec, t, g in zip(cfg.tail_pattern, state["tail"], tail_caches)
        )
    if enc_mem is not None:
        state["enc_mem"] = enc_mem
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, state


def _fill_kv(cfg, attn_type, template, got, s_total, max_len):
    k, v = got
    quant = isinstance(template, attn_mod.QuantKVCache)
    c = (template.k_q if quant else template.k).shape[1]
    if attn_type == "local" and s_total > c:
        # ring buffer: keep the last ``window`` entries at their ring slots
        start = s_total - c
        k = jax.lax.dynamic_slice_in_dim(k, start, c, axis=1)
        v = jax.lax.dynamic_slice_in_dim(v, start, c, axis=1)
        roll = s_total % c  # ring offset: slot(p) = p mod c
        k = jnp.roll(k, roll, axis=1)
        v = jnp.roll(v, roll, axis=1)
    else:
        pad = [(0, 0), (0, c - k.shape[1]), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    if quant:
        k_q, k_s = attn_mod._quantize(k)
        v_q, v_s = attn_mod._quantize(v)
        return attn_mod.QuantKVCache(k_q=k_q, v_q=v_q, k_s=k_s, v_s=v_s)
    return KVCache(k=k.astype(template.k.dtype), v=v.astype(template.v.dtype))


def _fill_cache(cfg, spec, template, got, s_total, max_len):
    if spec.kind == "attn":
        kv, xkv = got
        self_t, cross_t = template
        self_c = _fill_kv(cfg, spec.attn_type, self_t, kv, s_total, max_len)
        cross_c = cross_t
        if cross_t is not None and xkv is not None:
            cross_c = KVCache(
                k=xkv[0].astype(cross_t.k.dtype), v=xkv[1].astype(cross_t.v.dtype)
            )
        return (self_c, cross_c)
    return got  # recurrent states pass through


def _fill_stacked(cfg, templates, got, s_total, max_len):
    """Stacked (scan ys) caches -> decode-state layout, per superblock slot."""
    out = []
    for i, spec in enumerate(cfg.block_pattern):
        t_i = jax.tree.map(lambda a: a, _tuple_idx(templates, i))
        g_i = _tuple_idx(got, i)
        if spec.kind == "attn":
            filled = jax.vmap(
                lambda t, g: _fill_cache(cfg, spec, t, g, s_total, max_len),
                in_axes=(0, 0),
            )(t_i, g_i)
        else:
            filled = g_i
        out.append(filled)
    return tuple(out)


def _tuple_idx(tree_of_tuples, i):
    return tree_of_tuples[i]
