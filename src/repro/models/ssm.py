"""Mamba-style selective SSM block (jamba's recurrent layer).

Selective scan h_t = exp(-dt_t * A) h_{t-1} + dt_t * (B_t x_t), y_t = C_t h_t
+ D x_t with input-dependent (B, C, dt).  TPU adaptation: a two-level scan --
outer ``lax.scan`` over time chunks, inner ``associative_scan`` within the
chunk -- so the (B, chunk, d_in, state) intermediate stays VMEM-scale while
the sequential depth drops from S to S/chunk.  Decode is the O(1) recurrent
step on a persistent (B, d_in, state) state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, model_dtype

__all__ = ["ssm_init", "ssm_apply_train", "SSMState", "init_ssm_state", "ssm_apply_decode"]


class SSMState(NamedTuple):
    h: jax.Array        # (B, d_in, state) f32
    conv_buf: jax.Array # (B, conv-1, d_in) -- trailing inputs for causal conv


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return d_in, dt_rank, cfg.ssm_state


def ssm_init(key, cfg) -> dict:
    dt = model_dtype(cfg)
    d, (d_in, dt_rank, st) = cfg.d_model, _dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "in_proj": init_dense(ks[0], d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": init_dense(ks[2], d_in, dt_rank + 2 * st, dt),
        "dt_proj": init_dense(ks[3], dt_rank, d_in, dt),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(a),                               # (d_in, state) f32
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_dense(ks[4], d_in, d, dt),
    }


def _causal_conv(x, w, b, prepend=None):
    """Depthwise causal conv along time.  x: (B, S, d_in); w: (K, d_in)."""
    k = w.shape[0]
    pad = x if prepend is None else jnp.concatenate([prepend.astype(x.dtype), x], axis=1)
    if prepend is None:
        pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(pad[:, k - 1:])
    for i in range(k):  # K is tiny (4): unrolled taps
        out = out + pad[:, i: i + out.shape[1]] * w[i][None, None, :]
    return out + b[None, None, :]


def _selective_terms(params, cfg, xs, mask=None):
    """xs: (B, S, d_in) post-conv activations -> decay a_t, input b_t, C_t.

    ``mask`` (S,) zeroes dt on padded steps (decay=1, drive=0: identity)."""
    d_in, dt_rank, st = _dims(cfg)
    proj = dense(xs, params["x_proj"])
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + st], axis=-1)
    dt_full = dense(dt_low, params["dt_proj"]).astype(jnp.float32)
    dt_t = jax.nn.softplus(dt_full + params["dt_bias"])          # (B,S,d_in)
    if mask is not None:
        dt_t = dt_t * mask[None, :, None]
    a = -jnp.exp(params["a_log"])                                # (d_in, st)
    decay = jnp.exp(dt_t[..., None] * a[None, None])             # (B,S,d_in,st)
    # drive_t[b,s,d,n] = dt[b,s,d] * x[b,s,d] * B[b,s,n]
    drive = (dt_t * xs.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
    return decay, drive, cmat.astype(jnp.float32)


def _chunk_scan(decay, drive, h0):
    """Associative scan within a chunk.  decay/drive: (B, C, d_in, st)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_acc, b_acc = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    hs = a_acc * h0[:, None] + b_acc                 # (B, C, d_in, st)
    return hs, hs[:, -1]


def ssm_apply_train(params: dict, cfg, x: jax.Array, *, return_state: bool = False):
    """x: (B, S, d) -> (y, SSMState|None).  Chunked selective scan."""
    b, s, d = x.shape
    d_in, _, st = _dims(cfg)
    xz = dense(x, params["in_proj"])
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = _causal_conv(xs_raw, params["conv_w"], params["conv_b"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    chunk = min(cfg.ssm_chunk, s)
    s_pad = (s + chunk - 1) // chunk * chunk
    if s_pad != s:
        xs = jnp.pad(xs, ((0, 0), (0, s_pad - s), (0, 0)))
    nc = s_pad // chunk
    valid = (jnp.arange(s_pad) < s).astype(jnp.float32)

    # checkpointed chunks with the selective terms (the (B,C,d_in,st) decay /
    # drive tensors) derived *inside* the chunk: full-sequence variants would
    # be ~S/chunk times larger than the whole block's other activations
    @jax.checkpoint
    def outer(h, xs_chunk):
        x_c, m_c = xs_chunk
        dec_c, drv_c, c_c = _selective_terms(params, cfg, x_c, mask=m_c)
        hs, h_next = _chunk_scan(dec_c, drv_c, h)
        y = jnp.einsum("bcds,bcs->bcd", hs, c_c)     # C_t . h_t
        y = y + params["d_skip"][None, None, :] * x_c.astype(jnp.float32)
        return h_next, y

    xs_c = jnp.moveaxis(xs.reshape(b, nc, chunk, d_in), 1, 0)
    m_c = valid.reshape(nc, chunk)
    h0 = jnp.zeros((b, d_in, st), jnp.float32)
    h_fin, ys = jax.lax.scan(outer, h0, (xs_c, m_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, d_in)[:, :s].astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(y, params["out_proj"])
    state = None
    if return_state:
        kc = cfg.ssm_conv - 1
        buf = jnp.pad(xs_raw.astype(jnp.float32), ((0, 0), (kc, 0), (0, 0)))[:, -kc:]
        state = SSMState(h=h_fin, conv_buf=buf)
    return out, state


def init_ssm_state(cfg, batch: int) -> SSMState:
    d_in, _, st = _dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, d_in, st), jnp.float32),
        conv_buf=jnp.zeros((batch, cfg.ssm_conv - 1, d_in), jnp.float32),
    )


def ssm_apply_decode(params: dict, cfg, x1: jax.Array, state: SSMState):
    """One-token step.  x1: (B, 1, d) -> (out, new_state)."""
    d_in, _, st = _dims(cfg)
    xz = dense(x1, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                          # (B,1,d_in)
    xs_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                           prepend=state.conv_buf)
    xs_conv = xs_conv[:, -1:]                                  # newest step
    xs_act = jax.nn.silu(xs_conv.astype(jnp.float32)).astype(x1.dtype)

    decay, drive, cmat = _selective_terms(params, cfg, xs_act)
    h = decay[:, 0] * state.h + drive[:, 0]                    # (B, d_in, st)
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :]
    y = y + params["d_skip"][None, None, :] * xs_act.astype(jnp.float32)
    y = y.astype(x1.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)

    new_buf = jnp.concatenate(
        [state.conv_buf[:, 1:], xs.astype(jnp.float32)], axis=1
    )
    return dense(y, params["out_proj"]), SSMState(h=h, conv_buf=new_buf)
