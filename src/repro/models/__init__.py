"""Model zoo substrate: layers, attention, MoE, SSM, xLSTM, assembly."""
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.params import count_params, param_shapes

__all__ = [
    "decode_step", "forward", "init_decode_state", "init_params", "loss_fn",
    "prefill", "count_params", "param_shapes",
]
