"""Parameter accounting (feeds MODEL_FLOPS = 6*N*D in the roofline)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["param_shapes", "count_params"]


@functools.lru_cache(maxsize=64)
def param_shapes(cfg):
    """Abstract param tree (ShapeDtypeStructs) -- no allocation."""
    from repro.models.transformer import init_params

    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _leaf_count(path_str: str, leaf, cfg, active_only: bool) -> int:
    n = 1
    for s in leaf.shape:
        n *= s
    if active_only and ("_moe" in path_str) and cfg.n_experts:
        # only top_k of n_experts experts touch each token
        n = n * cfg.top_k // cfg.n_experts
    return n


def count_params(cfg, active_only: bool = False) -> int:
    from repro.sharding.partition import _path_str

    shapes = param_shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        total += _leaf_count(_path_str(path), leaf, cfg, active_only)
    return total
