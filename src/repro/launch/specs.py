"""``input_specs``: weak-type-correct ShapeDtypeStruct stand-ins + shardings
for every (arch x shape) dry-run cell.  No device allocation anywhere --
states come from ``jax.eval_shape`` over the real constructors, so the specs
can never drift from the model code.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models import init_decode_state, param_shapes
from repro.sharding.partition import _path_str, logical_to_spec, param_specs
from repro.train.optimizer import OptConfig, opt_init
from repro.train.steps import init_train_state

__all__ = [
    "train_batch_specs", "decode_state_specs", "abstract_train_state",
    "abstract_decode_state", "batch_shardings", "state_shardings", "input_specs",
]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.frontend == "patches":
        batch["prefix_embeds"] = _sds((b, cfg.num_prefix_embeds, cfg.d_model), cfg.dtype)
    elif cfg.frontend == "frames":
        batch["enc_frames"] = _sds((b, cfg.num_prefix_embeds, cfg.d_model), cfg.dtype)
    return batch


def abstract_train_state(cfg: ModelConfig, oc: OptConfig):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, oc)
    )


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def batch_shardings(batch, mesh: Mesh):
    def spec(path, leaf):
        logical = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, logical_to_spec(logical, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch)


_DECODE_RULES = {
    # KVCache leaves: (..., B, C, kv, hd) -- kv heads shard when divisible,
    # else head_dim (flash-decoding-style splits stay available via kv_seq)
    "k": ("batch", None, "kv_heads", "head_dim"),
    "v": ("batch", None, "kv_heads", "head_dim"),
    "k_q": ("batch", None, "kv_heads", "head_dim"),
    "v_q": ("batch", None, "kv_heads", "head_dim"),
    "k_s": ("batch", None, "kv_heads", None),
    "v_s": ("batch", None, "kv_heads", None),
    # mamba
    "h": ("batch", "ssm_inner", None),
    "conv_buf": ("batch", None, "ssm_inner"),
    # xlstm
    "c": ("batch", "heads", None, None),
    "n": ("batch", "heads", None),
    "m": ("batch", "heads"),
    "enc_mem": ("batch", None, None),
    "pos": (),
}

_DECODE_RULES_BY_RANK = {  # (name, rank) overrides (slstm c/n are rank 3)
    ("c", 3): ("batch", "heads", None),
}


def decode_state_specs(state, mesh: Mesh):
    def spec(path, leaf):
        name = None
        for part in reversed(_path_str(path).split("/")):
            if not part.isdigit():
                name = part
                break
        logical = _DECODE_RULES_BY_RANK.get((name, len(leaf.shape)))
        if logical is None:
            logical = _DECODE_RULES.get(name)
        if logical is None:
            return NamedSharding(mesh, P())
        pad = (None,) * (len(leaf.shape) - len(logical))
        return NamedSharding(
            mesh, logical_to_spec(pad + tuple(logical), leaf.shape, mesh)
        )

    return jax.tree_util.tree_map_with_path(spec, state)


def state_shardings(state, mesh: Mesh):
    """Train-state shardings: params/opt via the param partitioner."""
    def spec(path, leaf):
        p = _path_str(path)
        from repro.sharding.partition import spec_for_path

        return NamedSharding(mesh, spec_for_path(p, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, state)


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------

def default_accum_steps(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Gradient-accumulation policy: keep per-microbatch activations HBM-sized."""
    if shape.step != "train":
        return 1
    n = cfg.param_count()
    if n > 1e11:
        return 8
    if n > 2e10:
        return 4
    return 1


def input_specs(cfg: ModelConfig, shape_name: str, oc: Optional[OptConfig] = None):
    """Abstract inputs for one dry-run cell.

    Returns dict with ``kind`` (train|prefill|decode), ``args`` (pytree of
    ShapeDtypeStructs matching the step function signature) and a
    ``shardings(mesh)`` callable producing matching NamedShardings.
    """
    shape = SHAPES[shape_name]
    oc = oc or OptConfig(moments_dtype="bfloat16" if cfg.param_count() > 3e10 else "float32")

    if shape.step == "train":
        state = abstract_train_state(cfg, oc)
        batch = train_batch_specs(cfg, shape)

        def shardings(mesh):
            return (state_shardings(state, mesh), batch_shardings(batch, mesh))

        return {"kind": "train", "args": (state, batch), "shardings": shardings,
                "opt_config": oc, "accum_steps": default_accum_steps(cfg, shape)}

    if shape.step == "prefill":
        batch = train_batch_specs(cfg, shape)
        tokens = batch.pop("tokens")
        args = (tokens, batch)

        def shardings(mesh):
            return (batch_shardings(tokens, mesh), batch_shardings(batch, mesh))

        return {"kind": "prefill", "args": args, "shardings": shardings,
                "opt_config": oc}

    # decode: one new token against a seq_len cache
    state = abstract_decode_state(cfg, shape.global_batch, shape.seq_len)
    token = _sds((shape.global_batch, 1), jnp.int32)

    def shardings(mesh):
        return (decode_state_specs(state, mesh), batch_shardings(token, mesh))

    return {"kind": "decode", "args": (state, token), "shardings": shardings,
            "opt_config": oc}
