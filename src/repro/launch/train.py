"""Training launcher: data pipeline -> train loop with checkpointing,
SymED telemetry, straggler watchdog, and elastic restart.

This is the end-to-end driver; ``examples/train_lm.py`` wraps it with a
~100M-param preset over SymED-symbolized sensor streams.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ModelConfig, attn
from repro.core.symed import SymEDConfig
from repro.data import SymbolPipeline, SymbolTokenizer, TokenBatcher
from repro.launch.mesh import make_test_mesh
from repro.sharding import use_mesh_rules
from repro.launch.specs import state_shardings
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step
from repro.train.telemetry import StepWatchdog, TelemetryHub

__all__ = ["train_loop", "lm100m_config", "main"]


def lm100m_config(vocab: int) -> ModelConfig:
    """~100M-param decoder-only LM for the end-to-end example."""
    return ModelConfig(
        name="symlm-100m", family="dense", d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab=vocab, head_dim=64,
        block_pattern=(attn("global"),), n_blocks=12, mlp_kind="swiglu",
        tie_embeddings=True, supports_long_ctx=False, dtype="float32",
    )


def train_loop(
    cfg: ModelConfig,
    *,
    steps: int = 50,
    batch: int = 8,
    seq: int = 256,
    lr: float = 3e-4,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 25,
    symed: Optional[SymEDConfig] = None,
    resume: bool = True,
    log_every: int = 5,
    fail_at_step: Optional[int] = None,
):
    """Runs the full production loop on whatever devices exist."""
    symed = symed or SymEDConfig(tol=0.5, alpha=0.02, n_max=256, k_max=64,
                                 len_max=128)
    tok = SymbolTokenizer(k_max=symed.k_max)
    assert cfg.vocab >= tok.vocab_size, "config vocab must cover the tokenizer"

    pipe = SymbolPipeline(symed, tok, stream_len=1024, slab=32)
    batches = iter(TokenBatcher(pipe, batch, seq + 1))

    oc = OptConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))

    state = init_train_state(jax.random.key(0), cfg, oc)
    mgr = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
    start = 0
    if mgr and resume:
        restored, manifest = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start = int(manifest["step"])
            print(f"[train] resumed from step {start}")

    hub = TelemetryHub(tol=0.3, alpha=0.05)
    dog = StepWatchdog()
    history = []
    for step in range(start, steps):
        toks = next(batches)
        dog.start_step()
        state, metrics = step_fn(state, {"tokens": jnp.asarray(toks[:, :seq + 1])})
        jax.block_until_ready(metrics["loss"])
        ev = dog.end_step(step)
        if ev:
            print(f"[watchdog] {ev['kind']} at step {ev['step']}: "
                  f"{ev['dt']:.2f}s (z={ev['z']:.1f})")
        hub.record_metrics("host0", {k: float(v) for k, v in metrics.items()})
        history.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"[train] step {step}: loss={history[-1]:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")
        if mgr:
            mgr.maybe_save(step + 1, state)
        if fail_at_step is not None and step + 1 == fail_at_step:
            raise RuntimeError(f"simulated node failure at step {step + 1}")

    report = hub.traffic_report()
    tele_raw = sum(r["raw_bytes"] for r in report.values())
    tele_wire = sum(r["wire_bytes"] for r in report.values())
    print(f"[telemetry] raw={tele_raw}B wire={tele_wire}B "
          f"cr={tele_wire / max(tele_raw, 1):.3f} across {len(report)} streams")
    return state, {"loss_history": history, "telemetry": report,
                   "watchdog_events": dog.events}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id; default: symlm-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="raise a simulated node failure at this step")
    args = ap.parse_args()

    tok_vocab = SymbolTokenizer(k_max=64).vocab_size
    if args.arch:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    else:
        cfg = lm100m_config(vocab=max(tok_vocab, 128))
    cfg = dataclasses.replace(cfg, vocab=max(cfg.vocab, tok_vocab))

    t0 = time.perf_counter()
    _, report = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at_step,
    )
    print(f"[train] done in {time.perf_counter() - t0:.1f}s; "
          f"final loss {report['loss_history'][-1]:.4f}")


if __name__ == "__main__":
    main()
