import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles abstract inputs (ShapeDtypeStructs -- no allocation) and
     NamedShardings from the partitioner,
  3. jits the right step function (train_step / prefill / serve decode_step)
     with explicit in/out shardings, ``.lower()``s and ``.compile()``s it,
  4. records memory_analysis(), cost_analysis(), the parsed collective
     inventory, and the three roofline terms to JSON.

Any sharding mismatch, compile-time OOM, or unsupported collective is a bug
in the system and fails the cell.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh multipod --out results/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             grad_compress: bool = False, accum_steps: int | None = None,
             no_sp: bool = False, kv_int8: bool = False) -> dict:
    from repro.configs import SHAPES, get_config, shapes_for
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs
    from repro.models import count_params, decode_step, loss_fn, prefill
    from repro.sharding import use_mesh_rules
    from repro.train.steps import (
        make_compressed_train_step, make_train_step,
    )
    from repro.utils.flopcount import analytic_cell
    from repro.utils.hlo import collective_wire_bytes, parse_collectives, roofline_terms

    cfg = get_config(arch)
    if kv_int8:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_quant=True)
    if shape_name not in shapes_for(cfg):
        raise ValueError(f"{arch} skips {shape_name}: {cfg.long_ctx_note}")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    spec = input_specs(cfg, shape_name)
    oc = spec["opt_config"]

    t0 = time.perf_counter()
    exclude = ("pod",) if grad_compress else ()
    disable = ("seq_block",) if no_sp else ()
    with mesh, use_mesh_rules(mesh, exclude=exclude, disable=disable):
        in_shardings = spec["shardings"](mesh)
        if spec["kind"] == "train":
            accum = accum_steps if accum_steps is not None else spec["accum_steps"]
            if grad_compress:
                import jax as _jax
                import jax.numpy as _jnp

                from repro.launch.specs import state_shardings

                step = make_compressed_train_step(cfg, oc, mesh)
                state_abs, batch_abs = spec["args"]
                state_abs = dict(state_abs)
                state_abs["error_fb"] = _jax.eval_shape(
                    lambda p: _jax.tree.map(
                        lambda x: _jnp.zeros(x.shape, _jnp.bfloat16), p),
                    state_abs["params"],
                )
                spec = dict(spec)
                spec["args"] = (state_abs, batch_abs)
                in_shardings = (state_shardings(state_abs, mesh), in_shardings[1])
            else:
                step = make_train_step(cfg, oc, accum_steps=accum)
            out_shardings = (in_shardings[0], None)
            jitted = jax.jit(
                step, in_shardings=in_shardings, out_shardings=out_shardings,
                donate_argnums=(0,),
            )
        elif spec["kind"] == "prefill":
            from repro.launch.specs import abstract_train_state, state_shardings

            astate = abstract_train_state(cfg, oc)
            params_abs = astate["params"]
            p_shard = state_shardings({"params": params_abs}, mesh)["params"]

            def prefill_fn(params, tokens, extras):
                return prefill(
                    params, cfg, tokens,
                    prefix_embeds=extras.get("prefix_embeds"),
                    enc_frames=extras.get("enc_frames"),
                )

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(p_shard,) + in_shardings,
            )
            spec = dict(spec)
            spec["args"] = (params_abs,) + spec["args"]
        else:  # decode
            from repro.launch.specs import abstract_train_state, state_shardings

            astate = abstract_train_state(cfg, oc)
            params_abs = astate["params"]
            p_shard = state_shardings({"params": params_abs}, mesh)["params"]

            def decode_fn(params, state, token):
                return decode_step(params, cfg, state, token)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(p_shard,) + in_shardings,
                out_shardings=(None, in_shardings[0]),
                donate_argnums=(1,),
            )
            spec = dict(spec)
            spec["args"] = (params_abs,) + spec["args"]

        args = spec["args"]
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)   # while-trip-count aware
    wire = collective_wire_bytes(colls)

    n_chips = mesh.devices.size
    n_params = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    model_shards = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    # analytic flops/bytes: cost_analysis counts scan bodies once (see
    # utils/flopcount docstring), so the roofline terms use the analytic model
    ana = analytic_cell(cfg, shape_name, n_chips, model_shards)
    terms = roofline_terms(ana["flops_per_dev"], ana["hbm_bytes_per_dev"], wire)
    model_flops = ana["model_flops"]

    per_op = {}
    for c in colls:
        per_op.setdefault(c["op"], {"count": 0.0, "weighted_result_bytes": 0.0})
        per_op[c["op"]]["count"] += c.get("count", 1.0)
        per_op[c["op"]]["weighted_result_bytes"] += (
            c["result_bytes"] * c.get("count", 1.0)
        )

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": spec["kind"],
        "grad_compress": grad_compress,
        "seq_parallel": not no_sp,
        "n_chips": n_chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "compile_seconds": round(time.perf_counter() - t0, 1),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_dev": ana["flops_per_dev"],
            "hbm_bytes_per_dev": ana["hbm_bytes_per_dev"],
            "wire_bytes_per_dev": wire,
            "xla_flops_per_dev_raw": float(cost.get("flops", 0.0)),
            "xla_bytes_per_dev_raw": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": per_op,
        "roofline": terms,
        "model_flops": model_flops,
        "useful_flops_ratio": (
            model_flops / (ana["flops_per_dev"] * n_chips)
            if ana["flops_per_dev"] else None
        ),
    }
    return result


def iter_cells(mesh_kind: str):
    from repro.configs import ARCHS, shapes_for

    for arch, cfg in ARCHS.items():
        for shape in shapes_for(cfg):
            yield arch, shape, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--accum", type=int, default=None,
                    help="override gradient-accumulation steps (train cells)")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel block boundaries")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV caches (decode cells)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = list(iter_cells(args.mesh))
    elif args.arch and not args.shape:
        from repro.configs import get_config, shapes_for

        cells = [(args.arch, s, args.mesh) for s in shapes_for(get_config(args.arch))]
    else:
        cells = [(args.arch, args.shape, args.mesh)]
    failures = 0
    for arch, shape, mesh_kind in cells:
        tag = (f"{arch}_{shape}_{mesh_kind}" + ("_i8" if args.grad_compress else "")
               + (f"_{args.tag}" if args.tag else ""))
        try:
            res = run_cell(arch, shape, mesh_kind, grad_compress=args.grad_compress,
                           accum_steps=args.accum, no_sp=args.no_sp,
                           kv_int8=args.kv_int8)
            (out_dir / f"{tag}.json").write_text(json.dumps(res, indent=2))
            m = res["memory"]
            r = res["roofline"]
            print(
                f"OK   {tag}: peak/dev={m['peak_bytes_per_dev']/2**30:.2f}GiB "
                f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']} "
                f"(compiled in {res['compile_seconds']}s)"
            )
        except Exception as e:  # noqa: BLE001 -- report and continue the sweep
            failures += 1
            (out_dir / f"{tag}.FAILED.txt").write_text(traceback.format_exc())
            print(f"FAIL {tag}: {type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
