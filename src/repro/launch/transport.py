"""Sender->receiver wire transport: the paper's deployment, on real sockets.

``repro.launch.stream`` made the receiver resident; this module puts the
*network* in front of it.  The paper's low-powered senders compress locally
and transmit piece tuples to an edge receiver that digitizes them -- SymED's
headline result is that this wire carries ~9.5% of the raw traffic.  Until
now that number was asserted by telemetry arithmetic; here it is exercised:
a ``SenderClient`` runs the O(1) ``CompressorState`` locally and ships
finished pieces over TCP, a socket server loop decodes concurrent
interleaved sessions into batched ``StreamServer.ingest_many`` /
``ingest_pieces_many`` calls, and the receiver's symbol-delta frames travel
back on the same socket -- both directions of the ROADMAP wire story are
measurable (``wire_in_bytes`` / ``wire_in_ratio`` next to the existing
wire-out numbers).

Wire format (all integers big-endian):

    frame   := u32 body_len, body
    body    := u8 type, u8 sid_len, sid bytes, payload

    type  payload                                           direction
    ----  ------------------------------------------------  ---------
    OPEN    u8 mode (0 raw / 1 pieces), u32 digitizer seed  sender ->
    DATA    raw:    u32 n, n x f32 raw points               sender ->
            pieces: f32 t0 hello, u32 t_seen, u32 n,
                    n x (f32 endpoint + u32 arrival step)   sender ->
    CLOSE   u32 t_seen, u8 has_tail [, f32 tail endpoint]   sender ->
    DELTA   symbol-delta frame: u32 n, n x (u8 label +
            f32 endpoint)  -- ``receiver.pack_delta_frame``  <- receiver
    CLOSED  u32 n_pieces, u32 t_seen, u8 evicted,
            closing DELTA payload                            <- receiver
    ERROR   utf-8 message                                    <- receiver

The DELTA payload is byte-for-byte the 4 B header + 5 B/symbol layout the
service already accounts (``DELTA_FRAME_HEADER_BYTES`` /
``DELTA_SYMBOL_BYTES``); the pieces DATA payload carries the t0 "hello"
on every frame (idempotent -- the receiver consumes it only while
``t_seen == 0``) plus ``PIECE_TUPLE_BYTES`` per piece.  Raw-in and
compressed-in sessions may interleave on one server; per-session outputs
are bitwise-equal across modes (``tests/test_transport.py``).

CLI (loopback demo wiring; ``--serve`` and ``--send`` are the halves the
CI transport-smoke job runs as separate processes):

    PYTHONPATH=src python -m repro.launch.transport --serve --port 7543 \
        --autoscale --min-slots 8 --max-slots 16 --devices 8 \
        --expect-sessions 14
    PYTHONPATH=src python -m repro.launch.transport --send --port 7543 \
        --streams 10 --length 192 --mode pieces --verify
    PYTHONPATH=src python -m repro.launch.transport            # in-process demo
"""
from __future__ import annotations

import sys

if __name__ == "__main__":  # pragma: no cover -- CLI path only
    # Must precede the jax import below (jax locks the device count on
    # first init); shared pre-scan with the stream/fleet/workload CLIs.
    from repro.launch.cli import prescan_host_devices

    prescan_host_devices()

import argparse
import select
import socket
import struct
import time
import zlib
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.receiver import (
    PIECE_TUPLE_BYTES, pack_delta_frame, pack_piece_tuples,
    unpack_delta_frame, unpack_piece_tuples,
)

__all__ = [
    "OPEN", "DATA", "CLOSE", "DELTA", "CLOSED", "ERROR",
    "Frame", "FrameDecoder", "SenderClient", "TransportServer",
    "encode_open", "encode_data_raw", "encode_data_pieces", "encode_close",
    "encode_delta", "encode_closed", "encode_error", "main",
]

OPEN, DATA, CLOSE, DELTA, CLOSED, ERROR = 1, 2, 3, 4, 5, 6
MODE_RAW, MODE_PIECES = 0, 1
MAX_FRAME = 1 << 22  # 4 MiB: a decoder guard against garbage length prefixes


class Frame(NamedTuple):
    type: int
    sid: str
    payload: bytes


def _frame(ftype: int, sid: str, payload: bytes = b"") -> bytes:
    sid_b = sid.encode("utf-8")
    if len(sid_b) > 255:
        raise ValueError(f"session id too long ({len(sid_b)} bytes)")
    body = struct.pack("!BB", ftype, len(sid_b)) + sid_b + payload
    return struct.pack("!I", len(body)) + body


def encode_open(sid: str, mode: int, seed: int) -> bytes:
    return _frame(OPEN, sid, struct.pack("!BI", mode, seed & 0xFFFFFFFF))


def encode_data_raw(sid: str, window) -> bytes:
    w = np.asarray(window, np.float32).reshape(-1)
    return _frame(
        DATA, sid, struct.pack("!I", w.shape[0]) + w.astype(">f4").tobytes())


def encode_data_pieces(sid: str, t0: float, t_seen: int, endpoints,
                       steps) -> bytes:
    endpoints = np.asarray(endpoints, np.float32).reshape(-1)
    head = struct.pack("!fII", t0, t_seen, endpoints.shape[0])
    return _frame(DATA, sid, head + pack_piece_tuples(endpoints, steps))


def encode_close(sid: str, t_seen: int = 0,
                 tail_endpoint: Optional[float] = None) -> bytes:
    payload = struct.pack("!IB", t_seen, tail_endpoint is not None)
    if tail_endpoint is not None:
        payload += struct.pack("!f", tail_endpoint)
    return _frame(CLOSE, sid, payload)


def encode_delta(sid: str, labels, endpoints) -> bytes:
    return _frame(DELTA, sid, pack_delta_frame(labels, endpoints))


def encode_closed(sid: str, n_pieces: int, t_seen: int, evicted: bool,
                  labels, endpoints) -> bytes:
    head = struct.pack("!IIB", n_pieces, t_seen, bool(evicted))
    return _frame(CLOSED, sid, head + pack_delta_frame(labels, endpoints))


def encode_error(sid: str, message: str) -> bytes:
    return _frame(ERROR, sid, message.encode("utf-8"))


class FrameDecoder:
    """Incremental frame decoder: feed arbitrary byte slices, get frames.

    TCP is a byte stream -- a frame may arrive split across any number of
    ``recv`` calls, and one ``recv`` may carry many frames (the property
    battery in ``tests/test_transport.py`` slices the stream at random
    boundaries).  The decoder buffers until a length prefix and its body are
    complete, then yields ``Frame(type, sid, payload)``.
    """

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[Frame]:
        self._buf.extend(data)
        frames = []
        while True:
            if len(self._buf) < 4:
                return frames
            (body_len,) = struct.unpack_from("!I", self._buf)
            if body_len < 2 or body_len > MAX_FRAME:
                raise ValueError(f"bad frame length {body_len}")
            if len(self._buf) < 4 + body_len:
                return frames
            body = bytes(self._buf[4: 4 + body_len])
            del self._buf[: 4 + body_len]
            ftype, sid_len = struct.unpack_from("!BB", body)
            if 2 + sid_len > len(body):
                raise ValueError("frame shorter than its session id")
            sid = body[2: 2 + sid_len].decode("utf-8")
            frames.append(Frame(ftype, sid, body[2 + sid_len:]))


def decode_open(payload: bytes) -> Tuple[int, int]:
    mode, seed = struct.unpack_from("!BI", payload)
    return mode, seed


def decode_data_raw(payload: bytes) -> np.ndarray:
    (n,) = struct.unpack_from("!I", payload)
    return np.frombuffer(payload, ">f4", count=n, offset=4).astype(np.float32)


def decode_data_pieces(payload: bytes):
    t0, t_seen, n = struct.unpack_from("!fII", payload)
    endpoints, steps = unpack_piece_tuples(payload[12:], n)
    return t0, t_seen, endpoints, steps


def decode_close(payload: bytes):
    t_seen, has_tail = struct.unpack_from("!IB", payload)
    tail = struct.unpack_from("!f", payload, 5)[0] if has_tail else None
    return t_seen, tail


def decode_closed(payload: bytes):
    n_pieces, t_seen, evicted = struct.unpack_from("!IIB", payload)
    labels, endpoints = unpack_delta_frame(payload[9:])
    return {"n_pieces": n_pieces, "t_seen": t_seen, "evicted": bool(evicted),
            "labels": labels, "endpoints": endpoints}


def session_seed(sid: str, base_seed: int) -> int:
    """Deterministic per-session digitizer seed both halves can derive."""
    return (zlib.crc32(sid.encode("utf-8")) ^ base_seed) & 0xFFFFFFFF


# --------------------------------------------------------------------- sender


class _ClientSession:
    def __init__(self, sid: str, mode: int):
        self.sid = sid
        self.mode = mode
        self.state = None          # pieces mode: resident CompressorState
        self.t0 = 0.0
        self.t_seen = 0
        self.payload_bytes = 0.0   # outbound payload bytes (sans framing)
        self.deltas: List[Tuple[np.ndarray, np.ndarray]] = []
        self.result: Optional[dict] = None


class SenderClient:
    """The paper's IoT-node half, speaking the transport's wire format.

    ``mode="pieces"`` runs the O(1) sender compressor locally
    (``symed_encode_chunk`` windows, same arithmetic as the receiver's
    raw-mode scan, so outputs stay bitwise-equal) and ships only finished
    piece tuples; ``mode="raw"`` ships the raw f32 windows and lets the edge
    run the compressor.  Several sessions may interleave over the one
    connection.  Inbound DELTA frames are collected per session
    (``delta_concat`` joins them); ``close`` blocks until the receiver's
    CLOSED frame arrives and returns its summary.
    """

    def __init__(self, host: str, port: int, cfg, mode: str = "pieces",
                 connect_timeout: float = 60.0, reply_timeout: float = 300.0):
        if mode not in ("raw", "pieces"):
            raise ValueError(f"mode must be 'raw' or 'pieces', got {mode!r}")
        self.cfg = cfg
        self.mode = MODE_PIECES if mode == "pieces" else MODE_RAW
        # generous: a cold receiver traces + compiles its batched table step
        # (per capacity) before the first reply can leave
        self.reply_timeout = float(reply_timeout)
        self._decoder = FrameDecoder()
        self._sessions: Dict[str, _ClientSession] = {}
        self.sock = self._connect(host, port, connect_timeout)

    @staticmethod
    def _connect(host, port, timeout):
        deadline = time.monotonic() + timeout
        while True:
            try:
                sock = socket.create_connection((host, port), timeout=30.0)
                sock.settimeout(None)  # reads go through select
                return sock
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.25)

    def open(self, sid: str, seed: int,
             mode: Optional[str] = None) -> None:
        """Open ``sid``; ``mode`` overrides the client default per session
        (mixed raw/pieces fleets share one socket, keeping frame order)."""
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} is already open")
        if mode is None:
            mode_int = self.mode
        elif mode in ("raw", "pieces"):
            mode_int = MODE_PIECES if mode == "pieces" else MODE_RAW
        else:
            raise ValueError(f"mode must be 'raw' or 'pieces', got {mode!r}")
        self._sessions[sid] = _ClientSession(sid, mode_int)
        self.sock.sendall(encode_open(sid, mode_int, seed))

    def settled(self, sid: str) -> bool:
        """True once the receiver closed ``sid`` (CLOSED arrived -- clean
        or evicted); further sends for it would be dropped server-side."""
        self._drain(block=False)
        sess = self._sessions.get(sid)
        return sess is not None and sess.result is not None

    def send(self, sid: str, window) -> None:
        """Ship one window; pieces mode compresses it locally first."""
        sess = self._sessions[sid]
        window = np.asarray(window, np.float32).reshape(-1)
        if not len(window):
            return
        if sess.mode == MODE_RAW:
            frame = encode_data_raw(sid, window)
            sess.t_seen += len(window)
            sess.payload_bytes += 4 + 4.0 * len(window)
        else:
            import jax.numpy as jnp

            from repro.core.compress import pieces_on_wire
            from repro.core.symed import symed_encode_chunk

            if sess.state is None:
                sess.t0 = float(window[0])
            sess.state, events = symed_encode_chunk(
                jnp.asarray(window), self.cfg, sess.state)
            endpoints, steps = pieces_on_wire(events, sess.t_seen)
            sess.t_seen += len(window)
            frame = encode_data_pieces(
                sid, sess.t0, sess.t_seen, endpoints, steps)
            sess.payload_bytes += 12 + PIECE_TUPLE_BYTES * len(endpoints)
        self.sock.sendall(frame)
        self._drain(block=False)

    def close(self, sid: str) -> dict:
        """Flush (pieces mode ships the sender's tail), await CLOSED.

        If the receiver already settled the session -- LRU eviction delivers
        an unsolicited CLOSED with the evicted flag -- the parked result is
        returned without sending a CLOSE for the dropped session id.
        """
        sess = self._sessions[sid]
        self._drain(block=False)
        if sess.result is not None:
            return sess.result
        tail_endpoint = None
        if sess.mode == MODE_PIECES and sess.state is not None:
            from repro.core.compress import compressor_finalize

            tail = compressor_finalize(sess.state)
            if bool(tail.emit):
                tail_endpoint = float(tail.endpoint)
        self.sock.sendall(encode_close(sid, sess.t_seen, tail_endpoint))
        sess.payload_bytes += 5 + (4 if tail_endpoint is not None else 0)
        while sess.result is None:
            self._drain(block=True)
        return sess.result

    def delta_concat(self, sid: str) -> Tuple[np.ndarray, np.ndarray]:
        """All DELTA frames plus the CLOSED closing frame, concatenated."""
        sess = self._sessions[sid]
        parts = list(sess.deltas)
        if sess.result is not None:
            parts.append((sess.result["labels"], sess.result["endpoints"]))
        if not parts:
            return np.zeros((0,), np.int32), np.zeros((0,), np.float32)
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]))

    @property
    def payload_bytes(self) -> float:
        return sum(s.payload_bytes for s in self._sessions.values())

    def shutdown(self) -> None:
        self.sock.close()

    def _drain(self, block: bool) -> None:
        """Read whatever the receiver sent; ``block`` waits for one read.

        The blocking caller (``close``) re-checks its own condition and
        loops, so one successful read per call is enough.
        """
        while True:
            r, _, _ = select.select(
                [self.sock], [], [], self.reply_timeout if block else 0.0)
            if not r:
                if block:
                    raise TimeoutError(
                        f"no frame from receiver within {self.reply_timeout}s")
                return
            data = self.sock.recv(1 << 16)
            if not data:
                raise ConnectionError("receiver closed the connection")
            for frame in self._decoder.feed(data):
                self._dispatch(frame)
            if block:
                return

    def _dispatch(self, frame: Frame) -> None:
        sess = self._sessions.get(frame.sid)
        if frame.type == ERROR:
            if sess is not None and sess.result is not None:
                return  # stale: the session settled (e.g. evicted) while
                        # our frame for it was in flight
            raise RuntimeError(
                f"receiver error for {frame.sid!r}: "
                f"{frame.payload.decode('utf-8', 'replace')}")
        if sess is None:
            return
        if frame.type == DELTA:
            sess.deltas.append(unpack_delta_frame(frame.payload))
        elif frame.type == CLOSED:
            sess.result = decode_closed(frame.payload)


# --------------------------------------------------------------------- server


class _WireSession:
    def __init__(self, sid: str, mode: int, conn):
        self.sid = sid
        self.mode = mode
        self.conn = conn


class TransportServer:
    """Socket loop in front of a ``StreamServer``: the edge node's front door.

    Single-threaded ``select`` loop: each tick reads every readable
    connection, decodes complete frames, then batches *all* staged DATA --
    across connections and sessions -- into at most one
    ``ingest_many`` and one ``ingest_pieces_many`` call (the donated batched
    table steps), routes the resulting DELTA frames back to the owning
    sockets, and finally processes CLOSEs (so a session's deltas always
    precede its CLOSED frame).  Slot-table autoscaling, LRU eviction and the
    digitize cadence are whatever the wrapped ``StreamServer`` was built
    with; an evicted session's connection receives CLOSED with the evicted
    flag set.
    """

    def __init__(self, server, host: str = "127.0.0.1", port: int = 0):
        self.server = server
        self.listener = socket.create_server((host, port))
        self.host, self.port = self.listener.getsockname()[:2]
        self._conns: Dict[socket.socket, FrameDecoder] = {}
        self._wire: Dict[str, _WireSession] = {}
        self.closed_sessions = 0
        self.frame_bytes = 0.0      # total socket bytes in (incl. framing)
        self.payload_bytes = {MODE_RAW: 0.0, MODE_PIECES: 0.0}
        self.raw_equiv_bytes = {MODE_RAW: 0.0, MODE_PIECES: 0.0}
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Record into the wrapped ``StreamServer``'s flight recorder, so one
        scrape covers the socket tier and the slot table together.

        Socket totals already tracked on ``self`` become scrape-time callback
        series (zero loop cost); per-frame/decode signals are live counters
        and histograms recorded in ``_tick``/``_process``.
        """
        from repro.obs import disabled

        self._obs = getattr(self.server, "obs", None) or disabled()
        self._obs_on = self._obs.enabled
        m = self._obs.metrics
        self._h_decode = m.histogram(
            "transport_decode_seconds",
            "per-recv frame decode latency", unit="ns")
        self._h_route = m.histogram(
            "transport_route_seconds",
            "per-batch frame handling: stage + ingest + reply", unit="ns")
        self._m_frames = {
            OPEN: m.counter("transport_frames_in_total", "frames received",
                            labels={"type": "open"}),
            DATA: m.counter("transport_frames_in_total", "frames received",
                            labels={"type": "data"}),
            CLOSE: m.counter("transport_frames_in_total", "frames received",
                             labels={"type": "close"}),
        }
        self._m_frames_other = m.counter(
            "transport_frames_in_total", "frames received",
            labels={"type": "other"})
        self._m_tx = m.counter("transport_tx_bytes_total",
                               "bytes written back to senders")
        self._m_proto_errors = m.counter(
            "transport_protocol_errors_total",
            "malformed frames / payloads rejected")
        self._m_drops = m.counter(
            "transport_conn_drops_total",
            "connections dropped (EOF, errors, protocol violations)")
        if not self._obs_on:
            return
        m.counter_fn("transport_rx_bytes_total",
                     "socket bytes received (incl. framing)",
                     lambda: float(self.frame_bytes))
        m.counter_fn("transport_payload_bytes_total", "payload bytes by mode",
                     lambda: float(self.payload_bytes[MODE_RAW]),
                     labels={"mode": "raw"})
        m.counter_fn("transport_payload_bytes_total", "payload bytes by mode",
                     lambda: float(self.payload_bytes[MODE_PIECES]),
                     labels={"mode": "pieces"})
        m.counter_fn("transport_sessions_closed_total",
                     "sessions closed over the wire",
                     lambda: float(self.closed_sessions))
        m.gauge_fn("transport_open_connections", "live sender sockets",
                   lambda: float(len(self._conns)))

    def serve(self, expect_sessions: Optional[int] = None,
              stop=None, poll: float = 0.05) -> None:
        """Run until ``expect_sessions`` sessions closed (or ``stop`` set)."""
        try:
            while True:
                if stop is not None and stop.is_set():
                    return
                if (expect_sessions is not None
                        and self.closed_sessions >= expect_sessions):
                    return
                self._tick(poll)
        finally:
            if expect_sessions is not None or (
                    stop is not None and stop.is_set()):
                self.shutdown()

    def shutdown(self) -> None:
        for conn in list(self._conns):
            conn.close()
        self._conns.clear()
        self.listener.close()

    # ------------------------------------------------------------ internals

    def _tick(self, poll: float) -> None:
        rlist, _, _ = select.select(
            [self.listener, *self._conns], [], [], poll)
        staged: List[Tuple[socket.socket, Frame]] = []
        for sock_ in rlist:
            if sock_ is self.listener:
                conn, _ = self.listener.accept()
                self._conns[conn] = FrameDecoder()
                continue
            try:
                data = sock_.recv(1 << 16)
            except OSError:
                data = b""
            if not data:
                self._drop_conn(sock_)
                continue
            self.frame_bytes += len(data)
            t_dec = time.perf_counter_ns() if self._obs_on else 0
            try:
                frames = self._conns[sock_].feed(data)
            except ValueError as e:
                self._m_proto_errors.inc()
                try:
                    sock_.sendall(encode_error("", f"protocol error: {e}"))
                except OSError:
                    pass
                self._drop_conn(sock_)
                continue
            if self._obs_on:
                self._h_decode.observe(time.perf_counter_ns() - t_dec)
                self._obs.tracer.add(
                    "transport.decode", t_dec,
                    {"bytes": len(data), "frames": len(frames)})
                frame_counters = self._m_frames
                for f in frames:
                    (frame_counters.get(f.type)
                     or self._m_frames_other).inc()
            staged.extend((sock_, f) for f in frames)
        if staged:
            self._process(staged)

    def _drop_conn(self, conn) -> None:
        """A vanished sender abandons its sessions: close them server-side."""
        conn.close()
        if self._conns.pop(conn, None) is not None:
            self._m_drops.inc()
        for sid in [s for s, w in self._wire.items() if w.conn is conn]:
            del self._wire[sid]
            if sid in self.server:
                self.server.close(sid)
                self.closed_sessions += 1

    def _reply(self, conn, data: bytes) -> None:
        try:
            conn.sendall(data)
            self._m_tx.inc(len(data))
        except OSError:
            self._drop_conn(conn)

    def _process(self, staged) -> None:
        t_route = time.perf_counter_ns() if self._obs_on else 0
        raw_batch: Dict[str, list] = {}
        pieces_batch: Dict[str, dict] = {}
        closes: List[str] = []
        for conn, frame in staged:
            try:
                self._handle_frame(conn, frame, raw_batch, pieces_batch,
                                   closes)
            except (struct.error, ValueError, IndexError) as e:
                # a well-framed body with garbage inside must not take the
                # serve loop (and every other tenant) down -- the offending
                # connection is dropped, its sessions closed server-side
                self._m_proto_errors.inc()
                self._reply(conn, encode_error(
                    frame.sid, f"malformed frame payload: {e}"))
                self._drop_conn(conn)
        self._flush(raw_batch, pieces_batch, closes)
        if self._obs_on:
            self._h_route.observe(time.perf_counter_ns() - t_route)
            self._obs.tracer.add("transport.route", t_route,
                                 {"frames": len(staged)})

    def _handle_frame(self, conn, frame: Frame, raw_batch, pieces_batch,
                      closes) -> None:
        import jax

        sid = frame.sid
        if frame.type == OPEN:
            mode, seed = decode_open(frame.payload)
            if sid in self._wire or sid in self.server:
                self._reply(conn, encode_error(sid, "already open"))
                return
            before = set(self.server.evicted)
            try:
                self.server.open(sid, key=jax.random.key(seed))
            except RuntimeError as e:  # table full, eviction disabled
                self._reply(conn, encode_error(sid, str(e)))
                return
            self._wire[sid] = _WireSession(sid, mode, conn)
            self._notify_evicted(before)
        elif frame.type == DATA:
            w = self._wire.get(sid)
            if w is None:
                self._reply(conn, encode_error(sid, "unknown session"))
                return
            if w.mode == MODE_RAW:
                window = decode_data_raw(frame.payload)
                raw_batch.setdefault(sid, []).append(window)
                self.payload_bytes[MODE_RAW] += len(frame.payload)
                self.raw_equiv_bytes[MODE_RAW] += 4.0 * len(window)
            else:
                t0, t_seen, endpoints, steps = decode_data_pieces(
                    frame.payload)
                p = pieces_batch.setdefault(sid, {
                    "endpoints": [], "steps": [], "t_seen": 0,
                    "t0": t0, "wire_bytes": 0.0,
                })
                p["endpoints"].append(endpoints)
                p["steps"].append(steps)
                prev = p["t_seen"]
                p["t_seen"] = max(p["t_seen"], t_seen)
                p["wire_bytes"] += len(frame.payload)
                self.payload_bytes[MODE_PIECES] += len(frame.payload)
                self.raw_equiv_bytes[MODE_PIECES] += 4.0 * max(
                    t_seen - max(prev, self._seen(sid)), 0)
        elif frame.type == CLOSE:
            w = self._wire.get(sid)
            if w is None:
                self._reply(conn, encode_error(sid, "unknown session"))
                return
            t_seen, tail = decode_close(frame.payload)
            self.payload_bytes[w.mode] += len(frame.payload)
            if w.mode == MODE_PIECES and tail is not None:
                p = pieces_batch.setdefault(sid, {
                    "endpoints": [], "steps": [], "t_seen": 0,
                    "t0": 0.0, "wire_bytes": 0.0,
                })
                p["endpoints"].append(np.asarray([tail], np.float32))
                p["steps"].append(np.asarray([t_seen], np.int32))
                p["t_seen"] = max(p["t_seen"], t_seen)
                p["wire_bytes"] += 4.0  # the tail's f32 endpoint
            closes.append(sid)
        else:
            self._reply(conn, encode_error(sid, "unexpected frame type"))

    def _flush(self, raw_batch, pieces_batch, closes) -> None:  # symlint: hot-path
        if raw_batch:
            arrivals = {sid: np.concatenate(ws) for sid, ws in
                        raw_batch.items() if sid in self.server}
            if arrivals:
                deltas = self.server.ingest_many(arrivals)
                self._route_deltas(deltas)
        if pieces_batch:
            arrivals = {}
            for sid, p in pieces_batch.items():
                if sid not in self.server:
                    continue
                arrivals[sid] = {
                    "endpoints": (np.concatenate(p["endpoints"])
                                  if p["endpoints"]
                                  else np.zeros((0,), np.float32)),
                    "steps": (np.concatenate(p["steps"]) if p["steps"]
                              else np.zeros((0,), np.int32)),
                    "t_seen": p["t_seen"],
                    "t0": p["t0"],
                    "wire_bytes": p["wire_bytes"],
                }
            if arrivals:
                deltas = self.server.ingest_pieces_many(arrivals)
                self._route_deltas(deltas)
        for sid in closes:
            w = self._wire.pop(sid, None)
            if w is None or sid not in self.server:
                continue
            res = self.server.close(sid)
            self.closed_sessions += 1
            d = res["delta"]
            self._reply(w.conn, encode_closed(
                sid, res["n_pieces"], res["t_seen"], False,
                d["labels"], d["endpoints"]))

    def _seen(self, sid: str) -> int:
        return (self.server.session_stats(sid)["t_seen"]
                if sid in self.server else 0)

    def _route_deltas(self, deltas: Dict[str, dict]) -> None:
        for sid, d in deltas.items():
            w = self._wire.get(sid)
            if w is not None and d["frames"]:
                self._reply(w.conn, encode_delta(
                    sid, d["labels"], d["endpoints"]))

    def _notify_evicted(self, before) -> None:
        for sid in set(self.server.evicted) - before:
            w = self._wire.pop(sid, None)
            if w is None:
                continue
            self.closed_sessions += 1
            res = self.server.evicted[sid]
            d = res["delta"]
            self._reply(w.conn, encode_closed(
                sid, res["n_pieces"], res["t_seen"], True,
                d["labels"], d["endpoints"]))

    def summary(self) -> Dict[str, float]:
        """Actual-socket traffic next to the StreamServer's logical totals."""
        raw_pay, pieces_pay = (self.payload_bytes[MODE_RAW],
                               self.payload_bytes[MODE_PIECES])
        raw_eq = self.raw_equiv_bytes[MODE_RAW] + self.raw_equiv_bytes[
            MODE_PIECES]
        return {
            "sessions_closed": float(self.closed_sessions),
            "frame_bytes": self.frame_bytes,
            "payload_bytes_raw": raw_pay,
            "payload_bytes_pieces": pieces_pay,
            "raw_equiv_bytes": raw_eq,
            "pieces_ratio": pieces_pay / max(
                self.raw_equiv_bytes[MODE_PIECES], 1.0),
        }


# ------------------------------------------------------------------- CLI


def _serve_main(args) -> int:
    from repro.core.symed import SymEDConfig
    from repro.launch.fleet import fleet_data_mesh
    from repro.launch.stream import StreamServer

    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    mesh = fleet_data_mesh() if args.devices > 1 else None
    server = StreamServer(
        cfg, max_sessions=args.max_slots, window_cap=args.window,
        digitize_every_k=args.digitize_every, evict_idle=args.evict,
        autoscale=args.autoscale, min_slots=args.min_slots,
        shrink_patience=args.shrink_patience, pretrace=args.pretrace,
        seed=args.seed, mesh=mesh,
    )
    transport = TransportServer(server, host=args.host, port=args.port)
    exporter = None
    if args.metrics_port is not None:
        from repro.obs.export import start_exporter
        exporter = start_exporter(server.obs, args.metrics_port)
        print(f"metrics exporter        : {exporter.url}/metrics",
              flush=True)
    print(f"listening on {transport.host}:{transport.port} "
          f"(devices={args.devices} slots={args.max_slots}"
          f"{' autoscale' if args.autoscale else ''})", flush=True)
    t0 = time.perf_counter()
    transport.serve(expect_sessions=args.expect_sessions)
    rep = server.report(time.perf_counter() - t0)
    summ = transport.summary()
    if args.trace_out:
        server.obs.tracer.write(args.trace_out)
        print(f"trace written           : {args.trace_out}")
    if exporter is not None:
        if args.metrics_linger:
            print(f"metrics exporter        : lingering "
                  f"{args.metrics_linger:.0f}s for scrapes", flush=True)
            time.sleep(args.metrics_linger)
        exporter.close()
    print(f"sessions                : {int(rep['opened'])} opened, "
          f"{int(rep['closed'])} closed, {int(rep['evicted'])} evicted")
    print(f"wire in                 : {int(rep['wire_in_bytes'])} payload "
          f"bytes for {int(rep['points_in'])} points "
          f"({int(rep['raw_bytes'])} raw-equivalent)")
    print(f"wire out                : {int(rep['bytes_out'])} bytes in "
          f"{int(rep['frames_out'])} delta frames")
    print("transport_summary "
          f"sessions={int(summ['sessions_closed'])} "
          f"wire_in_bytes={int(rep['wire_in_bytes'])} "
          f"raw_bytes={int(rep['raw_bytes'])} "
          f"wire_in_ratio={rep['wire_in_ratio']:.4f} "
          f"pieces_ratio={summ['pieces_ratio']:.4f} "
          f"wire_out_bytes={int(rep['bytes_out'])} "
          f"frame_bytes={int(summ['frame_bytes'])} "
          f"capacity={int(rep['capacity'])} "
          f"grows={int(rep['grows'])} shrinks={int(rep['shrinks'])} "
          f"evicted={int(rep['evicted'])}")
    return 0


def _send_main(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro.core.symed import SymEDConfig, symed_encode
    from repro.data.synthetic import make_fleet

    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    data = np.asarray(make_fleet(args.streams, args.length, seed=args.seed))
    client = SenderClient(args.host, args.port, cfg, mode=args.mode,
                          connect_timeout=args.connect_timeout)
    sids = [f"{args.session_prefix}-{i}" for i in range(args.streams)]
    for sid in sids:
        client.open(sid, session_seed(sid, args.seed))
    # interleaved sessions: round-robin one window per session per pass
    for c in range(0, args.length, args.window):
        for i, sid in enumerate(sids):
            client.send(sid, data[i, c: c + args.window])
    results = {sid: client.close(sid) for sid in sids}
    points = sum(r["t_seen"] for r in results.values())
    symbols = sum(r["n_pieces"] for r in results.values())
    print(f"sent {args.streams} sessions x {args.length} points "
          f"({args.mode} mode): {symbols} symbols back")
    print("sender_summary "
          f"mode={args.mode} sessions={args.streams} points={points} "
          f"payload_bytes={int(client.payload_bytes)} "
          f"raw_bytes={4 * points} "
          f"ratio={client.payload_bytes / max(4.0 * points, 1.0):.4f}")
    if args.verify:
        from repro.core.compress import compress_stream

        for i, sid in enumerate(sids):
            res = results[sid]
            labels, endpoints = client.delta_concat(sid)
            key = jax.random.key(session_seed(sid, args.seed))
            ts = jnp.asarray(data[i, : res["t_seen"]])
            ref = symed_encode(ts, cfg, key, reconstruct=False)
            n = int(ref["n_pieces"])
            np.testing.assert_array_equal(
                labels, np.asarray(ref["symbols_online"])[:n],
                err_msg=f"{sid}: delta labels")
            ev = compress_stream(ts, tol=cfg.tol, len_max=cfg.len_max,
                                 alpha=cfg.alpha)
            want_eps = list(np.asarray(ev["endpoint"])[np.asarray(ev["emit"])])
            if bool(ev["tail"].emit):
                want_eps.append(float(ev["tail"].endpoint))
            np.testing.assert_array_equal(
                endpoints, np.asarray(want_eps, np.float32),
                err_msg=f"{sid}: delta endpoints")
            assert res["n_pieces"] == n, (sid, res["n_pieces"], n)
        print(f"delta_equivalence=OK sessions={args.streams} "
              f"symbols={symbols}")
    client.shutdown()
    return 0


def _demo_main(args) -> int:
    """In-process loopback: server thread + one sender per mode."""
    import threading

    import jax

    from repro.core.symed import SymEDConfig
    from repro.launch.stream import StreamServer

    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    server = StreamServer(
        cfg, max_sessions=args.max_slots, window_cap=args.window,
        digitize_every_k=args.digitize_every, autoscale=args.autoscale,
        min_slots=args.min_slots, seed=args.seed)
    transport = TransportServer(server, port=0)
    n_sessions = 2 * args.streams
    thread = threading.Thread(
        target=transport.serve, kwargs={"expect_sessions": n_sessions},
        daemon=True)
    thread.start()
    print(f"loopback server on port {transport.port}")
    for mode in ("pieces", "raw"):
        send_args = argparse.Namespace(
            **{**vars(args), "mode": mode, "port": transport.port,
               "host": "127.0.0.1", "session_prefix": f"demo-{mode}",
               "verify": True})
        _send_main(send_args)
    thread.join(timeout=60)
    rep = server.report(1.0)
    summ = transport.summary()
    print(f"wire in  (pieces mode)  : {int(summ['payload_bytes_pieces'])} B "
          f"vs {int(summ['payload_bytes_raw'])} B raw mode "
          f"(pieces ratio {summ['pieces_ratio']:.3f})")
    print(f"wire out                : {int(rep['bytes_out'])} B symbol-delta "
          f"frames")
    return 0


def main():
    from repro.launch.cli import (
        add_devices_arg, add_metrics_args, add_slot_table_args,
        add_symed_args, validate_shared_args)

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    role = ap.add_mutually_exclusive_group()
    role.add_argument("--serve", action="store_true",
                      help="run the receiver socket server")
    role.add_argument("--send", action="store_true",
                      help="run a sender client")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="server port (0: OS-assigned, printed at startup)")
    ap.add_argument("--mode", default="pieces", choices=("raw", "pieces"),
                    help="sender mode: raw windows or locally-compressed "
                         "piece tuples")
    ap.add_argument("--streams", type=int, default=4,
                    help="sessions this sender interleaves")
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--session-prefix", default="s",
                    help="session id prefix (make unique per sender process)")
    ap.add_argument("--verify", action="store_true",
                    help="sender: check returned deltas bitwise against "
                         "symed_encode")
    ap.add_argument("--connect-timeout", type=float, default=120.0,
                    help="sender: retry the connect this long")
    ap.add_argument("--expect-sessions", type=int, default=None,
                    help="server: exit after this many sessions closed")
    add_slot_table_args(ap, max_slots=8)
    add_devices_arg(
        ap, help="server: forced host device count (>1 shards the "
                 "slot table)")
    add_symed_args(ap)
    add_metrics_args(ap)
    args = ap.parse_args()
    validate_shared_args(ap, args)
    if args.serve:
        return _serve_main(args)
    if args.send:
        return _send_main(args)
    return _demo_main(args)


if __name__ == "__main__":
    sys.exit(main())
