"""Sharded SymED fleet runtime: distributed senders -> edge receivers at scale.

This is the runtime the ``repro.core.symed`` docstring promises: a slab of
``(n_streams, T)`` sensor streams is sharded over one or more mesh axes with
``shard_map``; every device owns a sub-slab of sender+receiver pairs and runs
``symed_batch`` (or the streaming-receiver path) locally; fleet-level
telemetry (wire bytes, pieces, compression rate) is aggregated with on-mesh
``psum`` reductions so every shard returns the same replicated totals.

Ingestion modes:

  * **whole-stream** (``chunk_len=None``): one vmapped ``symed_encode`` per
    shard -- maximum throughput when the slab fits;
  * **streaming receiver** (``chunk_len=C``): the stream is processed in
    ``C``-point windows through the resumable ``ReceiverState`` of
    ``repro.core.symed.symed_receive_chunk``.  What crosses each window
    boundary is O(n_max) per stream, independent of T: the O(1) sender
    ``CompressorState``, the padded wire buffers (endpoints + arrival steps),
    and the resumable ``DigitizerState``.  The digitize cadence
    ``digitize_every_k = k`` runs the receiver's k-means over the newly
    arrived pieces every ``k`` windows, so symbols stream out *online* while
    points are still arriving (the paper's 42ms/symbol deployment shape);
    ``k=0``/``None`` defers digitization to end-of-stream.  For every window
    split and cadence the end-of-stream outputs are bitwise-identical to the
    whole-stream path (tested in ``tests/test_streaming_receiver.py``).

Mesh layouts:

  * **single-pod** (``axis="data"``): flat 1-D sharding, e.g. the (16, 16)
    dry-run pod's ``data`` axis;
  * **multi-pod** (``axis=("pod", "data")``): streams shard over the flattened
    ``pod x data`` device grid and telemetry reduces *hierarchically* -- a
    ``psum`` over ``data`` (ICI, within-pod) first, then a ``psum`` over
    ``pod`` (DCN, across pods) -- the reduction tree a real multi-pod
    deployment would use.  Totals are invariant to the device layout: 1
    device, ``(8,)``, and ``(2, 4)`` produce identical ``pieces`` /
    ``wire_bytes`` / ``compression_rate`` (per-stream PRNG keys are split
    before sharding; tested via CLI subprocesses in ``tests/test_fleet.py``).

CLI (CPU dry-run; forces N host devices before jax initializes, mirroring
``repro.launch.dryrun``):

    PYTHONPATH=src python -m repro.launch.fleet --streams 256 --length 1024 \
        --chunk 128 --digitize-every 2 --devices 8 --pods 2
"""
from __future__ import annotations

if __name__ == "__main__":  # pragma: no cover -- CLI path only
    # Must precede the jax import below: jax locks the device count on
    # first init, and argparse can only run after the (jax-importing)
    # library half of this module loads.  Shared pre-scan with the
    # stream/transport/workload CLIs; the fleet dry-run defaults to 8.
    from repro.launch.cli import prescan_host_devices

    prescan_host_devices(default="8")

import argparse
import functools
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.receiver import delta_frame_bytes
from repro.core.symed import (
    SymEDConfig, symed_encode, symed_receive_chunk, symed_receive_finish,
)
from repro.launch.mesh import make_pod_data_mesh
from repro.utils.jax_compat import make_mesh, shard_map

__all__ = [
    "fleet_data_mesh", "resolve_fleet_mesh", "describe_ingestion",
    "validate_cli_args", "run_fleet", "fleet_report", "main",
]

AxisSpec = Union[str, Sequence[str]]


def fleet_data_mesh(n_devices: Optional[int] = None):
    """1-D ``(data,)`` mesh over the first ``n_devices`` (default: all)."""
    n = n_devices or jax.device_count()
    return make_mesh((n,), ("data",), devices=jax.devices()[:n])


def resolve_fleet_mesh(n_pods: int, n_dev: int):
    """CLI helper: ``(mesh, axis, layout string)`` for a pods-aware run.

    Shared by ``repro.launch.fleet`` and ``examples/edge_fleet.py`` so the
    two CLIs cannot drift apart in how they map ``--pods`` to a mesh.
    """
    if n_dev % n_pods:
        raise ValueError(f"{n_dev} devices must divide over {n_pods} pods")
    if n_pods > 1:
        mesh = make_pod_data_mesh(n_pods, n_dev // n_pods)
        return mesh, ("pod", "data"), f"pod x data = {n_pods} x {n_dev // n_pods}"
    return fleet_data_mesh(n_dev), "data", f"data = {n_dev}"


def describe_ingestion(chunk: Optional[int], digitize_every: int) -> str:
    """Human-readable ingestion mode for the CLI reports."""
    if not chunk:
        return "whole-stream"
    cadence = (f", digitize every {digitize_every}" if digitize_every
               else ", digitize at finish")
    return f"streaming({chunk}{cadence})"


def validate_cli_args(ap: argparse.ArgumentParser, args) -> None:
    """Early validation of the streaming/fleet flags both CLIs share.

    Called before any jax work so bad invocations fail fast (exit 2 via
    ``ap.error``) instead of surfacing as tracebacks from ``run_fleet``.
    """
    from repro.launch.cli import validate_shared_args

    validate_shared_args(ap, args)
    if args.chunk is not None and args.chunk < 0:
        ap.error(f"--chunk must be >= 0 (0 = whole-stream), got {args.chunk}")
    if args.chunk and args.chunk > args.length:
        ap.error(f"--chunk {args.chunk} exceeds --length {args.length}: "
                 "the ingestion window cannot outgrow the stream")
    if args.digitize_every and not args.chunk:
        ap.error("--digitize-every requires --chunk (streaming mode)")
    if args.pods < 1:
        ap.error(f"--pods must be >= 1, got {args.pods}")


def _encode_slab(slab, keys, cfg: SymEDConfig, chunk_len, digitize_every_k,
                 reconstruct):  # symlint: hot-path
    """Per-shard body: vmapped SymED over a local (b, T) sub-slab.

    Returns ``(out, wire_out)``: ``wire_out`` (b,) is the outbound
    symbol-delta traffic each stream's receiver would put on the wire --
    one frame per digitize pass plus the closing frame at end-of-stream
    (``repro.launch.stream``'s emitter; whole-stream ingestion degenerates
    to a single closing frame carrying every symbol).
    """
    if chunk_len is None:
        out = jax.vmap(lambda t, k: symed_encode(t, cfg, k, reconstruct))(
            slab, keys)
        return out, delta_frame_bytes(out["n_pieces"])

    # streaming receiver: only the current window + the O(n_max) ReceiverState
    # are live; the loop unrolls over the static window count.  The digitize
    # cadence is resolved *here*, per window, rather than letting the traced
    # ``chunks % k`` cond do it: under vmap a cond lowers to select, which
    # would run the O(n_max) digitizer scan on every window and merely discard
    # the off-cadence results -- deciding host-side keeps the k-means cost at
    # the intended T/(C*k) per stream.  ``(i + 1) % k`` mirrors the in-state
    # ``chunks`` counter exactly, so outputs are unchanged.
    t_len = slab.shape[-1]
    dk = digitize_every_k or 0
    state = None
    wire_out = jnp.zeros((slab.shape[0],), jnp.float32)
    for i, c in enumerate(range(0, t_len, chunk_len)):
        window = slab[:, c: c + chunk_len]
        dk_i = 1 if dk and (i + 1) % dk == 0 else 0
        if state is None:
            state, info = jax.vmap(
                lambda w, k: symed_receive_chunk(w, cfg, None, k,
                                                 digitize_every_k=dk_i)
            )(window, keys)
        else:
            state, info = jax.vmap(
                lambda w, s: symed_receive_chunk(w, cfg, s,
                                                 digitize_every_k=dk_i)
            )(window, state)
        wire_out = wire_out + info["symbol_delta"]["frame_bytes"]
    n_dig_before_finish = state.dig.n
    if reconstruct:
        out = jax.vmap(
            lambda s, t: symed_receive_finish(s, cfg, t, reconstruct=True)
        )(state, slab)
    else:
        out = jax.vmap(
            lambda s: symed_receive_finish(s, cfg, None, reconstruct=False)
        )(state)
    # the closing frame: whatever the final flush digitized
    wire_out = wire_out + delta_frame_bytes(out["n_pieces"] - n_dig_before_finish)
    return out, wire_out


@functools.lru_cache(maxsize=32)
def _mapped_runner(mesh, axes: Tuple[str, ...], cfg: SymEDConfig, chunk_len,  # symlint: entry(drive=fleet, budget=0)
                   digitize_every_k, reconstruct):
    """Jitted shard_map program, cached so repeat fleet runs (benchmarks,
    chunk-by-chunk services) pay trace+compile once per configuration."""

    def hier_psum(v):
        # hierarchical telemetry tree: reduce the innermost axis first
        # (within-pod ICI), then each enclosing axis (cross-pod DCN)
        for ax in reversed(axes):
            v = jax.lax.psum(v, ax)
        return v

    def shard_fn(slab, slab_keys):
        out, wire_out = _encode_slab(slab, slab_keys, cfg, chunk_len,
                                     digitize_every_k, reconstruct)
        n_pts = jnp.float32(slab.shape[0] * slab.shape[1])
        tele = {
            "streams": hier_psum(jnp.float32(slab.shape[0])),
            "points": hier_psum(n_pts),
            "pieces": hier_psum(jnp.sum(out["n_pieces"].astype(jnp.float32))),
            "wire_bytes": hier_psum(jnp.sum(out["wire_bytes"])),
            "raw_bytes": hier_psum(n_pts * 4.0),
            "wire_out_bytes": hier_psum(jnp.sum(wire_out)),
        }
        return out, tele

    # P accepts a tuple of axis names per dim; a 1-tuple == the bare name
    return jax.jit(shard_map(
        shard_fn, mesh,
        in_specs=(P(axes, None), P(axes)),
        out_specs=(P(axes), P()),
    ))


def run_fleet(
    fleet: jax.Array,
    cfg: SymEDConfig,
    key: jax.Array,
    mesh=None,
    *,
    chunk_len: Optional[int] = None,
    digitize_every_k: Optional[int] = None,
    reconstruct: bool = False,
    axis: AxisSpec = "data",
    obs=None,
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Run the SymED pipeline over ``fleet`` (n_streams, T), sharded on ``axis``.

    ``axis`` may be a single mesh axis (``"data"``) or a sequence
    (``("pod", "data")``) -- streams then shard over the flattened device grid
    of those axes and telemetry reduces hierarchically (innermost axis first).

    Each stream gets its own PRNG key (split from ``key``), so results are
    independent of the device layout: a (2, 4) pod x data mesh, an (8,) data
    mesh, and a single device produce identical outputs (tested).

    ``chunk_len=C`` switches to the streaming receiver (windows of ``C``
    points, O(n_max) carry); ``digitize_every_k=k`` additionally digitizes
    every ``k`` windows so symbols stream out online (requires ``chunk_len``).

    Returns ``(out, telemetry)``: ``out`` are the per-stream ``symed_encode``
    outputs (sharded like the input), ``telemetry`` the replicated fleet-wide
    totals reduced on-mesh: ``streams``, ``points``, ``pieces``,
    ``wire_bytes``, ``raw_bytes``, and ``wire_out_bytes`` -- the outbound
    symbol-delta traffic (one frame per digitize pass plus the closing
    frame, ``repro.launch.stream``'s wire format).

    ``obs``: optional ``repro.obs.Observability`` bundle; when given, the
    dispatch is recorded as a ``fleet.dispatch`` span + histogram sample
    (dispatch only -- the runner returns asynchronously; block on the
    telemetry before timing end-to-end).
    """
    mesh = mesh if mesh is not None else fleet_data_mesh()
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    if not axes:
        raise ValueError("axis must name at least one mesh axis")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in axes:
        if a not in sizes:
            raise ValueError(
                f"unknown mesh axis {a!r}; mesh has axes {tuple(sizes)}"
            )
    n_shards = 1
    for a in axes:
        n_shards *= sizes[a]
    fleet = jnp.asarray(fleet, jnp.float32)
    n_streams = fleet.shape[0]
    if n_streams % n_shards:
        raise ValueError(
            f"n_streams={n_streams} must divide over {n_shards} "
            f"{'x'.join(axes)} shards"
        )
    if chunk_len is not None and chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    if digitize_every_k is not None and digitize_every_k < 0:
        raise ValueError(
            f"digitize_every_k must be >= 0, got {digitize_every_k}")
    if digitize_every_k and chunk_len is None:
        raise ValueError("digitize_every_k requires chunk_len (streaming mode)")
    keys = jax.random.split(key, n_streams)

    fleet = jax.device_put(fleet, NamedSharding(mesh, P(axes, None)))
    keys = jax.device_put(keys, NamedSharding(mesh, P(axes)))

    runner = _mapped_runner(mesh, axes, cfg, chunk_len, digitize_every_k,
                            reconstruct)
    obs_on = obs is not None and obs.enabled
    t_disp = time.perf_counter_ns() if obs_on else 0
    with mesh:
        out, tele = runner(fleet, keys)
    if obs_on:
        obs.metrics.histogram(
            "fleet_dispatch_seconds", "run_fleet dispatch latency "
            "(trace/compile on first call at a shape)", unit="ns"
        ).observe(time.perf_counter_ns() - t_disp)
        obs.tracer.add("fleet.dispatch", t_disp,
                       {"streams": n_streams, "shards": n_shards})
    return out, tele


def fleet_report(tele: Dict[str, jax.Array], wall_seconds: float,
                 obs=None) -> Dict[str, object]:
    """Host-side summary: telemetry totals + wall-clock rates.

    ``obs``: optional ``repro.obs.Observability`` bundle.  When given, the
    fleet totals are published as gauges on its registry (so a scrape of a
    long-lived driver sees the wire/throughput story) and its JSON snapshot
    is merged under the report's ``"obs"`` key.

    Robust to empty fleets (zero streams / zero points): every ratio is
    clamped, so the report never divides by zero.  ``ms_per_symbol`` is the
    paper's per-symbol conversion latency metric (42ms/symbol in the paper's
    single-CPU setup; amortized here over the whole fleet run).

    Wire telemetry covers both directions, with the same keys
    ``StreamServer.report`` uses: ``wire_in_bytes``/``wire_in_ratio`` is the
    sender->receiver traffic against the raw stream (the paper's headline
    9.5% compression of network traffic; here the 4 B/piece endpoints +
    hello, i.e. ``wire_bytes``), ``wire_out_bytes``/``wire_out_ratio`` the
    receiver's outbound symbol-delta frames.  Both ratios share the
    ``raw_bytes`` denominator: outbound frames against the *compressed*
    inbound bytes read > 1.0 on short cadence windows (frame headers swamp
    the already-reduced denominator) even when the service is cutting
    traffic, so the out-ratio, like the in-ratio, answers "what fraction of
    the original signal's bytes crossed this hop".
    """
    t = {k: float(v) for k, v in tele.items()}
    dt = max(wall_seconds, 1e-9)
    rep: Dict[str, object] = {
        **t,
        "wall_seconds": wall_seconds,
        "points_per_s": t["points"] / dt,
        "pieces_per_s": t["pieces"] / dt,
        "streams_per_s": t["streams"] / dt,
        "ms_per_symbol": 1e3 * dt / max(t["pieces"], 1.0),
        "compression_rate": t["wire_bytes"] / max(t["raw_bytes"], 1.0),
        "mean_pieces_per_stream": t["pieces"] / max(t["streams"], 1.0),
        "wire_in_bytes": t["wire_bytes"],
        "wire_in_ratio": t["wire_bytes"] / max(t["raw_bytes"], 1.0),
        # wire-out telemetry is absent from pre-delta callers' dicts
        "wire_out_bytes": t.get("wire_out_bytes", 0.0),
        "wire_out_ratio": t.get("wire_out_bytes", 0.0) / max(t["raw_bytes"], 1.0),
    }
    if obs is not None and obs.enabled:
        m = obs.metrics
        for key in ("streams", "points", "pieces", "wire_bytes", "raw_bytes",
                    "wire_out_bytes"):
            if key in t:
                m.gauge(f"fleet_{key}", "fleet telemetry total").set(t[key])
        rep["obs"] = obs.snapshot()
    return rep


def main():
    from repro.launch.cli import (
        add_devices_arg, add_metrics_args, add_symed_args)

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--streams", type=int, default=256)
    ap.add_argument("--length", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=None,
                    help="streaming-receiver ingestion window "
                         "(default / 0: whole stream)")
    ap.add_argument("--digitize-every", type=int, default=0,
                    help="digitize cadence k: run the receiver's clustering "
                         "every k windows so symbols stream out online "
                         "(0: once at end-of-stream; requires --chunk)")
    ap.add_argument("--pods", type=int, default=1,
                    help="shard over a (pod, data) mesh with this many pods "
                         "(hierarchical telemetry reduction)")
    ap.add_argument("--reconstruct", action="store_true",
                    help="also reconstruct + score DTW error (slower)")
    add_devices_arg(ap, default=8,
                    help="forced host device count for the CPU dry-run")
    add_symed_args(ap)
    add_metrics_args(ap)
    args = ap.parse_args()

    validate_cli_args(ap, args)
    if args.devices % args.pods:
        ap.error(f"--devices {args.devices} must divide over "
                 f"--pods {args.pods}")

    from repro.data.synthetic import make_fleet

    n_dev = jax.device_count()
    mesh, mesh_axes, layout = resolve_fleet_mesh(args.pods, n_dev)
    streams = max(args.streams - args.streams % n_dev, n_dev)
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    fleet = make_fleet(streams, args.length, seed=args.seed)

    from repro.obs import Observability

    obs = Observability()
    exporter = None
    if args.metrics_port is not None:
        from repro.obs.export import start_exporter
        exporter = start_exporter(obs, args.metrics_port)
        print(f"metrics exporter        : {exporter.url}/metrics")
    t0 = time.perf_counter()
    out, tele = run_fleet(
        fleet, cfg, jax.random.key(args.seed), mesh,
        chunk_len=args.chunk or None,
        digitize_every_k=args.digitize_every or None,
        reconstruct=args.reconstruct, axis=mesh_axes, obs=obs,
    )
    jax.block_until_ready(tele["pieces"])
    rep = fleet_report(tele, time.perf_counter() - t0, obs=obs)

    mode = describe_ingestion(args.chunk, args.digitize_every)
    print(f"devices / data shards   : {n_dev}")
    print(f"mesh layout             : {layout}")
    print(f"ingestion               : {mode}")
    print(f"streams                 : {streams} x {args.length} points")
    print(f"wall time               : {rep['wall_seconds']:.2f}s")
    print(f"throughput              : {rep['points_per_s'] / 1e6:.2f} Mpoints/s, "
          f"{rep['pieces_per_s']:.0f} pieces/s")
    print(f"symbol latency          : {rep['ms_per_symbol']:.3f} ms/symbol "
          f"(paper: 42ms single-CPU)")
    print(f"fleet pieces            : {int(rep['pieces'])} "
          f"({rep['mean_pieces_per_stream']:.1f}/stream)")
    print(f"fleet raw bytes         : {int(rep['raw_bytes']):,}")
    print(f"fleet wire-in bytes     : {int(rep['wire_in_bytes']):,} "
          f"(ratio {rep['wire_in_ratio']:.4f})")
    print(f"fleet wire-out bytes    : {int(rep['wire_out_bytes']):,} "
          f"(symbol-delta frames)")
    print(f"compression rate        : {rep['compression_rate']:.6f} "
          f"(paper avg 0.095)")
    if args.reconstruct:
        print(f"mean DTW err (pieces)   : {np.asarray(out['re_pieces']).mean():.3f}")
        print(f"mean DTW err (symbols)  : {np.asarray(out['re_symbols']).mean():.3f}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"trace written           : {args.trace_out} "
              f"({obs.tracer.recorded} events, load at ui.perfetto.dev)")
    if exporter is not None:
        if args.metrics_linger:
            print(f"metrics exporter        : lingering "
                  f"{args.metrics_linger:.0f}s for scrapes", flush=True)
            time.sleep(args.metrics_linger)
        exporter.close()


if __name__ == "__main__":
    main()
