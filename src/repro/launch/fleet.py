"""Sharded SymED fleet runtime: distributed senders -> edge receivers at scale.

This is the runtime the ``repro.core.symed`` docstring promises: a slab of
``(n_streams, T)`` sensor streams is sharded over the mesh ``data`` axis with
``shard_map``; every device owns a sub-slab of sender+receiver pairs and runs
``symed_batch`` (or the chunked online path) locally; fleet-level telemetry
(wire bytes, pieces, compression rate) is aggregated with on-mesh ``psum``
reductions so every shard returns the same replicated totals.

Two ingestion modes:

  * **whole-stream** (``chunk_len=None``): one vmapped ``symed_encode`` per
    shard -- maximum throughput when the slab fits;
  * **chunked / streaming** (``chunk_len=C``): the stream is processed in
    ``C``-point windows via ``symed_encode_chunk``, carrying the O(1)
    ``CompressorState`` across windows, then flushed + digitized once at the
    end.  This is the *online* deployment shape of the paper (points arrive
    over time; the sender never holds the stream) and is step-for-step
    identical to the whole-stream path (tested bitwise in
    ``tests/test_fleet.py``).

CLI (CPU dry-run; forces N host devices before jax initializes, mirroring
``repro.launch.dryrun``):

    PYTHONPATH=src python -m repro.launch.fleet --streams 256 --length 1024 \
        --chunk 128 --devices 8
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # pragma: no cover -- CLI path only
    # Must precede the jax import below: jax locks the device count on first
    # init.  --devices is pre-scanned from argv because argparse can only run
    # after the (jax-importing) library half of this module loads.
    _n = "8"
    for _i, _a in enumerate(sys.argv):
        if _a == "--devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
        elif _a.startswith("--devices="):
            _n = _a.split("=", 1)[1]
    if int(_n) > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", "")
        )

import argparse
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.symed import (
    SymEDConfig, symed_encode, symed_encode_chunk, symed_finish,
)
from repro.utils.jax_compat import make_mesh, shard_map

__all__ = ["fleet_data_mesh", "run_fleet", "fleet_report", "main"]


def fleet_data_mesh(n_devices: Optional[int] = None):
    """1-D ``(data,)`` mesh over the first ``n_devices`` (default: all)."""
    n = n_devices or jax.device_count()
    return make_mesh((n,), ("data",), devices=jax.devices()[:n])


def _encode_slab(slab, keys, cfg: SymEDConfig, chunk_len, reconstruct):
    """Per-shard body: vmapped SymED over a local (b, T) sub-slab."""
    if chunk_len is None:
        out = jax.vmap(lambda t, k: symed_encode(t, cfg, k, reconstruct))(slab, keys)
    else:
        t_len = slab.shape[-1]
        state, parts = None, []
        for c in range(0, t_len, chunk_len):
            # streaming ingestion: only the current window + O(1) carry are
            # live sender-side; the loop unrolls over the static window count
            state, ev = symed_encode_chunk(slab[:, c: c + chunk_len], cfg, state)
            parts.append(ev)
        events = {k: jnp.concatenate([p[k] for p in parts], axis=-1)
                  for k in parts[0]}
        ts_for_finish = slab if reconstruct else slab[:, :1]
        out = jax.vmap(
            lambda ev, st, k, t: symed_finish(ev, st, cfg, k, t, reconstruct)
        )(events, state, keys, ts_for_finish)
    return out


@functools.lru_cache(maxsize=32)
def _mapped_runner(mesh, axis: str, cfg: SymEDConfig, chunk_len, reconstruct):
    """Jitted shard_map program, cached so repeat fleet runs (benchmarks,
    chunk-by-chunk services) pay trace+compile once per configuration."""

    def shard_fn(slab, slab_keys):
        out = _encode_slab(slab, slab_keys, cfg, chunk_len, reconstruct)
        n_pts = jnp.float32(slab.shape[0] * slab.shape[1])
        psum = lambda v: jax.lax.psum(v, axis)
        tele = {
            "streams": psum(jnp.float32(slab.shape[0])),
            "points": psum(n_pts),
            "pieces": psum(jnp.sum(out["n_pieces"].astype(jnp.float32))),
            "wire_bytes": psum(jnp.sum(out["wire_bytes"])),
            "raw_bytes": psum(n_pts * 4.0),
        }
        return out, tele

    return jax.jit(shard_map(
        shard_fn, mesh,
        in_specs=(P(axis, None), P(axis)),
        out_specs=(P(axis), P()),
    ))


def run_fleet(
    fleet: jax.Array,
    cfg: SymEDConfig,
    key: jax.Array,
    mesh=None,
    *,
    chunk_len: Optional[int] = None,
    reconstruct: bool = False,
    axis: str = "data",
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Run the SymED pipeline over ``fleet`` (n_streams, T), sharded on ``axis``.

    Each stream gets its own PRNG key (split from ``key``), so results are
    independent of the device layout: a (2,2) mesh and a single device
    produce identical outputs (tested).

    Returns ``(out, telemetry)``: ``out`` are the per-stream ``symed_encode``
    outputs (sharded like the input), ``telemetry`` the replicated fleet-wide
    totals reduced on-mesh (``psum`` over ``axis``): ``streams``, ``points``,
    ``pieces``, ``wire_bytes``, ``raw_bytes``.
    """
    mesh = mesh if mesh is not None else fleet_data_mesh()
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    fleet = jnp.asarray(fleet, jnp.float32)
    n_streams = fleet.shape[0]
    if n_streams % n_shards:
        raise ValueError(
            f"n_streams={n_streams} must divide over {n_shards} '{axis}' shards"
        )
    if chunk_len is not None and chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    keys = jax.random.split(key, n_streams)

    fleet = jax.device_put(fleet, NamedSharding(mesh, P(axis, None)))
    keys = jax.device_put(keys, NamedSharding(mesh, P(axis)))

    runner = _mapped_runner(mesh, axis, cfg, chunk_len, reconstruct)
    with mesh:
        out, tele = runner(fleet, keys)
    return out, tele


def fleet_report(tele: Dict[str, jax.Array], wall_seconds: float) -> Dict[str, float]:
    """Host-side summary: telemetry totals + wall-clock rates."""
    t = {k: float(v) for k, v in tele.items()}
    dt = max(wall_seconds, 1e-9)
    return {
        **t,
        "wall_seconds": wall_seconds,
        "points_per_s": t["points"] / dt,
        "pieces_per_s": t["pieces"] / dt,
        "streams_per_s": t["streams"] / dt,
        "compression_rate": t["wire_bytes"] / max(t["raw_bytes"], 1.0),
        "mean_pieces_per_stream": t["pieces"] / max(t["streams"], 1.0),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--streams", type=int, default=256)
    ap.add_argument("--length", type=int, default=1024)
    ap.add_argument("--chunk", type=int, default=None,
                    help="chunked/online ingestion window "
                         "(default / 0: whole stream)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for the CPU dry-run")
    ap.add_argument("--tol", type=float, default=0.5)
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--reconstruct", action="store_true",
                    help="also reconstruct + score DTW error (slower)")
    args = ap.parse_args()

    from repro.data.synthetic import make_fleet

    n_dev = jax.device_count()
    mesh = fleet_data_mesh(n_dev)
    streams = max(args.streams - args.streams % n_dev, n_dev)
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    fleet = make_fleet(streams, args.length, seed=0)

    t0 = time.time()
    out, tele = run_fleet(
        fleet, cfg, jax.random.key(0), mesh,
        chunk_len=args.chunk or None, reconstruct=args.reconstruct,
    )
    jax.block_until_ready(tele["pieces"])
    rep = fleet_report(tele, time.time() - t0)

    mode = f"chunked({args.chunk})" if args.chunk else "whole-stream"
    print(f"devices / data shards   : {n_dev}")
    print(f"ingestion               : {mode}")
    print(f"streams                 : {streams} x {args.length} points")
    print(f"wall time               : {rep['wall_seconds']:.2f}s")
    print(f"throughput              : {rep['points_per_s'] / 1e6:.2f} Mpoints/s, "
          f"{rep['pieces_per_s']:.0f} pieces/s")
    print(f"fleet pieces            : {int(rep['pieces'])} "
          f"({rep['mean_pieces_per_stream']:.1f}/stream)")
    print(f"fleet raw bytes         : {int(rep['raw_bytes']):,}")
    print(f"fleet wire bytes        : {int(rep['wire_bytes']):,}")
    print(f"compression rate        : {rep['compression_rate']:.4f} "
          f"(paper avg 0.095)")
    if args.reconstruct:
        print(f"mean DTW err (pieces)   : {np.asarray(out['re_pieces']).mean():.3f}")
        print(f"mean DTW err (symbols)  : {np.asarray(out['re_symbols']).mean():.3f}")


if __name__ == "__main__":
    main()
