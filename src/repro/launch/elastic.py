"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

Policy: keep the ``model`` axis intact (tensor-parallel groups must be whole
-- losing one chip kills its TP group), shrink the ``data``/``pod`` axes to
the largest full multiple that survives, then restore the latest checkpoint
with the new mesh's shardings (``repro.ckpt`` stores leaves unsharded, so
restore *is* the reshard).

This is the single-process emulation of the production flow:
  watchdog flags dead pod -> controller drops its hosts -> remaining hosts
  re-init jax.distributed with the shrunken topology -> ``elastic_mesh`` ->
  ``CheckpointManager.restore_latest(..., shardings=new)`` -> resume.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.launch.mesh import make_test_mesh

__all__ = ["elastic_mesh", "resume_on_mesh"]


def elastic_mesh(model_size: int, *, devices: Optional[Sequence] = None):
    """Largest (data, model) mesh fitting the surviving devices."""
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < model_size:
        raise RuntimeError(
            f"{len(devices)} devices cannot host a model axis of {model_size}")
    data = len(devices) // model_size
    n = data * model_size
    from repro.utils.jax_compat import make_mesh

    return make_mesh((data, model_size), ("data", "model"), devices=devices[:n])


def resume_on_mesh(ckpt_dir, abstract_state, mesh):
    """Restore the latest checkpoint resharded onto ``mesh``."""
    from repro.ckpt import CheckpointManager
    from repro.launch.specs import state_shardings

    mgr = CheckpointManager(ckpt_dir)
    shardings = state_shardings(abstract_state, mesh)
    state, manifest = mgr.restore_latest(abstract_state, shardings=shardings)
    if state is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    return state, manifest
