"""Package."""
