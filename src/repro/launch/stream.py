"""Resident SymED session service: the paper's deployment shape as a driver.

The paper's receiver is a *long-lived* process: compressed points arrive over
the network, symbols leave in real time (42 ms/symbol in the paper's
single-CPU setup).  ``repro.launch.fleet`` replays pre-materialized slabs;
this module keeps the state *resident* instead.  A ``StreamServer`` owns a
slot table of ``max_sessions`` batched ``ReceiverState``s (one slot per live
stream) and drives every arrival through **one donated-jit batched step**
(``jax.vmap`` of ``symed_receive_masked_chunk``): ragged arrivals are padded
to the ``window_cap`` with per-slot valid counts, fresh and resumed sessions
share the same program (seeding is a runtime branch), and idle slots ride
along as masked no-ops.  Donation means the table's device buffers are
updated in place call after call -- the service's steady-state allocates
nothing.

Wire out: every digitize pass emits a **symbol-delta frame**
``(new_labels, new_piece_endpoints, n_new)`` -- only what changed since the
previous call (ABBA-VSM-style downstream consumers ingest the symbol stream
incrementally).  The frames are self-concatenating: joining every delta of a
session plus its closing frame reproduces ``symed_finish``'s
``symbols_online`` / wire endpoints **bitwise** (property battery in
``tests/test_stream_service.py``).

An online DTW monitor (``dtw_every=m``) scores each session's
piece-reconstruction against the raw points seen so far every ``m`` windows
(``reconstruct_from_pieces`` + ``kernels.ops.dtw``), so a drifting sender is
visible while the stream is still live.

Slot lifecycle: ``open`` allocates a free slot (or, with ``evict_idle``,
closes the least-recently-active session to make room -- its final output is
parked in ``server.evicted``); ``close`` flushes the tail, emits the closing
delta frame, and frees the slot for reuse.

CLI (trace-driven; arrivals come from a ``repro.workload`` trace --
``--workload`` names a scenario or a recorded ``workload_trace/v1`` jsonl,
and the legacy ``--arrival-pattern`` values are deprecated shims that
synthesize the equivalent trace.  ``--devices N`` forces N host CPU devices
and shards the slot table over a ``data`` mesh axis):

    PYTHONPATH=src python -m repro.launch.stream --sessions 6 --max-slots 4 \
        --length 384 --window 48 --workload bursty --evict --verify
"""
from __future__ import annotations

if __name__ == "__main__":  # pragma: no cover -- CLI path only
    # Must precede the jax import below (jax locks the device count on
    # first init); shared pre-scan with the fleet/transport/workload CLIs.
    from repro.launch.cli import prescan_host_devices

    prescan_host_devices()

import argparse
import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.receiver import (
    DELTA_FRAME_HEADER_BYTES, DELTA_SYMBOL_BYTES, PIECE_TUPLE_BYTES,
    pieces_from_wire,
)
from repro.core.reconstruct import reconstruct_from_pieces
from repro.core.symed import (
    SymEDConfig, receiver_init, symbols_to_string, symed_receive_finish,
    symed_receive_masked_chunk_table, symed_receive_masked_pieces_table,
)
from repro.kernels import ops
from repro.obs import Observability, as_obs
from repro.utils.jax_compat import trace_annotation

__all__ = ["StreamServer", "main"]

# Shared inert context for the non-annotated dispatch path: nullcontext is
# stateless and reentrant, so one instance serves every round.
_NULL_ANN_CTX = contextlib.nullcontext()


def _null_annotation(name: str):
    return _NULL_ANN_CTX


@functools.partial(
    jax.jit, static_argnames=("cfg", "digitize_every_k", "use_kernel"),
    donate_argnums=(0,),
)
def _table_step(table, windows, n_valid, *, cfg, digitize_every_k,  # symlint: entry(drive=stream, budget=0, shapes=table-step)
                use_kernel=False):
    """One batched service step: every slot ingests its padded window.

    The table-level receive fuses the digitize pass across slots (one
    cursor loop sized by the widest span of new pieces, Pallas Lloyd
    half-steps when ``use_kernel``); the sender half vmaps per slot.  All
    loop-varying quantities (windows, valid counts, the in-state cadence
    clock) are runtime operands -- only capacity changes retrace.
    """
    return symed_receive_masked_chunk_table(
        windows, n_valid, cfg, table,
        digitize_every_k=digitize_every_k, use_kernel=use_kernel,
    )


@functools.partial(
    jax.jit, static_argnames=("cfg", "digitize_every_k", "use_kernel"),
    donate_argnums=(0,),
)
def _table_step_pieces(table, endpoints, steps, n_valid, hello, t_seen, *,  # symlint: entry(drive=stream, budget=0, shapes=table-step-pieces)
                       cfg, digitize_every_k, use_kernel=False):
    """Compressed-in service step: every slot scatters its padded pieces."""
    return symed_receive_masked_pieces_table(
        endpoints, steps, n_valid, hello, t_seen, cfg, table,
        digitize_every_k=digitize_every_k, use_kernel=use_kernel,
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(table, slot, blank):
    """Reset one slot of the table to a blank state (open / reopen)."""
    return jax.tree.map(lambda l, b: l.at[slot].set(b), table, blank)


@jax.jit
def _read_slot(table, slot):
    """Extract one slot's ReceiverState (for finish / monitoring)."""
    return jax.tree.map(lambda l: l[slot], table)


@jax.jit
def _gather_slots(table, perm):
    """Reorder/resize the table by gathering ``perm`` (autoscale shrink).

    A pure gather: slot states move bitwise-unchanged, so the delta-
    concatenation contract is untouched by any resize point.  Not donated --
    the output shape differs from the input's.
    """
    return jax.tree.map(lambda l: l[perm], table)


@jax.jit
def _concat_slots(table, blanks):
    """Append blank slots to the table (autoscale grow)."""
    return jax.tree.map(
        lambda l, b: jnp.concatenate([l, b], axis=0), table, blanks)


def _new_delta() -> dict:
    """Empty merged symbol-delta accumulator (one per sid per ingest call)."""
    return {"labels": [], "endpoints": [], "n_new": 0, "frames": 0,
            "bytes": 0.0}


def _finalize_deltas(deltas: Dict[str, dict]) -> Dict[str, dict]:
    """Concatenate each accumulator's per-round slices into flat arrays."""
    for out in deltas.values():
        out["labels"] = (np.concatenate(out["labels"])
                         if out["labels"] else np.zeros((0,), np.int32))
        out["endpoints"] = (np.concatenate(out["endpoints"])
                            if out["endpoints"] else np.zeros((0,), np.float32))
    return deltas


@dataclasses.dataclass
class _Session:
    """Host-side bookkeeping for one live slot (device state is the table)."""

    stream_id: str
    slot: int
    chunks: int = 0           # non-empty windows ingested
    t_seen: int = 0           # stream points ingested
    symbols_out: int = 0      # symbols emitted across delta frames
    frames_out: int = 0       # delta frames emitted
    bytes_out: float = 0.0    # outbound delta-frame bytes
    last_active: int = 0      # server clock at last arrival (LRU eviction)
    raw: Optional[List[np.ndarray]] = None  # raw points (DTW monitor only)
    dtw: Optional[float] = None             # latest monitor reading


class StreamServer:
    """Session-table SymED service: resident ``ReceiverState`` per stream.

    ``open(stream_id)`` allocates a slot, ``ingest(stream_id, window)``
    feeds a ragged arrival through the donated batched step and returns the
    symbol-delta frame it produced, ``close(stream_id)`` flushes the stream
    and frees the slot.  All sessions advance together: ``ingest_many``
    batches concurrent arrivals into a single device program.

    Args:
      cfg: SymED hyperparameters (shared by every session).
      max_sessions: slot-table capacity (static; the batched step's shape).
      window_cap: padded arrival width.  Longer arrivals are split into
        ``window_cap``-sized rounds host-side; shorter ones are padded and
        masked, so any arrival size works without retracing.
      digitize_every_k: digitize cadence in non-empty windows per session
        (``symed_receive_chunk`` semantics; 0 defers symbols to ``close``).
      dtw_every: every this-many windows per session, reconstruct from the
        accumulated pieces and score DTW against the raw points seen so far
        (0 disables; enabling keeps each session's raw history on the host).
      dtw_band: Sakoe-Chiba radius for the monitor (None = full DTW).
      evict_idle: when the table is full *and cannot grow further*, ``open``
        evicts the least-recently active session (final output parked in
        ``server.evicted``) instead of raising.
      autoscale: grow/shrink the donated slot table between steps.  The
        capacity walks a power-of-two ladder from ``min_slots`` up to
        ``max_sessions``: ``open`` on a full table doubles it (carrying every
        live state), ``close``/eviction shrinks it once occupancy falls to a
        quarter of the current size (live slots are compacted with a pure
        gather, so states move bitwise-unchanged and the delta-concatenation
        contract holds across every resize point).  Eviction only fires at
        ``max_sessions``.  Each distinct capacity traces the batched step
        once (between-steps cost, amortized at steady state).
      min_slots: autoscale floor (default: the mesh device count, else 1).
      shrink_patience: autoscale hysteresis -- shrink only after this many
        *consecutive* low-occupancy observations (closes / ingest rounds).
        A session count oscillating across the quarter-occupancy boundary
        would otherwise alternate grow/shrink every tick, re-gathering the
        slot table each time.  ``1`` restores the immediate-shrink behavior.
        Resizes never touch slot contents (pure gather/concat), so delta
        streams are bitwise-unaffected by the setting (property tested).
      use_kernel: route the digitize pass's Lloyd half-steps through the
        fused Pallas k-means kernel, one ``pallas_call`` per iteration for
        the whole slot table (default: on for TPU backends, off on CPU
        where the bitwise vmapped reference is also the fastest lowering).
      pretrace: trace + compile the batched step for *every* capacity on
        the autoscale ladder at construction time (one donated call per
        rung on blank tables), so no ingest round ever pays a trace: grows
        and shrinks hit the jit cache.  Off by default -- tests and
        short-lived drivers would pay ladder-warmup for rungs they never
        visit; the CLI and benchmarks turn it on.
      seed: base PRNG seed for per-session digitizer keys.
      mesh: optional 1-D ``(data,)`` mesh; the slot table shards over it
        (``max_sessions``, ``min_slots`` and every ladder capacity must
        divide over the mesh devices).
      obs: the flight recorder (``repro.obs``).  ``None`` (default) makes a
        fresh enabled ``Observability`` bundle; ``False`` disables recording
        entirely (shared null instruments, zero per-round cost); passing a
        bundle lets layered components (e.g. the transport front end) share
        one registry -- but each registry admits only *one* ``StreamServer``
        (the totals-backed callback series are per-server).  Recording is
        host-side integer arithmetic only, so the ingest hot path stays
        sync-free; the instrumented-vs-disabled tick overhead is gated at
        <= 5% by ``benchmarks/check_bench.py``.
    """

    def __init__(
        self,
        cfg: SymEDConfig,
        *,
        max_sessions: int = 8,
        window_cap: int = 64,
        digitize_every_k: int = 1,
        dtw_every: int = 0,
        dtw_band: Optional[int] = None,
        evict_idle: bool = False,
        autoscale: bool = False,
        min_slots: Optional[int] = None,
        shrink_patience: int = 3,
        use_kernel: Optional[bool] = None,
        pretrace: bool = False,
        seed: int = 0,
        mesh=None,
        obs=None,
    ):
        if max_sessions < 1:
            raise ValueError(f"max_sessions must be >= 1, got {max_sessions}")
        if window_cap < 1:
            raise ValueError(f"window_cap must be >= 1, got {window_cap}")
        if digitize_every_k < 0:
            raise ValueError(
                f"digitize_every_k must be >= 0, got {digitize_every_k}")
        if dtw_every < 0:
            raise ValueError(f"dtw_every must be >= 0, got {dtw_every}")
        if mesh is not None and max_sessions % mesh.devices.size:
            raise ValueError(
                f"max_sessions={max_sessions} must divide over the "
                f"{mesh.devices.size}-device mesh")
        if min_slots is None:
            min_slots = mesh.devices.size if mesh is not None else 1
        if not 1 <= min_slots <= max_sessions:
            raise ValueError(
                f"min_slots={min_slots} must be in [1, {max_sessions}]")
        if mesh is not None and min_slots % mesh.devices.size:
            raise ValueError(
                f"min_slots={min_slots} must divide over the "
                f"{mesh.devices.size}-device mesh")
        if shrink_patience < 1:
            raise ValueError(
                f"shrink_patience must be >= 1, got {shrink_patience}")
        self.cfg = cfg
        self.max_sessions = int(max_sessions)
        self.window_cap = int(window_cap)
        self.digitize_every_k = int(digitize_every_k)
        self.dtw_every = int(dtw_every)
        self.dtw_band = dtw_band
        self.evict_idle = bool(evict_idle)
        self.autoscale = bool(autoscale)
        self.min_slots = int(min_slots)
        self.shrink_patience = int(shrink_patience)
        self._low_ticks = 0         # consecutive low-occupancy observations
        self.use_kernel = (bool(use_kernel) if use_kernel is not None
                           else not ops.on_cpu())
        # capacity ladder: min_slots * 2^i, clipped at max_sessions
        self._ladder = [self.min_slots]
        while self._ladder[-1] < self.max_sessions:
            self._ladder.append(min(self._ladder[-1] * 2, self.max_sessions))
        self.capacity = self.min_slots if autoscale else self.max_sessions
        self._mesh = mesh
        self._base_key = jax.random.key(seed)
        self._serial = 0            # sessions ever opened (key derivation)
        self._clock = 0             # ingest rounds (LRU ordering)
        self._sessions: Dict[str, _Session] = {}
        self._dtw_due: set = set()  # sessions whose DTW cadence fired
        self._free = list(range(self.capacity))
        self.evicted: Dict[str, dict] = {}
        # fleet-wide wire accounting (the service's fleet_report counterpart)
        self.totals = {
            "points_in": 0, "bytes_in": 0.0, "symbols_out": 0,
            "frames_out": 0, "bytes_out": 0.0, "steps": 0,
            "opened": 0, "closed": 0, "evicted": 0,
            "grows": 0, "shrinks": 0,
        }
        self._table = self._shard(self._blanks(self.capacity))
        if pretrace:
            self._pretrace_ladder()
        self.obs = as_obs(obs)
        self._obs_on = self.obs.enabled
        self._ann = (trace_annotation if self.obs.jax_annotate
                     else _null_annotation)
        # retrace accounting baseline: jit cache entries at construction
        # (module-level cache, so the counter reports compiles observed by
        # *this* server since its init -- incl. first-touch rungs when
        # pretrace is off)
        self._compiled_base = self._cache_entries()
        self._compiled_seen = self._compiled_base
        self._register_metrics()

    @staticmethod
    def _cache_entries() -> int:
        return int(_table_step._cache_size() + _table_step_pieces._cache_size())

    def _note_compiles(self) -> None:
        """Drop an instant trace event when the jit cache grew this round.

        A growing cache during serving means a retrace the pretrace ladder
        did not cover -- exactly the event worth seeing on the timeline.
        Cost when nothing changed: two cache-size reads (dict lens).
        """
        cs = self._cache_entries()
        if cs > self._compiled_seen:
            self.obs.tracer.instant("stream.retrace", {"compiled": cs})
            self._compiled_seen = cs

    def _register_metrics(self) -> None:
        """Wire the flight recorder to this server.

        Histograms are recorded in the serving loop (integer bucket adds);
        everything already counted in ``self.totals`` is exposed as
        scrape-time callback series instead -- zero added hot-path work.
        """
        m = self.obs.metrics
        self._h_symbol_lat = m.histogram(
            "symed_symbol_latency_seconds",
            "per-symbol latency: window arrival to delta-frame emit "
            "(the paper's 42 ms metric)", unit="ns")
        self._h_tick = m.histogram(
            "symed_ingest_tick_seconds",
            "per-round ingest latency: pack + dispatch + harvest", unit="ns")
        if not self._obs_on:
            return
        t = self.totals
        for key, name, help_text in (
            ("points_in", "symed_points_in_total", "raw points ingested"),
            ("bytes_in", "symed_wire_in_bytes_total", "inbound wire bytes"),
            ("symbols_out", "symed_symbols_out_total", "symbols emitted"),
            ("frames_out", "symed_frames_out_total", "delta frames emitted"),
            ("bytes_out", "symed_wire_out_bytes_total", "outbound wire bytes"),
            ("steps", "symed_batched_steps_total", "donated table steps run"),
            ("opened", "symed_sessions_opened_total", "sessions opened"),
            ("closed", "symed_sessions_closed_total", "sessions closed"),
            ("evicted", "symed_sessions_evicted_total", "sessions LRU-evicted"),
            ("grows", "symed_table_grows_total", "autoscale ladder grows"),
            ("shrinks", "symed_table_shrinks_total", "autoscale ladder shrinks"),
        ):
            m.counter_fn(name, help_text,
                         (lambda k=key: float(t[k])))
        m.gauge_fn("symed_active_sessions", "open sessions",
                   lambda: float(len(self._sessions)))
        m.gauge_fn("symed_table_capacity", "slot-table capacity",
                   lambda: float(self.capacity))
        m.counter_fn("symed_table_retraces_total",
                     "batched-step compiles observed since server init",
                     lambda: float(max(self._cache_entries()
                                       - self._compiled_base, 0)))

    def _pretrace_ladder(self) -> None:
        """Warm the jit cache for every capacity on the autoscale ladder.

        AOT ``lower().compile()`` would not populate the call cache jit
        actually consults, so each rung makes one real (donated) call on a
        blank table with zero-valid windows -- a masked no-op that leaves no
        state behind.  After this, grow/shrink during serving never traces
        (asserted flat by ``tests/test_stream_service.py`` via
        ``_table_step._cache_size()``).
        """
        ladder = self._ladder if self.autoscale else [self.capacity]
        for cap in ladder:
            blanks = self._shard(self._blanks(cap))
            win_f = self._put(jnp.zeros((cap, self.window_cap), jnp.float32))
            win_i = self._put(jnp.zeros((cap, self.window_cap), jnp.int32))
            cnt = self._put(jnp.zeros((cap,), jnp.int32))
            scal_f = self._put(jnp.zeros((cap,), jnp.float32))
            scal_i = self._put(jnp.zeros((cap,), jnp.int32))
            blanks, _ = _table_step(
                blanks, win_f, cnt,
                cfg=self.cfg, digitize_every_k=self.digitize_every_k,
                use_kernel=self.use_kernel)
            _table_step_pieces(
                blanks, win_f, win_i, cnt, scal_f, scal_i,
                cfg=self.cfg, digitize_every_k=self.digitize_every_k,
                use_kernel=self.use_kernel)

    def _blanks(self, n: int):
        """``n`` fresh blank slots (keys are placeholders; ``open`` reseeds)."""
        return jax.vmap(lambda k: receiver_init(self.cfg, k))(
            jax.random.split(self._base_key, n))

    def _shard(self, table):
        if self._mesh is not None:
            table = jax.device_put(
                table, NamedSharding(self._mesh, P("data")))
        return table

    def _put(self, arr):
        """Stage one slot-axis operand (sharded over the mesh if present)."""
        if self._mesh is not None:
            arr = jax.device_put(arr, NamedSharding(self._mesh, P("data")))
        return arr

    # ------------------------------------------------------------------ API

    @property
    def active_sessions(self) -> int:
        return len(self._sessions)

    def __contains__(self, stream_id: str) -> bool:
        return stream_id in self._sessions

    def session_ids(self) -> List[str]:
        """Open session ids, in open order (monitoring surface)."""
        return list(self._sessions)

    def session_stats(self, stream_id: str) -> dict:
        """Live bookkeeping for one open session (monitoring surface)."""
        sess = self._sessions[stream_id]
        return {
            "slot": sess.slot, "chunks": sess.chunks, "t_seen": sess.t_seen,
            "symbols_out": sess.symbols_out, "frames_out": sess.frames_out,
            "bytes_out": sess.bytes_out, "dtw": sess.dtw,
        }

    def open(self, stream_id: str, key: Optional[jax.Array] = None) -> int:
        """Allocate a slot for ``stream_id``; returns the slot index.

        ``key`` seeds the session's digitizer (default: derived from the
        server seed and the session serial, so every session is independent
        and reproducible).
        """
        if stream_id in self._sessions:
            raise ValueError(f"session {stream_id!r} is already open")
        if not self._free and self.capacity < self.max_sessions:
            self._grow()
        if not self._free:
            if not self.evict_idle:
                raise RuntimeError(
                    f"session table full ({self.max_sessions} slots); "
                    "close a session or construct with evict_idle=True")
            lru = min(self._sessions.values(), key=lambda s: s.last_active)
            self.obs.tracer.instant("stream.evict", {"session": lru.stream_id})
            self.evicted[lru.stream_id] = self.close(lru.stream_id)
            self.totals["evicted"] += 1
            self.totals["closed"] -= 1  # eviction is not a clean close
        slot = self._free.pop()
        self._serial += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, self._serial)
        self._table = _write_slot(
            self._table, jnp.asarray(slot, jnp.int32),
            receiver_init(self.cfg, key))
        self._sessions[stream_id] = _Session(
            stream_id=stream_id, slot=slot, last_active=self._clock,
            raw=[] if self.dtw_every else None,
        )
        self.totals["opened"] += 1
        self.totals["bytes_in"] += 4.0  # the t0 "hello" payload
        return slot

    def ingest(self, stream_id: str, window) -> dict:
        """Feed one ragged arrival; returns its symbol-delta frame."""
        return self.ingest_many({stream_id: window})[stream_id]

    def ingest_many(self, arrivals: Dict[str, object]) -> Dict[str, dict]:  # symlint: hot-path
        """Feed concurrent arrivals through one batched step per round.

        ``arrivals`` maps open stream ids to 1-D float windows of any
        length; windows longer than ``window_cap`` are split into
        consecutive rounds so every session advances in lockstep.  Returns
        the merged symbol-delta frame per stream:
        ``{"labels", "endpoints", "n_new", "frames", "bytes"}``.

        Rounds are double-buffered against the device: round ``r`` is
        dispatched (async), round ``r+1`` is packed host-side, and only
        then is round ``r``'s output transferred back -- host staging and
        accounting overlap device work instead of serializing with it.
        """
        wins = {}
        for sid, w in arrivals.items():
            if sid not in self._sessions:
                raise KeyError(f"unknown session {sid!r} (open it first)")
            w = np.asarray(w, np.float32).reshape(-1)
            wins[sid] = w
        deltas = {sid: _new_delta() for sid in wins}
        rounds = max(
            (len(w) + self.window_cap - 1) // self.window_cap
            for w in wins.values()
        ) if wins else 0
        obs_on = self._obs_on
        tracer = self.obs.tracer
        pend_active, pend_info, pend_clock = [], None, 0  # round in flight
        pend_t0 = 0  # arrival stamp of the round in flight (obs)
        for r in range(rounds):
            t_arrive = time.perf_counter_ns() if obs_on else 0
            padded = np.zeros((self.capacity, self.window_cap), np.float32)
            n_valid = np.zeros((self.capacity,), np.int32)
            active = []
            for sid, w in wins.items():
                part = w[r * self.window_cap: (r + 1) * self.window_cap]
                if not len(part):
                    continue
                sess = self._sessions[sid]
                padded[sess.slot, : len(part)] = part
                n_valid[sess.slot] = len(part)
                active.append((sid, part))
            if active:
                windows = self._put(jnp.asarray(padded))
                counts = self._put(jnp.asarray(n_valid))
                if obs_on:
                    tracer.add("stream.pack", t_arrive,
                               {"round": r, "sessions": len(active)})
                t_disp = time.perf_counter_ns() if obs_on else 0
                with self._ann("symed.table_step"):
                    self._table, info = _table_step(
                        self._table, windows, counts,
                        cfg=self.cfg, digitize_every_k=self.digitize_every_k,
                        use_kernel=self.use_kernel)
                if obs_on:
                    tracer.add("stream.dispatch", t_disp)
                    self._note_compiles()
                self.totals["steps"] += 1
                self._clock += 1
            # harvest the *previous* round only after this one is in flight
            if pend_active:
                self._harvest_round(pend_active, pend_info, pend_clock,
                                    deltas, pend_t0)
            pend_active = active
            if active:
                pend_info, pend_clock, pend_t0 = info, self._clock, t_arrive
        if pend_active:
            self._harvest_round(pend_active, pend_info, pend_clock, deltas,
                                pend_t0)
        self._run_dtw_monitor()
        return _finalize_deltas(deltas)

    def _harvest_round(self, active, info, clock, deltas, t0_ns=0) -> None:
        """Transfer one round's outputs and fold them into the books.

        ``t0_ns`` is the round's host arrival stamp (pack start), so the
        latency histograms measure the full arrival -> delta-frame-emit
        path across the double buffer.
        """
        obs_on = self._obs_on
        t_h = time.perf_counter_ns() if obs_on else 0
        d = info["symbol_delta"]
        # one blocking transfer per round, not one per output leaf
        labels, endpoints, n_new, emitted, t_seen = jax.device_get(  # sync: ok
            (d["labels"], d["endpoints"], d["n_new"], d["emitted"],
             info["t_seen"]))
        lat = (time.perf_counter_ns() - t0_ns) if obs_on else 0
        for sid, part in active:
            sess = self._sessions[sid]
            n = int(n_new[sess.slot])
            self._account_delta(
                sess, deltas[sid], labels[sess.slot],
                endpoints[sess.slot], n,
                bool(emitted[sess.slot]))
            if obs_on and n:
                self._h_symbol_lat.observe_n(lat, n)
            sess.chunks += 1
            sess.t_seen = int(t_seen[sess.slot])
            sess.last_active = clock
            self.totals["points_in"] += len(part)
            self.totals["bytes_in"] += 4.0 * len(part)
            if sess.raw is not None:
                sess.raw.append(part)
            if (self.dtw_every and sess.raw is not None
                    and sess.chunks % self.dtw_every == 0):
                self._dtw_due.add(sid)
        if obs_on:
            self._h_tick.observe(lat)
            self.obs.tracer.add("stream.harvest", t_h,
                                {"sessions": len(active)})

    def ingest_pieces_many(self, arrivals: Dict[str, dict]) -> Dict[str, dict]:  # symlint: hot-path
        """Compressed-in counterpart of ``ingest_many``.

        Each arrival carries pieces the *sender's* compressor finished
        (``repro.launch.transport`` pieces mode) instead of raw points:
        ``{"endpoints": (n,) f32, "steps": (n,) i32 arrival steps,
        "t_seen": int cumulative sender point clock, "t0": float hello,
        "wire_bytes": float actual inbound payload bytes (optional;
        defaults to ``PIECE_TUPLE_BYTES`` per piece)}``.  Arrivals longer
        than ``window_cap`` pieces split into consecutive rounds.  Returns
        the same merged symbol-delta dicts as ``ingest_many``.  Raw-mode and
        pieces-mode sessions may share one table (idle slots mask out of
        either batched step), but a single session must stay in one mode.
        """
        pends = {}
        for sid, a in arrivals.items():
            if sid not in self._sessions:
                raise KeyError(f"unknown session {sid!r} (open it first)")
            pends[sid] = {
                "endpoints": np.asarray(a["endpoints"], np.float32).reshape(-1),
                "steps": np.asarray(a["steps"], np.int32).reshape(-1),
                "t_seen": int(a["t_seen"]),
                "t0": float(a["t0"]),
                "wire_bytes": float(a.get("wire_bytes", 0.0)),
            }
        deltas = {sid: _new_delta() for sid in pends}
        cap = self.window_cap
        rounds = max(
            ((len(p["endpoints"]) + cap - 1) // cap or 1)
            for p in pends.values()
        ) if pends else 0
        obs_on = self._obs_on
        tracer = self.obs.tracer
        pend_active, pend_info, pend_clock = [], None, 0  # round in flight
        pend_t0 = 0  # arrival stamp of the round in flight (obs)
        for r in range(rounds):
            t_arrive = time.perf_counter_ns() if obs_on else 0
            pad_e = np.zeros((self.capacity, cap), np.float32)
            pad_s = np.zeros((self.capacity, cap), np.int32)
            n_valid = np.zeros((self.capacity,), np.int32)
            hello = np.zeros((self.capacity,), np.float32)
            t_seen_in = np.zeros((self.capacity,), np.int32)
            active = []
            for sid, p in pends.items():
                part_e = p["endpoints"][r * cap: (r + 1) * cap]
                part_s = p["steps"][r * cap: (r + 1) * cap]
                if r > 0 and not len(part_e):
                    continue
                sess = self._sessions[sid]
                pad_e[sess.slot, : len(part_e)] = part_e
                pad_s[sess.slot, : len(part_s)] = part_s
                n_valid[sess.slot] = len(part_e)
                hello[sess.slot] = p["t0"]
                t_seen_in[sess.slot] = p["t_seen"]
                active.append((sid, len(part_e)))
                if r == 0:
                    wire = (p["wire_bytes"]
                            or PIECE_TUPLE_BYTES * len(p["endpoints"]))
                    self.totals["bytes_in"] += wire
            if active:
                args = [self._put(jnp.asarray(x))
                        for x in (pad_e, pad_s, n_valid, hello, t_seen_in)]
                if obs_on:
                    tracer.add("stream.pack_pieces", t_arrive,
                               {"round": r, "sessions": len(active)})
                t_disp = time.perf_counter_ns() if obs_on else 0
                with self._ann("symed.table_step_pieces"):
                    self._table, info = _table_step_pieces(
                        self._table, *args,
                        cfg=self.cfg, digitize_every_k=self.digitize_every_k,
                        use_kernel=self.use_kernel)
                if obs_on:
                    tracer.add("stream.dispatch_pieces", t_disp)
                    self._note_compiles()
                self.totals["steps"] += 1
                self._clock += 1
            # harvest the *previous* round only after this one is in flight
            if pend_active:
                self._harvest_pieces_round(pend_active, pend_info,
                                           pend_clock, deltas, pend_t0)
            pend_active = active
            if active:
                pend_info, pend_clock, pend_t0 = info, self._clock, t_arrive
        if pend_active:
            self._harvest_pieces_round(pend_active, pend_info, pend_clock,
                                       deltas, pend_t0)
        return _finalize_deltas(deltas)

    def _harvest_pieces_round(self, active, info, clock, deltas,
                              t0_ns=0) -> None:
        """Pieces-mode counterpart of ``_harvest_round``."""
        obs_on = self._obs_on
        t_h = time.perf_counter_ns() if obs_on else 0
        d = info["symbol_delta"]
        # one blocking transfer per round, not one per output leaf
        labels, endpoints, n_new, emitted, t_seen = jax.device_get(  # sync: ok
            (d["labels"], d["endpoints"], d["n_new"], d["emitted"],
             info["t_seen"]))
        lat = (time.perf_counter_ns() - t0_ns) if obs_on else 0
        for sid, n_in in active:
            sess = self._sessions[sid]
            n = int(n_new[sess.slot])
            self._account_delta(
                sess, deltas[sid], labels[sess.slot],
                endpoints[sess.slot], n,
                bool(emitted[sess.slot]))
            if obs_on and n:
                self._h_symbol_lat.observe_n(lat, n)
            if n_in:
                sess.chunks += 1
            now_seen = int(t_seen[sess.slot])
            self.totals["points_in"] += max(now_seen - sess.t_seen, 0)
            sess.t_seen = now_seen
            sess.last_active = clock
        if obs_on:
            self._h_tick.observe(lat)
            self.obs.tracer.add("stream.harvest_pieces", t_h,
                                {"sessions": len(active)})

    def close(self, stream_id: str) -> dict:
        """Flush the tail, emit the closing delta frame, free the slot.

        Returns ``{"out", "delta", "symbols", "n_pieces", "t_seen", "dtw"}``
        where ``out`` is the full ``symed_receive_finish`` dict (bitwise
        equal to ``symed_encode`` on the points this session ingested).
        """
        sess = self._sessions.pop(stream_id, None)
        if sess is None:
            raise KeyError(f"unknown session {stream_id!r}")
        delta = {"labels": np.zeros((0,), np.int32),
                 "endpoints": np.zeros((0,), np.float32),
                 "n_new": 0, "frames": 0, "bytes": 0.0}
        out = None
        n_pieces = 0
        if sess.t_seen:  # a never-fed session has nothing to flush
            sub = _read_slot(self._table, jnp.asarray(sess.slot, jnp.int32))
            out = symed_receive_finish(sub, self.cfg, with_delta=True)
            d = out["symbol_delta"]
            n = int(d["n_new"])
            frame = DELTA_FRAME_HEADER_BYTES + DELTA_SYMBOL_BYTES * n
            delta = {"labels": np.asarray(d["labels"])[:n],
                     "endpoints": np.asarray(d["endpoints"])[:n],
                     "n_new": n, "frames": 1, "bytes": frame}
            n_pieces = int(out["n_pieces"])
            sess.symbols_out += n
            sess.frames_out += 1
            sess.bytes_out += frame
            self.totals["symbols_out"] += n
            self.totals["frames_out"] += 1
            self.totals["bytes_out"] += frame
        self._free.append(sess.slot)
        self.totals["closed"] += 1
        self._maybe_shrink()
        return {
            "stream_id": stream_id,
            "out": out,
            "delta": delta,
            "symbols": (symbols_to_string(out["symbols_online"], n_pieces)
                        if out is not None else ""),
            "n_pieces": n_pieces,
            "t_seen": sess.t_seen,
            "symbols_out": sess.symbols_out,
            "bytes_out": sess.bytes_out,
            "dtw": sess.dtw,
        }

    def report(self, wall_seconds: float) -> Dict[str, object]:
        """Host-side service summary (the fleet_report counterpart).

        All top-level values are floats; when the flight recorder is
        enabled, an ``"obs"`` key holds its nested JSON snapshot
        (counters / gauges / histogram digests with p50/p99/p999).

        ``wire_in_bytes``/``wire_in_ratio`` measure inbound traffic against
        the raw-points equivalent (4 B/point): ~1 for raw-in transport,
        ~``PIECE_TUPLE_BYTES / (4 * points-per-piece)`` when senders
        compress locally (the paper's 9.5%-of-raw headline is this ratio's
        sender-side half).  ``wire_out_ratio`` measures outbound symbol
        frames against the *same raw-bytes denominator* -- it answers "what
        fraction of the original signal's bytes did downstream consumers
        receive", so it stays comparable across transports.  (It used to
        divide by ``bytes_in``, which for compressed-in transport is itself
        ~10% of raw -- tiny cadence frames with 4 B headers then pushed the
        ratio past 1.0 even though the service was *reducing* traffic.)
        """
        t = {k: float(v) for k, v in self.totals.items()}
        dt = max(wall_seconds, 1e-9)
        raw_bytes = 4.0 * t["points_in"]
        rep: Dict[str, object] = {
            **t,
            "active": float(self.active_sessions),
            "capacity": float(self.capacity),
            "wall_seconds": wall_seconds,
            "points_per_s": t["points_in"] / dt,
            "symbols_per_s": t["symbols_out"] / dt,
            "ms_per_symbol": 1e3 * dt / max(t["symbols_out"], 1.0),
            "raw_bytes": raw_bytes,
            "wire_in_bytes": t["bytes_in"],
            "wire_in_ratio": t["bytes_in"] / max(raw_bytes, 1.0),
            "wire_out_ratio": t["bytes_out"] / max(raw_bytes, 1.0),
        }
        if self._obs_on:
            rep["obs"] = self.obs.snapshot()
        return rep

    # ------------------------------------------------------------- internals

    def _account_delta(self, sess: _Session, out: dict, labels_row,
                       endpoints_row, n: int, emitted: bool) -> None:
        """Fold one round's symbol delta for one session into its merged
        accumulator + the session/fleet wire-out books (shared by the raw
        and compressed-in ingest paths)."""
        out["labels"].append(labels_row[:n])
        out["endpoints"].append(endpoints_row[:n])
        out["n_new"] += n
        sess.symbols_out += n
        self.totals["symbols_out"] += n
        if emitted:
            frame = DELTA_FRAME_HEADER_BYTES + DELTA_SYMBOL_BYTES * n
            sess.frames_out += 1
            sess.bytes_out += frame
            out["frames"] += 1
            out["bytes"] += frame
            self.totals["frames_out"] += 1
            self.totals["bytes_out"] += frame

    def _grow(self) -> None:
        """Double the slot table (next ladder capacity), carrying all state.

        Runs between batched steps: live slots keep their indices, the new
        upper half is blank.  The next ``_table_step`` call at this capacity
        traces once; steady state at the new size re-donates as before.
        """
        new_cap = self._ladder[self._ladder.index(self.capacity) + 1]
        self._table = self._shard(_concat_slots(
            self._table, self._blanks(new_cap - self.capacity)))
        self._free.extend(range(self.capacity, new_cap))
        self.capacity = new_cap
        self.totals["grows"] += 1
        self.obs.tracer.instant("stream.grow", {"capacity": new_cap})

    def _maybe_shrink(self) -> None:
        """Walk down the ladder once occupancy has stayed at or below a
        quarter of the capacity for ``shrink_patience`` consecutive
        qualifying ticks.

        Two hysteresis mechanisms compose here: the quarter-occupancy bound
        means the shrunken table is at most half full (a single open cannot
        immediately force a re-grow), and the patience counter means a
        session count oscillating across the boundary every tick does not
        re-gather the slot table every tick -- it must *stay* low for
        ``shrink_patience`` observations first.  The walk-down itself is a
        pure permutation of live slots, so delta output is bitwise
        unaffected by when (or whether) it fires.
        """
        if not (self.autoscale and self.capacity > self.min_slots):
            self._low_ticks = 0
            return
        target = self._ladder[self._ladder.index(self.capacity) - 1]
        if len(self._sessions) > target // 2:
            self._low_ticks = 0
            return
        self._low_ticks += 1
        if self._low_ticks < self.shrink_patience:
            return
        self._low_ticks = 0
        while self.autoscale and self.capacity > self.min_slots:
            target = self._ladder[self._ladder.index(self.capacity) - 1]
            if len(self._sessions) > target // 2:
                return
            # compact live slots (ascending, stable) into the low indices,
            # fill the rest from free (blank or stale) slots
            live = sorted(self._sessions.values(), key=lambda s: s.slot)
            perm = [s.slot for s in live]
            perm += [f for f in sorted(self._free)][: target - len(perm)]
            self._table = self._shard(_gather_slots(
                self._table, jnp.asarray(perm, jnp.int32)))
            for new_slot, sess in enumerate(live):
                sess.slot = new_slot
            self._free = list(range(len(live), target))
            self.capacity = target
            self.totals["shrinks"] += 1
            self.obs.tracer.instant("stream.shrink", {"capacity": target})

    def _run_dtw_monitor(self) -> None:
        """Online reconstruction error for every session whose DTW cadence
        fired during this ingest call: DTW(raw so far, pieces so far).

        All due sessions are read out of the slot table in one gather and
        one host transfer (the monitor used to do a per-session
        ``_read_slot`` + unannotated transfer inside the serving loop).
        Jit-compiles per distinct stream length (the reconstruction's output
        shape); the simulated driver keeps lengths small, a production
        monitor would bucket them.
        """
        if not self._dtw_due:
            return
        due = [self._sessions[sid] for sid in sorted(self._dtw_due)
               if sid in self._sessions]
        self._dtw_due.clear()
        if not due:
            return
        t_dtw = time.perf_counter_ns() if self._obs_on else 0
        subs = _gather_slots(
            self._table, jnp.asarray([s.slot for s in due], jnp.int32))
        # one transfer for the whole due set, off the per-round hot path
        subs = jax.device_get(subs)  # sync: ok
        for i, sess in enumerate(due):
            sub = jax.tree.map(lambda leaf: leaf[i], subs)
            raw = np.concatenate(sess.raw)
            lens, incs = pieces_from_wire(
                sub.endpoints, sub.steps, sub.n_pieces, sub.t0)
            rec = reconstruct_from_pieces(
                lens, incs, sub.n_pieces, sub.t0, raw.shape[0])
            d = ops.dtw(raw[None], np.asarray(rec)[None], band=self.dtw_band,
                        force_ref=ops.on_cpu())
            sess.dtw = float(d[0])
        if self._obs_on:
            self.obs.tracer.add("stream.dtw_monitor", t_dtw,
                                {"sessions": len(due)})


# ----------------------------------------------------------------- CLI


def validate_cli_args(ap: argparse.ArgumentParser, args) -> None:
    """Fail fast (exit 2) before any jax work, like the fleet CLI.

    Shared-flag checks live in ``repro.launch.cli.validate_shared_args``;
    only the stream-specific constraints remain here.
    """
    from repro.launch.cli import validate_shared_args

    validate_shared_args(ap, args)
    if args.dtw_every < 0:
        ap.error(f"--dtw-every must be >= 0, got {args.dtw_every}")
    if args.sessions > args.max_slots and not args.evict \
            and args.workload is None:
        ap.error(f"--sessions {args.sessions} exceeds --max-slots "
                 f"{args.max_slots}; pass --evict to allow LRU eviction")
    if args.workload is not None and args.arrival_pattern is not None:
        ap.error("--workload and --arrival-pattern are mutually exclusive")


def _build_workload(args):
    """Resolve the CLI's arrival flags into a ``repro.workload`` trace.

    Precedence: ``--workload FILE.jsonl`` (recorded trace) >
    ``--workload SCENARIO`` (synthesized with the CLI's shape knobs) >
    ``--arrival-pattern`` (deprecated shim) > silent ``roundrobin``.
    """
    from repro.workload import SCENARIOS, Trace, Workload, scenario_seed

    if args.workload is not None and args.workload not in SCENARIOS:
        return Trace.load(args.workload)  # recorded workload_trace/v1 jsonl
    if args.workload is not None:
        wl = Workload(args.workload,
                      seed=scenario_seed(args.workload, args.seed),
                      sessions=args.sessions, length=args.length,
                      window=args.window)
        return wl.trace()
    pattern = args.arrival_pattern
    wl = Workload.from_pattern(
        pattern if pattern is not None else "roundrobin",
        sessions=args.sessions, length=args.length, window=args.window,
        seed=args.seed, _warn=pattern is not None)
    return wl.trace()


def main():
    from repro.launch.cli import (
        add_devices_arg, add_metrics_args, add_slot_table_args,
        add_symed_args)

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--sessions", type=int, default=6,
                    help="simulated streams arriving at the service")
    ap.add_argument("--length", type=int, default=384)
    ap.add_argument("--window", type=int, default=48,
                    help="arrival window cap (ragged arrivals are padded)")
    ap.add_argument("--workload", default=None, metavar="NAME|FILE",
                    help="arrival trace: a repro.workload scenario name or "
                         "a recorded workload_trace/v1 jsonl "
                         "(default: roundrobin)")
    ap.add_argument("--arrival-pattern", default=None,
                    choices=("roundrobin", "random", "bursty"),
                    help="(deprecated: use --workload) legacy arrival shim")
    ap.add_argument("--dtw-every", type=int, default=0,
                    help="online DTW monitor cadence in windows (0: off)")
    ap.add_argument("--verify", action="store_true",
                    help="check delta concatenation against symed_encode")
    add_slot_table_args(ap, max_slots=4)
    add_devices_arg(
        ap, help="forced host device count; >1 shards the slot table")
    add_symed_args(ap)
    add_metrics_args(ap)
    args = ap.parse_args()
    validate_cli_args(ap, args)

    from repro.launch.fleet import fleet_data_mesh
    from repro.workload.replay import replay_trace

    trace = _build_workload(args)
    window_cap = trace.window  # a recorded trace carries its own shape
    cfg = SymEDConfig(tol=args.tol, alpha=args.alpha, n_max=256, k_max=32,
                      len_max=256)
    mesh = fleet_data_mesh() if args.devices > 1 else None
    obs = Observability(trace_capacity=65536)
    server = StreamServer(
        cfg, max_sessions=args.max_slots, window_cap=window_cap,
        digitize_every_k=args.digitize_every, dtw_every=args.dtw_every,
        evict_idle=args.evict, autoscale=args.autoscale,
        min_slots=args.min_slots, shrink_patience=args.shrink_patience,
        seed=args.seed, mesh=mesh, pretrace=args.pretrace, obs=obs,
    )
    exporter = None
    if args.metrics_port is not None:
        from repro.obs.export import start_exporter
        exporter = start_exporter(obs, args.metrics_port)
        print(f"metrics exporter        : {exporter.url}/metrics")

    res = replay_trace(trace, cfg=cfg, server=server, verify=args.verify)

    rep = server.report(res.wall_seconds)
    print(f"devices / table shards  : {args.devices}")
    print(f"slot table              : {args.max_slots} slots"
          f"{' (autoscaled)' if args.autoscale else ''}, "
          f"window cap {window_cap}, workload {trace.name}")
    print(f"sessions                : {int(rep['opened'])} opened, "
          f"{int(rep['closed'])} closed, {int(rep['evicted'])} evicted")
    # stable machine-readable summary (CI smoke jobs grep these key=value
    # pairs; keep the keys backward-compatible)
    print("stream_summary "
          f"opened={int(rep['opened'])} closed={int(rep['closed'])} "
          f"evicted={int(rep['evicted'])} capacity={int(rep['capacity'])} "
          f"grows={int(rep['grows'])} shrinks={int(rep['shrinks'])} "
          f"wire_in_bytes={int(rep['wire_in_bytes'])} "
          f"wire_out_bytes={int(rep['bytes_out'])}")
    print(f"wall time               : {rep['wall_seconds']:.2f}s "
          f"({int(rep['steps'])} batched steps)")
    print(f"points in               : {int(rep['points_in'])} "
          f"({int(rep['bytes_in'])} wire-in bytes)")
    print(f"symbols out             : {int(rep['symbols_out'])} in "
          f"{int(rep['frames_out'])} delta frames "
          f"({int(rep['bytes_out'])} wire-out bytes)")
    print(f"symbol latency          : {rep['ms_per_symbol']:.3f} ms/symbol "
          f"(paper: 42ms single-CPU)")
    if args.dtw_every:
        vals = [s["dtw"] for s in res.sessions.values()
                if s["dtw"] is not None]
        if vals:
            print(f"online DTW monitor      : mean {np.mean(vals):.3f} "
                  f"over {len(vals)} sessions")

    if args.verify:
        # the replay engine already ran the bitwise delta-concatenation
        # check against symed_encode (replay_trace(verify=True) raises on
        # any mismatch)
        print(f"delta equivalence       : OK ({res.verified} sessions "
              f"bitwise)")

    # flight-recorder summary (stable key=value line, like stream_summary)
    snap = obs.snapshot()
    lat = snap["histograms"].get("symed_symbol_latency_seconds", {})
    print("obs_summary "
          f"symbol_p50_ms={1e3 * lat.get('p50', 0.0):.3f} "
          f"symbol_p99_ms={1e3 * lat.get('p99', 0.0):.3f} "
          f"symbol_p999_ms={1e3 * lat.get('p999', 0.0):.3f} "
          f"symbols={int(lat.get('count', 0))} "
          f"spans={int(snap['spans_recorded'])}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"trace written           : {args.trace_out} "
              f"({obs.tracer.recorded} events, load at ui.perfetto.dev)")
    if exporter is not None:
        if args.metrics_linger:
            print(f"metrics exporter        : lingering "
                  f"{args.metrics_linger:.0f}s for scrapes", flush=True)
            time.sleep(args.metrics_linger)
        exporter.close()


if __name__ == "__main__":
    main()
