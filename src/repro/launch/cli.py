"""Shared argparse surface for the launch CLIs.

``repro.launch.stream``, ``repro.launch.transport`` and
``repro.launch.fleet`` grew the same flags three times -- device forcing,
SymED knobs, metrics/trace export, slot-table shape -- with drifting
defaults and validation.  This module is the single place each group is
declared and validated, so the three CLIs (and ``repro.workload``) accept
and reject identically.

Import safety: this module must stay importable *before* jax -- the
``__main__`` blocks call :func:`prescan_host_devices` to pin the forced
host device count, and jax locks the device count on first init.  Nothing
here may import jax (directly or transitively).
"""
from __future__ import annotations

import argparse
import os
import sys

__all__ = [
    "prescan_host_devices",
    "add_devices_arg",
    "add_symed_args",
    "add_metrics_args",
    "add_slot_table_args",
    "validate_shared_args",
]


def prescan_host_devices(argv=None, default: str = "1") -> None:
    """Set ``XLA_FLAGS`` from a raw ``--devices`` scan, before jax imports.

    jax locks the host device count on first init, so argparse is too late:
    the ``__main__`` blocks call this on ``sys.argv`` before importing
    anything that pulls in jax.  A malformed value is left for argparse to
    reject with a proper message.
    """
    argv = sys.argv if argv is None else argv
    n = default
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif a.startswith("--devices="):
            n = a.split("=", 1)[1]
    try:
        count = int(n)
    except ValueError:
        return
    if count > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={count} "
            + os.environ.get("XLA_FLAGS", "")
        )


def add_devices_arg(ap: argparse.ArgumentParser, *, default: int = 1,
                    help: str = "forced host device count; >1 shards "
                                "over a data mesh") -> None:
    ap.add_argument("--devices", type=int, default=default, help=help)


def add_symed_args(ap: argparse.ArgumentParser, *, seed: bool = True) -> None:
    """The compressor/digitizer knobs every driver threads into SymEDConfig."""
    ap.add_argument("--tol", type=float, default=0.5,
                    help="compression tolerance (paper's tol)")
    ap.add_argument("--alpha", type=float, default=0.01,
                    help="digitizer EWMA smoothing in (0, 1]")
    if seed:
        ap.add_argument("--seed", type=int, default=0,
                        help="base seed: synthetic data + per-session "
                             "digitizer keys")


def add_metrics_args(ap: argparse.ArgumentParser) -> None:
    """Flight-recorder export: Prometheus endpoint + Perfetto span trace."""
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus /metrics (+ /metrics.json, "
                         "/trace) on this port for the run's duration")
    ap.add_argument("--metrics-linger", type=float, default=0.0,
                    help="keep the metrics endpoint up this many seconds "
                         "after the run finishes (scrape window)")
    ap.add_argument("--trace-out", default=None,
                    help="write the span ring as Chrome trace-event JSON "
                         "(load at ui.perfetto.dev)")


def add_slot_table_args(ap: argparse.ArgumentParser, *,
                        max_slots: int = 4) -> None:
    """The resident ``StreamServer`` table shape (stream + transport serve)."""
    ap.add_argument("--max-slots", type=int, default=max_slots,
                    help="resident slot-table capacity")
    ap.add_argument("--min-slots", type=int, default=None,
                    help="autoscale floor (default: --devices)")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow/shrink the slot table between steps "
                         "(power-of-two ladder from --min-slots)")
    ap.add_argument("--evict", action="store_true",
                    help="LRU-evict when sessions exceed slots")
    ap.add_argument("--digitize-every", type=int, default=1,
                    help="digitize cadence in ingest windows")
    ap.add_argument("--shrink-patience", type=int, default=3,
                    help="consecutive low-occupancy ticks before the table "
                         "walks down the ladder (1: shrink immediately)")
    ap.add_argument("--pretrace", action="store_true",
                    help="warm the jit cache for every ladder capacity at "
                         "server init (no tracing during serving)")


def validate_shared_args(ap: argparse.ArgumentParser, args) -> None:
    """Fail fast (exit 2 via ``ap.error``) before any jax work.

    Checks every shared flag the namespace actually carries (``getattr``
    guards), so one validator serves parsers that mounted different
    subsets.  Messages are part of the CLI contract -- subprocess tests
    pin them -- so change them deliberately.
    """
    def has(name):
        return getattr(args, name, None) is not None

    if has("streams") and args.streams < 1:
        ap.error(f"--streams must be >= 1, got {args.streams}")
    if has("sessions") and args.sessions < 1:
        ap.error(f"--sessions must be >= 1, got {args.sessions}")
    if has("length") and args.length < 2:
        ap.error(f"--length must be >= 2, got {args.length}")
    if has("window"):
        if args.window < 1:
            ap.error(f"--window must be >= 1, got {args.window}")
        if has("length") and args.window > args.length:
            ap.error(f"--window {args.window} exceeds --length {args.length}")
    if has("digitize_every") and args.digitize_every < 0:
        ap.error(f"--digitize-every must be >= 0, got {args.digitize_every}")
    if has("tol") and args.tol <= 0:
        ap.error(f"--tol must be > 0, got {args.tol}")
    if has("alpha") and not 0 < args.alpha <= 1:
        ap.error(f"--alpha must be in (0, 1], got {args.alpha}")
    if has("devices") and args.devices < 1:
        ap.error(f"--devices must be >= 1, got {args.devices}")
    if has("max_slots"):
        if args.max_slots < 1:
            ap.error(f"--max-slots must be >= 1, got {args.max_slots}")
        if has("devices") and args.max_slots % args.devices:
            ap.error(f"--max-slots {args.max_slots} must divide over "
                     f"--devices {args.devices}")
    if has("min_slots"):
        if has("max_slots") and not 1 <= args.min_slots <= args.max_slots:
            ap.error(f"--min-slots {args.min_slots} must be in "
                     f"[1, --max-slots {args.max_slots}]")
        if has("devices") and args.min_slots % args.devices:
            ap.error(f"--min-slots {args.min_slots} must divide over "
                     f"--devices {args.devices}")
    if has("shrink_patience") and args.shrink_patience < 1:
        ap.error(f"--shrink-patience must be >= 1, got {args.shrink_patience}")
    if has("metrics_port") and not 0 <= args.metrics_port <= 65535:
        ap.error(f"--metrics-port must be in [0, 65535], got "
                 f"{args.metrics_port}")
    if has("metrics_linger") and args.metrics_linger < 0:
        ap.error(f"--metrics-linger must be >= 0, got {args.metrics_linger}")
