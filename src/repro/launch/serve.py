"""Batched serving driver: prefill a prompt batch, decode greedily.

Reduced configs run on CPU; full configs lower onto the production mesh (the
decode_32k / long_500k dry-run cells exercise exactly this step function).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params, prefill
from repro.train.steps import make_serve_step


def frontend_inputs(cfg, batch: int):
    """Stub modality inputs + the decoder-sequence prefix they prepend.

    Returns ``(kw, prefix_len)``.  ``prefix_len`` is derived from the input
    that actually gets *prepended* to the decoder sequence
    (``prefix_embeds``; encoder memories consumed via cross-attention add
    no decoder positions) -- the one rule ``prefill`` itself applies when it
    computes ``s_total``.  Deriving the KV allocation from the same kw dict,
    instead of re-matching on the frontend name, keeps the two accountings
    from drifting: a frontend whose prefix is miscounted makes decode write
    past the KV allocation on long generations, which XLA *clamps* (silent
    cache corruption, no error).
    """
    kw = {}
    if cfg.frontend == "patches":
        kw["prefix_embeds"] = jnp.zeros(
            (batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.frontend == "frames":
        kw["enc_frames"] = jnp.zeros(
            (batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    prefix_len = sum(v.shape[1] for k, v in kw.items() if k == "prefix_embeds")
    return kw, prefix_len


def serve(cfg, *, batch: int, prompt_len: int, gen: int, temperature: float = 0.0,
          seed: int = 0):
    params = init_params(jax.random.key(seed), cfg)
    prompts = jax.random.randint(
        jax.random.key(seed + 1), (batch, prompt_len), 0, cfg.vocab)
    kw, prefix_len = frontend_inputs(cfg, batch)

    max_len = prompt_len + prefix_len + gen
    t0 = time.perf_counter()
    logits, state = jax.jit(
        lambda p, t: prefill(p, cfg, t, max_len=max_len, **kw)
    )(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # the decode loop writes KV at positions [pos, pos + gen - 2]; if the
    # prefix accounting above ever disagrees with prefill's s_total, fail
    # loudly here instead of letting XLA clamp the cache writes
    pos0 = int(state["pos"])
    if pos0 != prompt_len + prefix_len or pos0 + gen - 1 > max_len:
        raise AssertionError(
            f"KV allocation mismatch: prefill starts decode at pos {pos0} "
            f"with {gen - 1} steps but max_len={max_len}")

    step = jax.jit(make_serve_step(cfg, temperature=temperature),
                   donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, state = step(params, state, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(gen - 1, 1),
        "tokens_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tokens, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                          gen=args.gen, temperature=args.temperature)
    print(f"[serve] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"generated {tokens.shape} tokens")
    print(f"[serve] prefill {stats['prefill_s']:.3f}s, "
          f"decode {1e3 * stats['decode_s_per_token']:.1f}ms/tok, "
          f"{stats['tokens_per_s']:.1f} tok/s")
    print(f"[serve] sample row: {np.asarray(tokens[0])[:16]}")


if __name__ == "__main__":
    main()
