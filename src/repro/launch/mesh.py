"""Production mesh builders (dry-run target: TPU v5e pods).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) -- the ``pod``
axis is pure data parallelism across ICI/DCN pod boundaries.

Functions (not module constants) so importing never touches jax device state.
Mesh construction goes through ``repro.utils.jax_compat.make_mesh`` so the
``axis_types`` kwarg (absent before jax 0.5) is only passed where it exists.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.utils.jax_compat import make_mesh

__all__ = ["make_pod_data_mesh", "make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} -- the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    return make_mesh(shape, axes, devices=devices)


def make_pod_data_mesh(n_pods: int, n_data: int | None = None):
    """2-D ``(pod, data)`` fleet mesh: ``pod`` spans DCN pod boundaries, ``data``
    the chips within a pod.  ``n_data=None`` spreads every local device over
    the pods (``device_count() / n_pods`` each).  ``n_pods=1`` degenerates to
    the flat data mesh, so callers can use one code path for both layouts.
    """
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    if n_data is None:
        total = jax.device_count()
        if total % n_pods:
            raise ValueError(
                f"{total} devices do not divide over {n_pods} pods; "
                "pass n_data explicitly"
            )
        n_data = total // n_pods
    if n_data < 1:
        raise ValueError(
            f"n_data must be >= 1, got {n_data} "
            f"(more pods ({n_pods}) than devices?)"
        )
    n = n_pods * n_data
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh (pod={n_pods}, data={n_data}) needs {n} devices, "
            f"have {len(devices)}"
        )
    return make_mesh((n_pods, n_data), ("pod", "data"), devices=devices)


def make_test_mesh(shape: Sequence[int] = (2, 2), axes: Sequence[str] = ("data", "model")):
    """Small mesh for unit tests (requires enough local/fake devices)."""
    n = 1
    for s in shape:
        n *= s
    return make_mesh(tuple(shape), tuple(axes), devices=jax.devices()[:n])
