"""Bounded ring-buffer span tracer emitting Chrome trace-event JSON.

The tracer records complete spans (``ph: "X"``) and instant events
(``ph: "i"``) into a fixed-capacity ring; when full, the oldest events
are overwritten and ``dropped`` counts what fell off.  Recording is a
tuple store into a preallocated list -- no allocation growth, no device
syncs, safe inside ``# symlint: hot-path`` functions.

``chrome_trace()`` renders the ring as a Chrome trace-event document
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
that loads directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  Timestamps are microseconds, durations
microseconds, per the spec.

The ``annotate`` helper bridges to ``jax.profiler`` trace annotations so
spans also show up inside XLA device profiles; the actual jax surface is
feature-detected in ``repro.utils.jax_compat`` (SL001 policy) and this
module degrades to ``nullcontext`` when jax is absent.
"""

from __future__ import annotations

import json
import time
from contextlib import nullcontext
from typing import Dict, List, Optional, Tuple

__all__ = ["SpanTracer", "annotate"]

# Event record layout: (name, phase, ts_ns, dur_ns, args)
_Event = Tuple[str, str, int, int, Optional[Dict[str, object]]]

_NULL_CTX = nullcontext()


def annotate(name: str):
    """Context manager adding ``name`` to the active jax device profile.

    Routed through ``jax_compat.trace_annotation`` (never spells the
    ``jax.profiler`` surface here); degrades to a no-op context when jax
    is unavailable.  Negligible cost when no profiler session is active,
    but still a context-manager entry per call -- keep it off by default
    in serving loops and enable via ``Observability(jax_annotate=True)``.
    """
    try:
        from repro.utils.jax_compat import trace_annotation
    except Exception:
        return _NULL_CTX
    return trace_annotation(name)


class SpanTracer:
    """Fixed-capacity ring of trace events.

    Hot-path usage is the two-call pattern::

        t0 = time.perf_counter_ns()
        ...work...
        tracer.add("stream.dispatch", t0)

    which costs one clock read plus a list store.  ``span()`` offers a
    context-manager form for non-hot call sites.
    """

    __slots__ = ("capacity", "enabled", "dropped", "_ring", "_n", "_pid", "_t0_ns")

    def __init__(self, capacity: int = 4096, enabled: bool = True, pid: int = 0):
        if capacity < 1:
            capacity = 1
        self.capacity = capacity
        self.enabled = bool(enabled)
        self.dropped = 0
        self._ring: List[Optional[_Event]] = [None] * capacity
        self._n = 0  # total events ever recorded
        self._pid = pid
        # trace epoch: event timestamps are reported relative to tracer
        # creation so Perfetto opens at t=0 rather than host-uptime
        self._t0_ns = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------

    def _push(self, ev: _Event) -> None:
        i = self._n
        slot = i % self.capacity
        if i >= self.capacity:
            self.dropped += 1
        self._ring[slot] = ev
        self._n = i + 1

    def add(self, name: str, t0_ns: int, args: Optional[Dict[str, object]] = None) -> None:
        """Record a complete span from ``t0_ns`` (perf_counter_ns) to now."""
        if not self.enabled:
            return
        now = time.perf_counter_ns()
        self._push((name, "X", t0_ns, now - t0_ns, args))

    def add_span(self, name: str, t0_ns: int, t1_ns: int,
                 args: Optional[Dict[str, object]] = None) -> None:
        """Record a complete span with both endpoints already measured."""
        if not self.enabled:
            return
        self._push((name, "X", t0_ns, t1_ns - t0_ns, args))

    def instant(self, name: str, args: Optional[Dict[str, object]] = None) -> None:
        """Record a zero-duration marker (autoscale grow/shrink, retrace...)."""
        if not self.enabled:
            return
        self._push((name, "i", time.perf_counter_ns(), 0, args))

    def span(self, name: str, args: Optional[Dict[str, object]] = None):
        """Context-manager form for non-hot call sites."""
        return _SpanCtx(self, name, args)

    # -- reading ------------------------------------------------------------

    @property
    def recorded(self) -> int:
        """Total events ever recorded (including those since overwritten)."""
        return self._n

    def events(self) -> List[_Event]:
        """Retained events, oldest first."""
        n = self._n
        cap = self.capacity
        if n <= cap:
            return [e for e in self._ring[:n] if e is not None]
        start = n % cap
        out = self._ring[start:] + self._ring[:start]
        return [e for e in out if e is not None]

    def chrome_trace(self, tid: int = 0) -> Dict[str, object]:
        """Render retained events as a Chrome trace-event JSON document."""
        t0 = self._t0_ns
        trace_events: List[Dict[str, object]] = []
        for name, ph, ts_ns, dur_ns, args in self.events():
            ev: Dict[str, object] = {
                "name": name,
                "ph": ph,
                "ts": (ts_ns - t0) / 1e3,  # microseconds
                "pid": self._pid,
                "tid": tid,
            }
            if ph == "X":
                ev["dur"] = dur_ns / 1e3
            if ph == "i":
                ev["s"] = "t"  # thread-scoped instant
            if args:
                ev["args"] = args
            trace_events.append(ev)
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path: str, tid: int = 0) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(tid=tid), f)


class _SpanCtx:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: SpanTracer, name: str, args):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self._tracer.add(self._name, self._t0, self._args)
        return False
