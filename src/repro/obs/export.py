"""Prometheus text exposition and a stdlib HTTP exporter for the recorder.

Three endpoints, all served off a daemon thread so the serving loop is
never blocked by a scrape:

- ``/metrics``       Prometheus text exposition (format 0.0.4).  Histogram
                     families emit sparse cumulative ``_bucket{le=...}``
                     lines plus ``_sum``/``_count``, and derived
                     ``<name>_p50``/``_p99``/``_p999`` gauge families so
                     quantiles are grep-able without a PromQL engine.
- ``/metrics.json``  The registry snapshot (same dict that is merged into
                     ``StreamServer.report`` / ``fleet_report``).
- ``/trace``         The span ring as Chrome trace-event JSON (load in
                     Perfetto).

No third-party client library: the exposition writer and HTTP server are
stdlib-only, matching the repo's no-new-deps policy.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import MetricsRegistry, bucket_bounds

__all__ = ["PROM_CONTENT_TYPE", "prometheus_text", "ObsHTTPServer", "start_exporter"]

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_QUANTILE_GAUGES = (("p50", 0.5), ("p99", 0.99), ("p999", 0.999))


def _fmt(v: float) -> str:
    """Prometheus sample value: shortest float that round-trips enough."""
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels(inst, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in sorted(getattr(inst, "labels", {}).items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format 0.0.4."""
    lines = []
    derived = []  # quantile gauge families, appended after the real families
    for name, insts in registry.families():
        kind = insts[0].kind
        help_text = next((i.help for i in insts if i.help), "")
        if help_text:
            lines.append(f"# HELP {name} {help_text}")
        if kind == "counter":
            lines.append(f"# TYPE {name} counter")
            for inst in insts:
                lines.append(f"{name}{_labels(inst)} {_fmt(inst.read())}")
        elif kind == "gauge":
            lines.append(f"# TYPE {name} gauge")
            for inst in insts:
                lines.append(f"{name}{_labels(inst)} {_fmt(inst.read())}")
        elif kind == "histogram":
            lines.append(f"# TYPE {name} histogram")
            for inst in insts:
                s = inst.scale
                cum = 0
                for idx, c in inst.nonzero_buckets():
                    cum += c
                    _, hi = bucket_bounds(idx)
                    le = 'le="%.9g"' % (hi * s)
                    lines.append(f"{name}_bucket{_labels(inst, le)} {cum}")
                inf = 'le="+Inf"'
                lines.append(f"{name}_bucket{_labels(inst, inf)} {inst.count}")
                lines.append(f"{name}_sum{_labels(inst)} {_fmt(inst.total * s)}")
                lines.append(f"{name}_count{_labels(inst)} {inst.count}")
                for suffix, q in _QUANTILE_GAUGES:
                    derived.append((f"{name}_{suffix}", _labels(inst),
                                    inst.quantile(q) * s))
    for qname, lbl, val in derived:
        lines.append(f"# TYPE {qname} gauge")
        lines.append(f"{qname}{lbl} {_fmt(val)}")
    return "\n".join(lines) + "\n"


class ObsHTTPServer:
    """Daemon-thread HTTP exporter bound to (host, port); port 0 = ephemeral."""

    def __init__(self, obs, host: str = "127.0.0.1", port: int = 0):
        self._obs = obs
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence per-request stderr spam
                pass

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                if path in ("/metrics", "/"):
                    body = prometheus_text(outer._obs.metrics).encode()
                    ctype = PROM_CONTENT_TYPE
                elif path == "/metrics.json":
                    body = json.dumps(outer._obs.snapshot()).encode()
                    ctype = "application/json"
                elif path == "/trace":
                    body = json.dumps(outer._obs.tracer.chrome_trace()).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-exporter", daemon=True)
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


def start_exporter(obs, port: int, host: str = "127.0.0.1") -> Optional[ObsHTTPServer]:
    """Start the exporter if ``port`` is set; ``None`` disables it."""
    if port is None:
        return None
    return ObsHTTPServer(obs, host=host, port=port)
