"""Process-local metrics: counters, gauges, and log-bucketed histograms.

Design constraints (the flight-recorder contract):

- **Hot-path safe.** Recording a histogram sample is one integer
  ``bit_length`` bucket computation plus three int adds on a plain
  Python object -- no jax import, no device sync, no allocation beyond
  the fixed bucket list created at registration time.  The serving loop
  (``StreamServer.ingest_many``, ``TransportServer._tick``) can record
  on every round and stay SL004/SL006-clean, because nothing here ever
  touches a device value.
- **Scrape-anytime.** The Prometheus exporter thread reads instruments
  concurrently with the serving loop.  All mutations are single-field
  int/float writes (GIL-atomic enough for monitoring), so scrapes never
  block the hot path and never see torn multi-field invariants worse
  than one sample of skew.
- **Bucket-derived quantiles.** Histograms use base-2 log buckets with
  ``_SUB_BITS`` extra resolution bits per octave (4 sub-buckets ->
  bucket width <= 25% of the value), so p50/p99/p999 read off the
  cumulative bucket walk with bounded relative error and zero per-sample
  cost beyond the increment.

Callback instruments (``counter_fn`` / ``gauge_fn``) read an existing
host-side total (e.g. ``StreamServer.totals``) lazily at scrape time --
the cheapest possible instrumentation: zero added hot-path work.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "bucket_index",
    "bucket_bounds",
    "N_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "NullInstrument",
    "NULL_INSTRUMENT",
    "MetricsRegistry",
]

# ---------------------------------------------------------------------------
# log-bucket scheme
# ---------------------------------------------------------------------------

# Sub-bucket resolution bits: each power-of-two octave [2^e, 2^(e+1)) is
# split into 2**_SUB_BITS equal sub-buckets, so a bucket spans at most
# 2^-_SUB_BITS = 25% of its lower bound.  Values 0..3 get exact unit
# buckets (they are below the first splittable octave).
_SUB_BITS = 2
_SUBS = 1 << _SUB_BITS

# Enough buckets to cover any 64-bit nanosecond count (~584 years).
N_BUCKETS = _SUBS + ((64 - _SUB_BITS) << _SUB_BITS)


def bucket_index(value: int) -> int:
    """Map a non-negative int to its log-bucket index (monotone in value)."""
    if value < _SUBS:
        return value if value > 0 else 0
    e = value.bit_length() - 1
    return ((e - _SUB_BITS) << _SUB_BITS) + ((value >> (e - _SUB_BITS)) & (_SUBS - 1)) + _SUBS


def bucket_bounds(index: int) -> Tuple[int, int]:
    """Half-open [lo, hi) value range of bucket ``index``."""
    if index < _SUBS:
        return index, index + 1
    j = index - _SUBS
    e = (j >> _SUB_BITS) + _SUB_BITS
    sub = j & (_SUBS - 1)
    width = 1 << (e - _SUB_BITS)
    lo = (1 << e) + sub * width
    return lo, lo + width


# Exposition scale per declared unit: sample values are stored in the
# instrument's native unit and divided by this on export.
UNIT_SCALE = {"ns": 1e-9, "us": 1e-6, "ms": 1e-3, "s": 1.0, "": 1.0, "bytes": 1.0}


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically increasing value.  Name it ``*_total`` (Prometheus idiom)."""

    __slots__ = ("name", "help", "labels", "value", "_fn")
    kind = "counter"

    def __init__(self, name: str, help: str = "", labels: Optional[Dict[str, str]] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.value = 0.0
        self._fn = fn

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def read(self) -> float:
        return float(self._fn()) if self._fn is not None else self.value


class Gauge:
    """Point-in-time value (can go up and down)."""

    __slots__ = ("name", "help", "labels", "value", "_fn")
    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels: Optional[Dict[str, str]] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def read(self) -> float:
        return float(self._fn()) if self._fn is not None else self.value


class Histogram:
    """Log-bucketed histogram of non-negative integer samples.

    Samples are recorded in the native ``unit`` (default nanoseconds) and
    scaled to base units (seconds) on export.  ``observe`` is the hot-path
    entry: bucket index + three int adds, nothing else.
    """

    __slots__ = ("name", "help", "labels", "unit", "buckets", "count", "total")
    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: Optional[Dict[str, str]] = None,
                 unit: str = "ns"):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.unit = unit
        self.buckets: List[int] = [0] * N_BUCKETS
        self.count = 0
        self.total = 0

    def observe(self, value: int) -> None:
        v = int(value)
        if v < 0:
            v = 0
        self.buckets[bucket_index(v)] += 1
        self.count += 1
        self.total += v

    def observe_n(self, value: int, n: int) -> None:
        """Record ``n`` samples of the same ``value`` (one bucket update)."""
        if n <= 0:
            return
        v = int(value)
        if v < 0:
            v = 0
        self.buckets[bucket_index(v)] += n
        self.count += n
        self.total += v * n

    @property
    def scale(self) -> float:
        return UNIT_SCALE.get(self.unit, 1.0)

    @property
    def mean(self) -> float:
        return (self.total / self.count) if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-midpoint estimate of the ``q`` quantile, in native units.

        Relative error is bounded by half the bucket width (<= 12.5%) plus
        within-bucket rank placement; good enough for p50/p99/p999 SLO
        tracking without storing samples.
        """
        if self.count <= 0:
            return 0.0
        target = q * self.count
        if target < 1.0:
            target = 1.0
        cum = 0
        last = 0
        for i, c in enumerate(self.buckets):
            if not c:
                continue
            cum += c
            last = i
            if cum >= target:
                lo, hi = bucket_bounds(i)
                return (lo + hi) / 2.0
        lo, hi = bucket_bounds(last)
        return (lo + hi) / 2.0

    def quantiles(self, qs: Sequence[float] = (0.5, 0.99, 0.999)) -> Tuple[float, ...]:
        return tuple(self.quantile(q) for q in qs)

    def nonzero_buckets(self) -> Iterable[Tuple[int, int]]:
        """Yield (index, count) for occupied buckets, ascending."""
        for i, c in enumerate(self.buckets):
            if c:
                yield i, c


class NullInstrument:
    """Shared no-op stand-in for every instrument kind when obs is disabled.

    All mutators are empty; all readers return 0.  One instance serves the
    whole process, so a disabled registry allocates nothing per metric.
    """

    __slots__ = ()
    kind = "null"
    name = ""
    unit = ""
    count = 0
    total = 0
    mean = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, value: int) -> None:
        pass

    def observe_n(self, value: int, n: int) -> None:
        pass

    def read(self) -> float:
        return 0.0

    def quantile(self, q: float) -> float:
        return 0.0

    def quantiles(self, qs: Sequence[float] = (0.5, 0.99, 0.999)) -> Tuple[float, ...]:
        return tuple(0.0 for _ in qs)


NULL_INSTRUMENT = NullInstrument()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _key(name: str, labels: Optional[Dict[str, str]]) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
    return name, tuple(sorted((labels or {}).items()))


class MetricsRegistry:
    """Ordered collection of instruments, keyed by (name, labels).

    Value instruments (``counter``/``gauge``/``histogram``) are
    get-or-create: asking twice for the same (name, labels) returns the
    same object, so layered components (stream server + transport front
    end) can share one registry.  Callback instruments (``counter_fn`` /
    ``gauge_fn``) bind a closure and therefore refuse duplicates -- two
    owners silently sharing one callback series would misreport.

    A disabled registry hands out the shared ``NULL_INSTRUMENT`` and
    collects nothing.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._instruments: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}

    # -- registration -------------------------------------------------------

    def _get_or_create(self, cls, name, help, labels, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        k = _key(name, labels)
        inst = self._instruments.get(k)
        if inst is not None:
            if inst.kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}, not {cls.kind}")
            return inst
        inst = cls(name, help, labels, **kw)
        self._instruments[k] = inst
        return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None, unit: str = "ns") -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, unit=unit)

    def _register_fn(self, cls, name, help, labels, fn):
        if not self.enabled:
            return NULL_INSTRUMENT
        k = _key(name, labels)
        if k in self._instruments:
            raise ValueError(f"callback metric {name!r}{dict(k[1])!r} already registered")
        inst = cls(name, help, labels, fn=fn)
        self._instruments[k] = inst
        return inst

    def counter_fn(self, name: str, help: str, fn: Callable[[], float],
                   labels: Optional[Dict[str, str]] = None) -> Counter:
        """Counter whose value is read from ``fn()`` at scrape time."""
        return self._register_fn(Counter, name, help, labels, fn)

    def gauge_fn(self, name: str, help: str, fn: Callable[[], float],
                 labels: Optional[Dict[str, str]] = None) -> Gauge:
        """Gauge whose value is read from ``fn()`` at scrape time."""
        return self._register_fn(Gauge, name, help, labels, fn)

    # -- collection ---------------------------------------------------------

    def instruments(self) -> List[object]:
        return list(self._instruments.values())

    def families(self) -> List[Tuple[str, List[object]]]:
        """Instruments grouped by metric name, registration-ordered."""
        fams: Dict[str, List[object]] = {}
        for inst in self._instruments.values():
            fams.setdefault(inst.name, []).append(inst)
        return list(fams.items())

    def snapshot(self) -> Dict[str, object]:
        """JSON-able dump: counters/gauges by labeled name, histogram digests.

        Histogram values are converted to base units (seconds for ``ns``).
        """
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, float]] = {}
        for inst in self._instruments.values():
            label = inst.name
            if getattr(inst, "labels", None):
                inner = ",".join(f"{k}={v}" for k, v in sorted(inst.labels.items()))
                label = f"{inst.name}{{{inner}}}"
            if inst.kind == "counter":
                counters[label] = inst.read()
            elif inst.kind == "gauge":
                gauges[label] = inst.read()
            elif inst.kind == "histogram":
                s = inst.scale
                p50, p99, p999 = inst.quantiles()
                hists[label] = {
                    "count": float(inst.count),
                    "sum": inst.total * s,
                    "mean": inst.mean * s,
                    "p50": p50 * s,
                    "p99": p99 * s,
                    "p999": p999 * s,
                }
        return {"counters": counters, "gauges": gauges, "histograms": hists}
