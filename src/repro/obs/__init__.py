"""Flight recorder for the edge pipeline.

One ``Observability`` bundle per serving process: a ``MetricsRegistry``
(counters / gauges / log-bucketed histograms with p50/p99/p999) plus a
``SpanTracer`` (bounded ring of Chrome trace events).  The stream,
transport, and fleet layers all record into the same bundle, so one
``/metrics`` scrape or ``/trace`` download covers the whole pipeline.

The recorder is hot-path safe by construction -- recording is host-side
integer arithmetic, never a device sync -- and cheap enough to be on by
default (`benchmarks/check_bench.py` gates the instrumented-vs-disabled
resident-tick overhead at <= 5%).  Pass ``obs=False`` to a server to get
shared null instruments with zero recording cost.
"""

from __future__ import annotations

from typing import Dict, Optional, Union

from repro.obs.metrics import (
    NULL_INSTRUMENT,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullInstrument,
    bucket_bounds,
    bucket_index,
)
from repro.obs.tracing import SpanTracer, annotate

__all__ = [
    "Observability",
    "as_obs",
    "MetricsRegistry",
    "SpanTracer",
    "Counter",
    "Gauge",
    "Histogram",
    "NullInstrument",
    "NULL_INSTRUMENT",
    "bucket_index",
    "bucket_bounds",
    "annotate",
]


class Observability:
    """Metrics registry + span tracer, enabled or fully inert as a unit."""

    def __init__(self, enabled: bool = True, trace_capacity: int = 4096,
                 jax_annotate: bool = False):
        self.enabled = bool(enabled)
        self.metrics = MetricsRegistry(enabled=self.enabled)
        self.tracer = SpanTracer(capacity=trace_capacity, enabled=self.enabled)
        # opt-in: also wrap device dispatch in jax profiler annotations so
        # spans land inside XLA device profiles (routed via jax_compat)
        self.jax_annotate = bool(jax_annotate) and self.enabled

    def snapshot(self) -> Dict[str, object]:
        """JSON-able state for merging into server/fleet reports."""
        snap = self.metrics.snapshot()
        snap["spans_recorded"] = float(self.tracer.recorded)
        snap["spans_dropped"] = float(self.tracer.dropped)
        return snap


_DISABLED: Optional[Observability] = None


def disabled() -> Observability:
    """The shared inert bundle (no per-call state, safe to share)."""
    global _DISABLED
    if _DISABLED is None:
        _DISABLED = Observability(enabled=False)
    return _DISABLED


def as_obs(obs: Union[None, bool, Observability]) -> Observability:
    """Normalize a server's ``obs=`` argument.

    ``None`` / ``True`` -> a fresh enabled bundle (per-server registry, so
    two servers never collide on callback metrics); ``False`` -> the shared
    disabled bundle; an ``Observability`` instance passes through.
    """
    if isinstance(obs, Observability):
        return obs
    if obs is False:
        return disabled()
    return Observability()
