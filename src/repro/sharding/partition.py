"""Logical-axis -> mesh-axis resolution with divisibility fallback.

Every logical name carries an ordered candidate list of mesh axes (or axis
tuples).  Resolution picks the first candidate whose axes all exist in the
mesh, whose product divides the tensor dim, and which is disjoint from axes
already used elsewhere in the same spec -- otherwise the dim is replicated.
This is what makes one rule table serve every assigned arch: paligemma's 8 q
heads fall back from ``heads``(16-way) to ``head_dim``; nemotron's 8 kv heads
fall back to replication; olmoe's 64 experts take true expert parallelism
while mixtral's 8 fall back to tensor-parallel d_ff.

Param-tree specs are resolved from leaf *path names* (see ``_PARAM_RULES``);
model params use stable key names precisely so this table can match them.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["logical_to_spec", "param_specs", "spec_for_path", "LOGICAL_RULES"]

# Ordered candidates per logical axis.  Entries are tuples of mesh axes that
# shard the dim jointly (e.g. batch over pod x data).
LOGICAL_RULES: dict[str, Sequence[Tuple[str, ...]]] = {
    "batch": [("pod", "data"), ("data",)],
    "fsdp": [("data",)],                 # param "long" dim: FSDP sharding
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [("model",)],
    "qkv_fused": [("model",)],           # fused H*hd dim -- always divisible
    "mlp": [("model",)],
    "experts": [("model",)],
    # MoE expert weights: shard the *non-contracting* dims (experts x d_ff)
    # so the contraction dim (d_model) never needs an FSDP weight gather --
    # the full-size f32 weight-grad that gather produces in backward was the
    # dominant HBM buffer for jamba/mixtral (dry-run iteration log).
    "moe_d": [("model",)],
    # matching activation shardings inside moe_apply (expert buffers are
    # token-replicated after the dispatch all-reduce, so f-over-data is free)
    "experts_act": [("model",)],
    "moe_f_act": [("data",)],
    "ssm_inner": [("model",)],
    "seq": [],                           # sequence stays unsharded (no CP here)
    # sequence parallelism at block boundaries: the scan-over-blocks carry is
    # the dominant live tensor under remat; sharding its seq dim over `model`
    # divides boundary storage by the TP degree (GSPMD re-gathers inside the
    # block where attention needs full sequence)
    "seq_block": [("model",)],
    "kv_seq": [("model",)],              # decode: flash-decoding style split
    "embed": [],                         # activation d_model: unsharded
    "stack": [],                         # scan-over-blocks leading axis
}


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve(
    logical: Optional[str], dim: int, mesh: Mesh, used: set[str],
    exclude: Tuple[str, ...] = (),
) -> Optional[Tuple[str, ...]]:
    if logical is None:
        return None
    sizes = _mesh_sizes(mesh)
    for cand in LOGICAL_RULES.get(logical, []):
        if not all(a in sizes for a in cand):
            continue
        if any(a in used or a in exclude for a in cand):
            continue
        prod = 1
        for a in cand:
            prod *= sizes[a]
        if prod and dim % prod == 0:
            used.update(cand)
            return cand
    # partial fallback: "batch over (pod, data)" should still use data alone
    # when pod is excluded/absent
    for cand in LOGICAL_RULES.get(logical, []):
        sub = tuple(a for a in cand if a in sizes and a not in used and a not in exclude)
        if not sub or sub == cand:
            continue
        prod = 1
        for a in sub:
            prod *= sizes[a]
        if prod and dim % prod == 0:
            used.update(sub)
            return sub
    return None


def logical_to_spec(
    logical: Tuple[Optional[str], ...], shape: Tuple[int, ...], mesh: Mesh,
    exclude: Tuple[str, ...] = (),
) -> P:
    """Resolve a tuple of logical names against a concrete shape + mesh."""
    assert len(logical) == len(shape), (logical, shape)
    used: set[str] = set()
    parts = []
    for name, dim in zip(logical, shape):
        axes = _resolve(name, dim, mesh, used, exclude)
        if axes is None:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return P(*parts)


# ---------------------------------------------------------------------------
# Param-tree rules: leaf path regex -> logical axes (rightmost dims; leading
# unmatched dims -- e.g. the scan-over-blocks stack axis -- replicate).
# ---------------------------------------------------------------------------

_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # embeddings / unembedding
    (r"(^|/)embed$", ("vocab", "fsdp")),
    (r"(^|/)lm_head$", ("fsdp", "vocab")),
    # attention (fused head dims stay divisible even when H isn't)
    (r"(^|/)wq$", ("fsdp", "qkv_fused")),
    (r"(^|/)wk$", ("fsdp", "qkv_fused")),
    (r"(^|/)wv$", ("fsdp", "qkv_fused")),
    (r"(^|/)wo$", ("qkv_fused", "fsdp")),
    (r"(^|/)b[qkv]$", ("qkv_fused",)),
    # dense mlp
    (r"(^|/)wi$", ("fsdp", "mlp")),
    (r"(^|/)wo_mlp$", ("mlp", "fsdp")),
    # moe
    (r"(^|/)router$", (None, None)),
    # (e -> model | d_model -> model when e indivisible | d_ff -> data):
    # contractions hit only replicated-or-activation dims; weight grads stay
    # sharded and the h tensor keeps one sharding across both einsums.
    (r"(^|/)wi_moe$", ("experts", "moe_d", "fsdp")),
    (r"(^|/)wo_moe$", ("experts", "fsdp", "moe_d")),
    # mamba
    (r"(^|/)in_proj$", ("fsdp", "ssm_inner")),
    (r"(^|/)out_proj$", ("ssm_inner", "fsdp")),
    (r"(^|/)x_proj$", ("ssm_inner", None)),
    (r"(^|/)dt_proj$", (None, "ssm_inner")),
    (r"(^|/)(a_log|d_skip|dt_bias|conv_w|conv_b)$", None),  # replicate
    # xlstm
    (r"(^|/)up$", ("fsdp", "ssm_inner")),
    (r"(^|/)down$", ("ssm_inner", "fsdp")),
    (r"(^|/)w[qkv]_m$", ("ssm_inner", None)),
    (r"(^|/)(wi_g|wf_g|bi|bf|b)$", None),
    (r"(^|/)wx$", ("fsdp", "mlp")),
    (r"(^|/)r$", None),
    (r"(^|/)ffn_up$", ("fsdp", "mlp")),
    (r"(^|/)ffn_down$", ("mlp", "fsdp")),
    # norms & leftovers
    (r"(^|/)(ln\w*|scale|norm\w*)$", None),
)


def spec_for_path(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one param leaf; unmatched paths replicate."""
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            if logical is None:
                return P()
            # right-align logical axes onto the trailing dims (stacked layers
            # carry a leading scan axis)
            pad = (None,) * (len(shape) - len(logical))
            return logical_to_spec(pad + tuple(logical), shape, mesh)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Mesh):
    """Tree of PartitionSpec matching a param tree (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(_path_str(path), leaf.shape, mesh), params
    )
