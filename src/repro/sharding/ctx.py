"""Ambient mesh context for activation sharding constraints.

Model code calls ``constrain(x, "batch", None, "heads")`` with *logical* axis
names; if a mesh context is active the names resolve to mesh axes (with
divisibility fallback) and a ``with_sharding_constraint`` is applied, otherwise
it is a no-op -- so the same model code runs on 1 CPU device (smoke tests) and
on the 512-way production mesh (dry-run) unchanged.

``exclude`` removes mesh axes from resolution -- used by the compressed train
step, where the ``pod`` axis is shard_map-manual and must not appear in
constraints issued inside the body.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

_CTX: contextvars.ContextVar[Optional[Tuple[Mesh, Tuple[str, ...]]]] = (
    contextvars.ContextVar("repro_mesh_ctx", default=None)
)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, exclude: Tuple[str, ...] = (),
                  disable: Tuple[str, ...] = ()):
    """``exclude``: mesh axes constraints may not touch (shard_map-manual).
    ``disable``: *logical* names to no-op -- e.g. ``seq_block`` turns off
    sequence parallelism (per-arch perf lever: SP saves scan-boundary memory
    but forces full-size weight gathers/grads -- net negative for jamba,
    positive for deep dense stacks; see EXPERIMENTS.md Sec. Perf)."""
    token = _CTX.set((mesh, tuple(exclude), tuple(disable)))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return ctx[0] if ctx else None


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical-axis sharding constraint if a mesh context is active."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, exclude, disable = ctx
    from repro.sharding.partition import logical_to_spec

    logical = tuple(None if l in disable else l for l in logical)
    spec = logical_to_spec(logical, x.shape, mesh, exclude=exclude)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
