"""Sharding: logical-axis rules -> PartitionSpecs with divisibility fallback.

``partition`` maps param-tree paths and logical activation axes onto mesh
axes (t5x/MaxText style); ``ctx`` provides the ambient-mesh constraint helper
used inside model code.
"""
from repro.sharding.ctx import constrain, use_mesh_rules, current_mesh
from repro.sharding.partition import (
    logical_to_spec,
    param_specs,
    spec_for_path,
)

__all__ = [
    "constrain", "use_mesh_rules", "current_mesh",
    "logical_to_spec", "param_specs", "spec_for_path",
]
