"""Pallas TPU kernel: fused k-means assign + cluster statistics.

One Lloyd half-step for a *batch of independent clustering problems* (SymED
receivers each own one): pairwise squared distances via the MXU-friendly
expansion ``|x|^2 - 2 x.c^T + |c|^2``, masked argmin, and the per-cluster
(sum, count) statistics needed for the center update -- all fused so the
(N, K) distance matrix never leaves VMEM.

Layout: grid = (streams, N tiles).  Centers for the current stream stay
resident; partial sums/counts accumulate directly in the output block (its
index map is constant over the N-tile axis, so Pallas keeps it in VMEM and
writes back once).  Feature dim D is padded to the 128-lane tile by the
wrapper; SymED's piece space is D=2 but the kernel is written for general D
(the benchmark sweeps D to show MXU utilization).

This is the half-step the resident service's fused table digitize runs
once per Lloyd iteration across the whole slot table
(``core.digitize.masked_kmeans_table`` with ``use_kernel=True``, dispatched
through ``kernels.ops.kmeans_assign``).  Contract note: the kernel zeroes
the labels of masked-out pieces while the jnp reference path leaves the
argmin there, so the kernel path is allclose-but-not-bitwise -- which is
why ``StreamServer`` defaults ``use_kernel`` to off on CPU, where the
bitwise delta-equivalence battery runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.jax_compat import tpu_compiler_params

__all__ = ["kmeans_assign_pallas"]

_BIG = 1e30  # plain Python float: jnp constants would be captured by the kernel


def _kernel(x_ref, m_ref, c_ref, act_ref, lab_ref, sums_ref, cnt_ref):
    jt = pl.program_id(1)
    x = x_ref[0]          # (bn, D)
    m = m_ref[0]          # (bn,)   1.0 valid / 0.0 padded piece
    c = c_ref[0]          # (K, D)
    act = act_ref[0]      # (K,)    1.0 active center / 0.0 inactive

    x2 = jnp.sum(x * x, axis=1, keepdims=True)                     # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                           # (1, K)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                              # (bn, K) MXU
    d = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)
    d = jnp.where(act[None, :] > 0.0, d, _BIG)

    labels = jnp.argmin(d, axis=1).astype(jnp.int32)               # (bn,)
    lab_ref[0] = jnp.where(m > 0.0, labels, 0)

    k = c.shape[0]
    onehot = (
        labels[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    ).astype(jnp.float32) * m[:, None]                             # (bn, K)

    p_sums = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                              # (K, D) MXU
    p_cnt = jnp.sum(onehot, axis=0)                                # (K,)

    @pl.when(jt == 0)
    def _():
        sums_ref[0] = jnp.zeros_like(sums_ref[0])
        cnt_ref[0] = jnp.zeros_like(cnt_ref[0])

    sums_ref[0] += p_sums
    cnt_ref[0] += p_cnt


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(
    x: jax.Array,
    mask: jax.Array,
    centers: jax.Array,
    center_active: jax.Array,
    *,
    block_n: int = 512,
    interpret: bool = False,
):
    """Fused assign + stats for batched k-means.

    Args:
      x: (S, N, D) points.  mask: (S, N) validity.
      centers: (S, K, D).  center_active: (S, K) validity.

    Returns:
      labels (S, N) i32, sums (S, K, D) f32, counts (S, K) f32 --
      ``new_centers = sums / max(counts, 1)`` where counts > 0.
    """
    x = jnp.asarray(x, jnp.float32)
    s, n, d = x.shape
    k = centers.shape[1]

    dp = _round_up(d, 128)
    kp = _round_up(k, 128)
    bn = min(block_n, _round_up(n, 8))
    np_ = _round_up(n, bn)

    x_p = jnp.pad(x, ((0, 0), (0, np_ - n), (0, dp - d)))
    m_p = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    c_p = jnp.pad(jnp.asarray(centers, jnp.float32), ((0, 0), (0, kp - k), (0, dp - d)))
    a_p = jnp.pad(center_active.astype(jnp.float32), ((0, 0), (0, kp - k)))

    grid = (s, np_ // bn)
    labels, sums, counts = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, kp, dp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, kp), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, kp, dp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, kp), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, np_), jnp.int32),
            jax.ShapeDtypeStruct((s, kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((s, kp), jnp.float32),
        ],
        # streams parallel, N tiles sequential (stats accumulate in-place)
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(x_p, m_p, c_p, a_p)
    return labels[:, :n], sums[:, :k, :d], counts[:, :k]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
