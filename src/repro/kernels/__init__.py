"""Pallas TPU kernels for SymED's compute hot spots.

  * ``ewma``   -- blocked EWMA/EWMV linear-recurrence scan (sender, Eq. 1-2)
  * ``kmeans`` -- fused assign+stats Lloyd half-step (receiver, Alg. 3)
  * ``dtw``    -- banded anti-diagonal DTW (evaluation metric)

``ops`` holds the jit'd public wrappers (interpret-mode on CPU); ``ref`` the
pure-jnp oracles the tests assert against.
"""
from repro.kernels import ops, ref
from repro.kernels.dtw import dtw_pallas
from repro.kernels.ewma import ewma_scan_pallas
from repro.kernels.kmeans import kmeans_assign_pallas

__all__ = ["ops", "ref", "dtw_pallas", "ewma_scan_pallas", "kmeans_assign_pallas"]
