"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function matches its kernel bit-for-bit up to float associativity; the
test suite sweeps shapes/dtypes and asserts allclose between the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.metrics import dtw_ref as _dtw_core
from repro.core.normalize import ewm_scan as _ewm_core

__all__ = ["ewma_scan_ref", "kmeans_assign_ref", "dtw_batch_ref"]

_BIG = jnp.float32(1e30)


def ewma_scan_ref(ts: jax.Array, alpha) -> tuple[jax.Array, jax.Array]:
    """Oracle for ``ewma.ewma_scan_pallas``: the paper-faithful sequential scan."""
    return _ewm_core(jnp.asarray(ts, jnp.float32), alpha)


def kmeans_assign_ref(
    x: jax.Array, mask: jax.Array, centers: jax.Array, center_active: jax.Array
):
    """Oracle for ``kmeans.kmeans_assign_pallas``."""
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    d = jnp.sum((x[:, :, None, :] - centers[:, None, :, :]) ** 2, axis=-1)
    d = jnp.where(center_active[:, None, :] > 0, d, _BIG)
    labels = jnp.argmin(d, axis=-1).astype(jnp.int32)
    labels = jnp.where(mask > 0, labels, 0)

    k = centers.shape[1]
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32) * mask[..., None]
    sums = jnp.einsum("snk,snd->skd", onehot, x)
    counts = jnp.sum(onehot, axis=1)
    return labels, sums, counts


def dtw_batch_ref(x: jax.Array, y: jax.Array, band: int | None = None) -> jax.Array:
    """Oracle for ``dtw.dtw_pallas`` (batched equal-length pairs)."""
    return _dtw_core(x, y, band=band)
