"""Pallas TPU kernel: blocked EWMA/EWMV linear-recurrence scan (paper Eq. 1-2).

The sender's normalization is two chained first-order linear recurrences with
constant decay ``a = 1 - alpha``:

    m_j = a*m_{j-1} + alpha*t_j                 (EWMA)
    w_j = a*w_{j-1} + alpha*(t_j - m_j)^2       (EWMV, uses the updated mean)

TPU adaptation (the paper runs this point-by-point in Python on an IoT node):
a Brownian-bridge-style *blocked scan*.  The grid walks (batch tiles ->
sequential time blocks); the carry (m, w) lives in VMEM scratch across time
blocks.  Within a block the recurrence is closed-form-expanded over chunks of
``CHUNK`` steps:

    m_{j} = a^{j+1} m_{-1} + alpha * sum_{i<=j} a^{j-i} t_i
          = a^{j+1} m_{-1} + alpha * a^j * cumsum_i (t_i * a^{-i})

so each chunk is pure vectorized VPU work (cumsum over the lane dim), and the
sequential dependence is only chunk-to-chunk.  ``CHUNK=32`` bounds the
dynamic range of ``a^{-i}`` at ``a^{-31}`` (< 1.1e3 for alpha <= 0.2), keeping
f32 precision; callers wanting alpha > 0.2 should shrink CHUNK.

Initialization matches the paper: m_0 = t_0, w_0 = 1.0 exactly (the first
block's carry is seeded from t_0, and the variance input at j=0 is forced to
``alpha`` so that w_0 = (1-alpha)*1 + alpha = 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.jax_compat import VMEM, MemorySpace, tpu_compiler_params

__all__ = ["ewma_scan_pallas", "CHUNK"]

CHUNK = 32


def _chunked_scan(x, a, y_prev):
    """Vectorized first-order recurrence over a (bb, bt) block.

    y_j = a*y_{j-1} + x_j, carry-in y_prev (bb,). Returns (ys, carry_out).
    """
    bb, bt = x.shape
    n_chunks = bt // CHUNK
    idx = jax.lax.broadcasted_iota(jnp.float32, (1, CHUNK), 1)
    a_pow = a ** idx                    # a^i,  i in [0, CHUNK)
    a_inv = a ** (-idx)                 # a^-i (bounded by design)
    a_next = a ** jnp.float32(CHUNK)    # a^CHUNK

    def chunk(c, carry):
        xs = jax.lax.dynamic_slice(x, (0, c * CHUNK), (bb, CHUNK))
        # y_j = a^{j+1} carry + a^j cumsum(x_i a^{-i})
        z = jnp.cumsum(xs * a_inv, axis=1)
        ys = (a * a_pow) * carry[:, None] + a_pow * z
        return ys, ys[:, -1]

    def body(c, state):
        out, carry = state
        ys, carry = chunk(c, carry)
        out = jax.lax.dynamic_update_slice(out, ys, (0, c * CHUNK))
        return out, carry

    out = jnp.zeros_like(x)
    out, carry = jax.lax.fori_loop(0, n_chunks, body, (out, y_prev))
    del a_next
    return out, carry


def _ewma_kernel(alpha_ref, ts_ref, mean_ref, var_ref, carry_m, carry_w):
    tb = pl.program_id(1)
    alpha = alpha_ref[0]
    a = 1.0 - alpha
    ts = ts_ref[...]
    bb, bt = ts.shape

    # seed the carry at the first time block: m_{-1} = t_0, w_{-1} = 1
    @pl.when(tb == 0)
    def _():
        carry_m[...] = ts[:, 0]
        carry_w[...] = jnp.ones_like(ts[:, 0])

    # ---- EWMA: inputs alpha*t, but step j=0 must yield exactly t_0 --------
    xm = alpha * ts
    is_first = tb == 0
    # at global j=0: a*t_0 + alpha*t_0 = t_0  (carry is t_0) -- already exact.
    means, m_out = _chunked_scan(xm, a, carry_m[...])
    mean_ref[...] = means
    carry_m[...] = m_out

    # ---- EWMV: inputs alpha*(t - m)^2; force w_0 = 1 -----------------------
    xw = alpha * (ts - means) ** 2
    j0 = jax.lax.broadcasted_iota(jnp.int32, xw.shape, 1)
    xw = jnp.where(is_first & (j0 == 0), alpha, xw)
    vars_, w_out = _chunked_scan(xw, a, carry_w[...])
    var_ref[...] = vars_
    carry_w[...] = w_out


@functools.partial(jax.jit, static_argnames=("block_b", "block_t", "interpret"))
def ewma_scan_pallas(
    ts: jax.Array,
    alpha: float | jax.Array,
    *,
    block_b: int = 256,
    block_t: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Blocked EWMA/EWMV over ``ts`` (B, T). Returns (means, vars).

    B is padded to ``block_b`` rows, T to ``block_t`` (both multiples of the
    (8, 128) f32 tile).  Matches ``repro.core.normalize.ewm_scan`` exactly on
    the valid region.
    """
    ts = jnp.asarray(ts, jnp.float32)
    b, t = ts.shape
    bb = min(block_b, _round_up(b, 8))
    bt = min(block_t, _round_up(t, CHUNK))
    bt = _round_up(bt, CHUNK)
    bp, tp = _round_up(b, bb), _round_up(t, bt)
    ts_p = jnp.pad(ts, ((0, bp - b), (0, tp - t)))

    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape((1,))

    grid = (bp // bb, tp // bt)
    means, vars_ = pl.pallas_call(
        _ewma_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=MemorySpace.SMEM),
            pl.BlockSpec((bb, bt), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bt), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bt), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, tp), jnp.float32),
            jax.ShapeDtypeStruct((bp, tp), jnp.float32),
        ],
        scratch_shapes=[
            VMEM((bb,), jnp.float32),
            VMEM((bb,), jnp.float32),
        ],
        # batch tiles parallel, time blocks sequential (carry in scratch)
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(alpha_arr, ts_p)
    return means[:b, :t], vars_[:b, :t]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
