"""Pallas TPU kernel: banded DTW distance (paper's reconstruction-error metric).

Dynamic-programming recurrence

    D[i,j] = (x_i - y_j)^2 + min(D[i-1,j], D[i,j-1], D[i-1,j-1])

evaluated by *anti-diagonal wavefront*: diagonal d holds cells (i, d-i), so the
whole diagonal updates in one vectorized VPU step and only two previous
diagonals are live.  TPU adaptation of the classic GPU wavefront:

  * the i-axis is the 128-lane dimension; a full diagonal is a (bb, N) vreg row,
  * ``y`` is stored *reversed* inside a 3N-wide VMEM buffer so the per-diagonal
    gather ``y[d-i]`` becomes a dynamic lane *slice* (offset 2N-1-d) instead of
    a gather,
  * the d-loop is a ``fori_loop`` with the two trailing diagonals as carries;
    everything stays VMEM-resident, only the final (bb,) distances are written.

Band (Sakoe-Chiba radius) masks cells with |i-j| > r at _BIG, bounding the
useful work to O(N * r) while keeping the dense layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.jax_compat import MemorySpace, tpu_compiler_params

__all__ = ["dtw_pallas"]

_BIG = 1e30  # plain Python float: jnp constants would be captured by the kernel


def _kernel(meta_ref, x_ref, yr_ref, out_ref):
    n_pad = x_ref.shape[1]
    n = meta_ref[0]       # true length (both series)
    r = meta_ref[1]       # band radius

    x = x_ref[...]                       # (bb, Np)
    bb = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (bb, n_pad), 1)

    def step(d, carry):
        prev2, prev = carry
        jj = d - ii
        valid = (ii < n) & (jj >= 0) & (jj < n) & (jnp.abs(ii - jj) <= r)

        # y[d - i] == yrev[(N-1-d) + i] with yrev embedded at offset n_pad
        off = n_pad + (n - 1) - d
        yv = jax.lax.dynamic_slice(yr_ref[...], (0, off), (bb, n_pad))
        cost = (x - yv) ** 2

        shift = lambda a: jnp.concatenate(
            [jnp.full((bb, 1), _BIG, jnp.float32), a[:, :-1]], axis=1
        )
        best = jnp.minimum(jnp.minimum(shift(prev), prev), shift(prev2))
        best = jnp.where((ii == 0) & (jj == 0), 0.0, best)
        cur = jnp.where(valid, cost + best, _BIG)
        return prev, cur

    init = (jnp.full((bb, n_pad), _BIG), jnp.full((bb, n_pad), _BIG))
    _, last = jax.lax.fori_loop(0, 2 * n - 1, step, init)
    # cell (n-1, n-1) lives at lane n-1 of the final diagonal
    total = jax.lax.dynamic_slice(last, (0, n - 1), (bb, 1))[:, 0]
    out_ref[...] = jnp.sqrt(total)


@functools.partial(jax.jit, static_argnames=("band", "block_b", "interpret"))
def dtw_pallas(
    x: jax.Array,
    y: jax.Array,
    band: int | None = None,
    *,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Banded DTW distances for a batch of equal-length pairs.

    Args:
      x, y: (B, N) f32 series.
      band: Sakoe-Chiba radius (None = full DTW).

    Returns (B,) f32 distances (sqrt of accumulated squared cost), matching
    ``repro.core.metrics.dtw_ref`` -- including its band clamp: the effective
    radius is ``max(band, |N - M|)`` so the terminal cell stays reachable
    (with the equal-length pairs this kernel takes, the clamp only guards
    ``band < 0``, but keeping the same formula here preserves ref/Pallas
    parity if the kernel ever grows ragged-pair support).
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    b, n = x.shape
    r = max(int(band), abs(x.shape[1] - y.shape[1])) if band is not None else n

    bb = min(block_b, _round_up(b, 8))
    bp = _round_up(b, bb)
    n_pad = _round_up(n, 128)

    x_p = jnp.pad(x, ((0, bp - b), (0, n_pad - n)))
    # reversed y embedded in a 3*Np buffer at offset Np: yr[:, Np + j] = y[N-1-j]
    y_rev = jnp.pad(y[:, ::-1], ((0, bp - b), (0, n_pad - n)))
    y_buf = jnp.pad(y_rev, ((0, 0), (n_pad, n_pad)))

    meta = jnp.asarray([n, r], jnp.int32)

    out = pl.pallas_call(
        _kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec(memory_space=MemorySpace.SMEM),
            pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((bb, 3 * n_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        compiler_params=tpu_compiler_params("parallel"),
        interpret=interpret,
    )(meta, x_p, y_buf)
    return out[:b]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
