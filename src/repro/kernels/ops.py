"""Public jit'd entry points for the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; on CPU (this
container) they execute under ``interpret=True`` -- the kernel *body* runs in
Python/XLA-CPU, which validates BlockSpec indexing and kernel semantics without
TPU hardware.  ``force_ref=True`` routes to the pure-jnp oracle (used by small
host-side paths where kernel launch overhead would dominate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dtw import dtw_pallas
from repro.kernels.ewma import ewma_scan_pallas
from repro.kernels.kmeans import kmeans_assign_pallas

__all__ = ["ewma_scan", "kmeans_assign", "dtw", "on_cpu"]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ewma_scan(ts: jax.Array, alpha, *, force_ref: bool = False):
    """Batched EWMA/EWMV (B, T) -> (means, vars)."""
    if force_ref:
        return ref.ewma_scan_ref(ts, alpha)
    return ewma_scan_pallas(ts, alpha, interpret=on_cpu())


def kmeans_assign(x, mask, centers, center_active, *, force_ref: bool = False):
    """One fused Lloyd assign+stats step: see ``kmeans_assign_pallas``."""
    if force_ref:
        return ref.kmeans_assign_ref(x, mask, centers, center_active)
    return kmeans_assign_pallas(x, mask, centers, center_active, interpret=on_cpu())


def dtw(x, y, band: int | None = None, *, force_ref: bool = False):
    """Batched banded DTW distances (B, N) x (B, N) -> (B,)."""
    if force_ref:
        return ref.dtw_batch_ref(x, y, band)
    return dtw_pallas(x, y, band, interpret=on_cpu())
