"""Synthetic UCR-like stream generators.

The UCR archive is not redistributable offline, so the benchmarks sample
these seeded families instead -- chosen to cover the archive's qualitative
range used by the paper (Table 1): smooth spectra, quasi-periodic sensors,
device switching (square events), motion random-walks, and ECG-ish bursts.
Each family yields z-scale-ish series; evaluation averages within family then
across families, mirroring the paper's equal-weight protocol.
"""
from __future__ import annotations

import zlib
from typing import Dict, List

import numpy as np

__all__ = ["FAMILIES", "make_dataset", "make_fleet"]


def _grid(n, length):
    return np.linspace(0.0, 1.0, length)[None, :].repeat(n, 0)


def _sensor(rng, n, length):
    """Quasi-periodic sensor (StarLightCurves / CinCECGTorso flavor)."""
    t = _grid(n, length)
    f = rng.uniform(3, 9, (n, 1))
    phase = rng.uniform(0, 2 * np.pi, (n, 1))
    amp2 = rng.uniform(0.1, 0.5, (n, 1))
    x = np.sin(2 * np.pi * f * t + phase) + amp2 * np.sin(4 * np.pi * f * t)
    return x + rng.normal(0, 0.08, x.shape)


def _device(rng, n, length):
    """Switching loads (ACSF1 / HouseTwenty / PLAID flavor)."""
    x = np.zeros((n, length))
    for i in range(n):
        pos = 0
        level = 0.0
        while pos < length:
            dur = int(rng.integers(length // 40 + 2, length // 8 + 4))
            level = rng.choice([0.0, 1.0, 2.0, 3.0]) + rng.normal(0, 0.05)
            x[i, pos: pos + dur] = level
            pos += dur
    return x + rng.normal(0, 0.05, x.shape)


def _motion(rng, n, length):
    """Smoothed random walk (Haptics / InlineSkate flavor)."""
    steps = rng.normal(0, 1.0, (n, length))
    x = np.cumsum(steps, axis=1)
    k = max(length // 100, 3)
    kernel = np.ones(k) / k
    sm = np.stack([np.convolve(r, kernel, mode="same") for r in x])
    return (sm - sm.mean(1, keepdims=True)) / (sm.std(1, keepdims=True) + 1e-9)


def _spectro(rng, n, length):
    """Smooth low-order curves (EthanolLevel / Rock flavor)."""
    t = _grid(n, length)
    c = rng.normal(0, 1, (n, 6))
    x = sum(c[:, k: k + 1] * t ** k for k in range(6))
    x = (x - x.mean(1, keepdims=True)) / (x.std(1, keepdims=True) + 1e-9)
    return x + rng.normal(0, 0.03, x.shape)


def _hemo(rng, n, length):
    """Pulse-train bursts (PigAirwayPressure / ECG flavor)."""
    t = _grid(n, length)
    rate = rng.uniform(8, 16, (n, 1))
    phase = (t * rate) % 1.0
    pulse = np.exp(-((phase - 0.2) ** 2) / 0.004) + 0.4 * np.exp(
        -((phase - 0.5) ** 2) / 0.01
    )
    drift = 0.3 * np.sin(2 * np.pi * t * rng.uniform(0.5, 1.5, (n, 1)))
    return pulse + drift + rng.normal(0, 0.04, pulse.shape)


FAMILIES = {
    "sensor": _sensor,
    "device": _device,
    "motion": _motion,
    "spectro": _spectro,
    "hemo": _hemo,
}


def make_dataset(family: str, n_series: int = 10, length: int = 1500,
                 seed: int = 0) -> np.ndarray:
    """(n_series, length) f32 array for one family.

    Seeding uses a *stable* hash of the family name (``zlib.crc32``):
    Python's builtin ``hash`` is randomized per process (PYTHONHASHSEED), so
    it would silently generate different "seeded" data in every subprocess,
    breaking cross-process reproducibility (e.g. the device-count-invariance
    checks in ``tests/test_fleet.py``).
    """
    rng = np.random.default_rng(seed ^ zlib.crc32(family.encode()) & 0xFFFF)
    return FAMILIES[family](rng, n_series, length).astype(np.float32)


def make_fleet(n_streams: int, length: int, seed: int = 0) -> np.ndarray:
    """Mixed-family fleet slab (n_streams, length) for scale-out runs."""
    rng = np.random.default_rng(seed)
    names = list(FAMILIES)
    per = [n_streams // len(names)] * len(names)
    per[0] += n_streams - sum(per)
    parts: List[np.ndarray] = []
    for name, k in zip(names, per):
        if k:
            parts.append(make_dataset(name, k, length, seed=int(rng.integers(1 << 30))))
    return np.concatenate(parts, axis=0)
