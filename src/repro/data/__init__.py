"""Data substrate: synthetic UCR-like streams, SymED tokenizer, pipeline."""
from repro.data.pipeline import SymbolPipeline, TokenBatcher
from repro.data.synthetic import FAMILIES, make_dataset, make_fleet
from repro.data.tokenizer import SymbolTokenizer

__all__ = [
    "FAMILIES", "make_dataset", "make_fleet", "SymbolTokenizer",
    "SymbolPipeline", "TokenBatcher",
]
