"""Streaming pipeline: raw fleets -> SymED symbols -> packed token batches.

``SymbolPipeline`` runs the batched SymED encoder (vmapped sender+receiver)
over fleet slabs and feeds a background-prefetched ``TokenBatcher`` --
the framework's input path for training sequence models on symbolized
sensor data.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.core.symed import SymEDConfig, symed_batch
from repro.data.synthetic import make_fleet
from repro.data.tokenizer import SymbolTokenizer

__all__ = ["SymbolPipeline", "TokenBatcher"]


class SymbolPipeline:
    """Symbolize fleet slabs on demand."""

    def __init__(self, cfg: SymEDConfig, tokenizer: SymbolTokenizer,
                 stream_len: int = 1024, slab: int = 64, seed: int = 0):
        self.cfg = cfg
        self.tok = tokenizer
        self.stream_len = stream_len
        self.slab = slab
        self.seed = seed

    def slabs(self) -> Iterator[np.ndarray]:
        i = 0
        while True:
            yield make_fleet(self.slab, self.stream_len, seed=self.seed + i)
            i += 1

    def docs(self) -> Iterator[list]:
        key = jax.random.key(self.seed)
        for slab in self.slabs():
            key, sub = jax.random.split(key)
            out = symed_batch(slab, self.cfg, sub, reconstruct=False)
            labels = np.asarray(out["symbols"])
            lens = np.asarray(out["pieces_len"])
            n_pieces = np.asarray(out["n_pieces"])
            for b in range(slab.shape[0]):
                yield self.tok.encode(labels[b], n_pieces[b], lens[b])


class TokenBatcher:
    """Background-prefetched (batch, seq) int32 batches."""

    def __init__(self, pipeline: SymbolPipeline, batch: int, seq_len: int,
                 prefetch: int = 4):
        self.pipeline = pipeline
        self.batch = batch
        self.seq_len = seq_len
        self._q: "queue.Queue[np.ndarray]" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _worker(self):
        rows = []
        for doc in self.pipeline.docs():
            if self._stop.is_set():
                return
            rows.append(doc)
            packed = self.pipeline.tok.pack(rows, self.seq_len)
            if packed.shape[0] >= self.batch:
                self._q.put(packed[: self.batch])
                rows = []

    def __iter__(self):
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
