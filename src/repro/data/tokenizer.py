"""SymED symbol streams as LM token streams.

The paper's promise is analytics *directly on symbols*; here the analytic is
sequence modeling: each SymED cluster id becomes a token, so the model zoo
trains on symbolized sensor fleets.  Vocab = [PAD, BOS, EOS, sep] + k_max
cluster symbols (+ optional length-bucket tokens to keep duration
information, since cluster ids alone drop the len coordinate at generation
time).
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import numpy as np

__all__ = ["SymbolTokenizer"]


class SymbolTokenizer:
    PAD, BOS, EOS, SEP = 0, 1, 2, 3
    _SPECIALS = 4

    def __init__(self, k_max: int = 100, len_buckets: Optional[List[int]] = None):
        self.k_max = k_max
        self.len_buckets = len_buckets or []
        self.vocab_size = self._SPECIALS + k_max + len(self.len_buckets)

    def encode(self, labels: np.ndarray, n_pieces: int,
               lengths: Optional[np.ndarray] = None) -> List[int]:
        out = [self.BOS]
        for i in range(int(n_pieces)):
            out.append(self._SPECIALS + int(labels[i]) % self.k_max)
            if self.len_buckets and lengths is not None:
                out.append(self._len_token(int(lengths[i])))
        out.append(self.EOS)
        return out

    def _len_token(self, length: int) -> int:
        idx = int(np.searchsorted(self.len_buckets, length))
        idx = min(idx, len(self.len_buckets) - 1)
        return self._SPECIALS + self.k_max + idx

    def pack(self, docs: Iterable[List[int]], seq_len: int) -> np.ndarray:
        """Pack encoded docs into (n, seq_len) rows (GPT-style contiguous)."""
        flat: List[int] = []
        for d in docs:
            flat.extend(d)
        n = max(len(flat) // seq_len, 1)
        flat = flat[: n * seq_len]
        if len(flat) < n * seq_len:
            flat.extend([self.PAD] * (n * seq_len - len(flat)))
        return np.asarray(flat, np.int32).reshape(n, seq_len)
