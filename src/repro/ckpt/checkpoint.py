"""Fault-tolerant checkpointing.

Layout per step::

    <dir>/ckpt_<step>/manifest.msgpack   # tree structure, shapes, dtypes,
                                         # mesh + sharding metadata, step,
                                         # compression codec
    <dir>/ckpt_<step>/data.bin           # compressed frames, one per leaf
                                         # (zstd when available, else zlib;
                                         # the manifest records which)

Guarantees:
  * **atomic**: written to ``.tmp-<pid>`` then ``os.rename``d -- a crashed
    writer never corrupts the latest checkpoint;
  * **elastic restore**: leaves are stored unsharded (gathered); restore
    ``jax.device_put``s onto *any* target mesh/sharding, so a job can come
    back on a different pod count (checkpoint resharding);
  * **self-describing**: the manifest carries enough to rebuild the pytree
    without importing model code.

On a real multi-host pod each host would write its addressable shards
(process-sliced zarr-style); the single-process container emulates the
gathered path, and the manifest already records per-leaf sharding specs so
the sharded writer is a drop-in extension (see DESIGN.md).
"""
from __future__ import annotations

import io
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: zstd gives ~2x better ratios, but the wheel may be absent
    import zstandard
except ImportError:
    zstandard = None
import zlib

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_SEP = "/"

# Codec used by *new* checkpoints.  Recorded per-manifest so readers pick the
# right decompressor regardless of which wheels they have; manifests from
# before the flag existed are zstd by construction.
_DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"


def _make_compressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint requests the zstd codec but the zstandard wheel "
                "is not installed"
            )
        cctx = zstandard.ZstdCompressor(level=3)
        return cctx.compress
    if codec == "zlib":
        return lambda data: zlib.compress(data, 6)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _make_decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with the zstd codec but the "
                "zstandard wheel is not installed"
            )
        dctx = zstandard.ZstdDecompressor()
        return lambda data: dctx.decompress(data, max_output_size=1 << 34)
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree) -> Dict[str, np.ndarray]:
    from repro.sharding.partition import _path_str

    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str | os.PathLike, step: int, state, *,
                    extra: Optional[Dict[str, Any]] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"ckpt_{step:08d}"
    tmp = directory / f".tmp-{os.getpid()}-{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten(state)
    compress = _make_compressor(_DEFAULT_CODEC)
    offsets = {}
    with open(tmp / "data.bin", "wb") as f:
        for name, arr in leaves.items():
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            comp = compress(buf.getvalue())
            offsets[name] = (f.tell(), len(comp))
            f.write(comp)

    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "codec": _DEFAULT_CODEC,
        "treedef": str(treedef),
        "leaves": {
            n: {"offset": o, "size": s, "shape": list(leaves[n].shape),
                "dtype": str(leaves[n].dtype)}
            for n, (o, s) in offsets.items()
        },
        "extra": extra or {},
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    ckpts = sorted(p for p in directory.glob("ckpt_*") if p.is_dir())
    for p in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in directory.glob("ckpt_*") if p.is_dir()
    )
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str | os.PathLike, step: int, target, *,
    shardings=None,
):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings -- pass the *new* mesh's specs to reshard elastically."""
    from repro.sharding.partition import _path_str

    path = Path(directory) / f"ckpt_{step:08d}"
    manifest = msgpack.unpackb((path / "manifest.msgpack").read_bytes())
    decompress = _make_decompressor(manifest.get("codec", "zstd"))
    data = (path / "data.bin").read_bytes()

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(flat)
    )
    out = []
    for (p, leaf), shard in zip(flat, shard_flat):
        name = _path_str(p)
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"leaf {name!r} missing from checkpoint {path}")
        raw = decompress(data[meta["offset"]: meta["offset"] + meta["size"]])
        arr = np.load(io.BytesIO(raw), allow_pickle=False)
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out
    ), manifest


class CheckpointManager:
    """Keep-last-N manager with resume support."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 every: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, state, extra=None) -> Optional[Path]:
        if step % self.every:
            return None
        return save_checkpoint(self.directory, step, state, extra=extra,
                               keep=self.keep)

    def restore_latest(self, target, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        state, manifest = restore_checkpoint(
            self.directory, step, target, shardings=shardings
        )
        return state, manifest
