"""mixtral-8x7b [moe] -- 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=32000.
Every layer: SWA (window 4096) + MoE FFN.  Pure sliding-window => KV bounded
by the window => legitimately sub-quadratic; long_500k runs on ring caches.
8 experts are indivisible by the 16-way model axis, so expert weights fall
back to tensor-parallel d_ff sharding (partitioner fallback chain).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    block_pattern=(attn("local", moe=True),),
    n_blocks=32,
    window=4096,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    n_experts=8,
    top_k=2,
    tie_embeddings=False,
    supports_long_ctx=True,
    long_ctx_note="pure SWA: ring KV bounded at window=4096 per layer",
)
