"""jamba-1.5-large-398b [hybrid] -- Mamba+attn 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  Superblock of 8:
attention at position 4 (Jamba puts the attn layer mid-block), mamba
elsewhere; MoE replaces the dense FFN on every other layer.  Jamba uses no
explicit positional encoding (``pos_kind='none'``).  SSM state is O(1) and
only 9/72 layers hold KV => long_500k runs.
"""
from repro.configs.base import ModelConfig, attn, mamba

_BLOCK = tuple(
    (attn("global", moe=(i % 2 == 1)) if i == 4 else mamba(moe=(i % 2 == 1)))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    block_pattern=_BLOCK,
    n_blocks=9,
    mlp_kind="swiglu",
    pos_kind="none",
    n_experts=16,
    top_k=2,
    tie_embeddings=False,
    supports_long_ctx=True,
    long_ctx_note="hybrid SSM: O(1) state; KV only on 9/72 layers",
)
