"""gemma3-27b [dense] -- 5:1 local:global, 128k context [hf:google/gemma-3].

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144.
Superblock = 5 sliding-window (1024) layers + 1 global layer, x10, tail of 2
local layers (62 = 6*10 + 2).  long_500k runs with the caveat (DESIGN.md
Sec. 5): local layers keep window-bounded ring KV; the 10 global layers hold
full-length KV sharded over the model axis; the decode step itself is O(S).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    block_pattern=tuple([attn("local")] * 5 + [attn("global")]),
    n_blocks=10,
    tail_pattern=(attn("local"), attn("local")),
    window=1024,
    mlp_kind="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    supports_long_ctx=True,
    long_ctx_note="5:1 local:global -- global layers hold full 500k KV (sharded); decode O(S)",
)
