"""Config registry: ``--arch <id>`` -> exact public configuration."""
from __future__ import annotations

from repro.configs import (
    codeqwen1_5_7b,
    command_r_35b,
    gemma3_27b,
    jamba_1_5_large_398b,
    mixtral_8x7b,
    nemotron_4_15b,
    olmoe_1b_7b,
    paligemma_3b,
    whisper_small,
    xlstm_125m,
)
from repro.configs.base import SHAPES, LayerSpec, ModelConfig, ShapeSpec, shapes_for
from repro.configs.symed_paper import PAPER_SYMED, PAPER_TOL_SWEEP

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        paligemma_3b, jamba_1_5_large_398b, whisper_small, gemma3_27b,
        codeqwen1_5_7b, nemotron_4_15b, command_r_35b, mixtral_8x7b,
        olmoe_1b_7b, xlstm_125m,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS", "get_config", "SHAPES", "shapes_for", "ModelConfig", "LayerSpec",
    "ShapeSpec", "PAPER_SYMED", "PAPER_TOL_SWEEP",
]
