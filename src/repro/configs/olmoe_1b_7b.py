"""olmoe-1b-7b [moe] -- 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16 => MHA, head_dim=128) d_ff=1024 (per expert)
vocab=50304.  64 experts divide the 16-way model axis exactly -> true
expert parallelism (4 experts per shard).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    block_pattern=(attn("global", moe=True),),
    n_blocks=16,
    mlp_kind="swiglu",
    n_experts=64,
    top_k=8,
    tie_embeddings=False,
    supports_long_ctx=False,
    long_ctx_note="pure full attention -- long_500k skipped per spec",
)
