"""The paper's own hyperparameter settings (SymED Sec. 4.1 / 4.3).

Main-results configuration: alpha=0.01, scl=1.0 (2D clustering), k_min=3,
k_max=100, tol swept 0.1..2.0 in 0.1 steps.  The running example (Fig. 3)
uses tol=0.4, alpha=0.02, scl=0 (1D).
"""
from repro.core.symed import SymEDConfig

PAPER_SYMED = SymEDConfig(tol=0.5, alpha=0.01, scl=1.0, k_min=3, k_max=100)

PAPER_RUNNING_EXAMPLE = SymEDConfig(
    tol=0.4, alpha=0.02, scl=0.0, k_min=3, k_max=100, n_max=128, len_max=128
)

PAPER_TOL_SWEEP = tuple(round(0.1 * i, 1) for i in range(1, 21))
