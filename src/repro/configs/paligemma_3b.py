"""paligemma-3b [vlm] -- SigLIP + gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384 vocab=257216.
The SigLIP vision tower is a STUB per spec: ``input_specs`` supplies 256
precomputed patch embeddings; the backbone sees them as a bidirectional
prefix (PaliGemma's prefix-LM masking).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    block_pattern=(attn("global"),),
    n_blocks=18,
    mlp_kind="geglu",
    rope_theta=10_000.0,
    prefix_lm=256,
    frontend="patches",
    num_prefix_embeds=256,
    tie_embeddings=True,
    supports_long_ctx=False,
    long_ctx_note="pure full attention -- long_500k skipped per spec",
)
