"""codeqwen1.5-7b [dense] -- qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (kv=32 => MHA, head_dim=128) d_ff=13440 vocab=92416.
Qwen1.5 signature: qkv biases, rope theta 1M (64k code context).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    head_dim=128,
    block_pattern=(attn("global"),),
    n_blocks=32,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=False,
    supports_long_ctx=False,
    long_ctx_note="pure full attention -- long_500k skipped per spec",
)
